"""Serve batched GNN requests through the runtime's inference engine —
the §5.3 merchant-system shape: train hash-compressed node embeddings
jointly with GraphSAGE, freeze, then answer node-classification requests.

``GraphInferenceEngine`` (the GNN twin of ``serving.DecodeEngine`` behind
the shared ``serving.Engine`` protocol) samples each request's frontier,
partitions it host-side against the hot-node cache, and decodes ONLY the
misses — watch ``rows_decoded`` collapse between the first request and the
repeats.

Run:  PYTHONPATH=src python examples/serve_gnn.py [--nodes 8000]
      [--steps 50] [--requests 8] [--batch 128]
"""

import argparse
import time

import numpy as np

from repro.configs.paper_gnn import paper_gnn_config
from repro.graph.runtime import GraphRuntime, GraphSource, RuntimeSpec
from repro.optim import AdamWConfig
from repro.serving import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=8000)
    ap.add_argument("--classes", type=int, default=8)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=128)
    args = ap.parse_args()

    spec = RuntimeSpec(
        graph=GraphSource(kind="powerlaw", seed=0, n_nodes=args.nodes,
                          n_classes=args.classes, avg_degree=10,
                          homophily=0.9),
        model=paper_gnn_config("sage", n_nodes=args.nodes,
                               n_classes=args.classes, fanout=5),
        optimizer=AdamWConfig(lr=1e-2, weight_decay=0.0),
        batch_size=256,
        total_steps=args.steps,
        log_every=max(args.steps // 4, 1),
    ).with_updates(c=64, m=8, d_c=128, d_m=128)

    rt = GraphRuntime.from_spec(spec)
    print(f"[train] {args.steps} steps ...")
    rt.train()
    print(f"[eval] val acc = {rt.evaluate('val')['accuracy']:.4f}")

    engine = rt.serve(serve_batch=args.batch)
    assert isinstance(engine, Engine)   # shared serving protocol
    print(f"[serve] batch={args.batch}, frontier cap={engine.frontier_cap}, "
          f"cache={engine.cache_capacity} slots")

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        ids = rng.integers(0, args.nodes, args.batch)
        t0 = time.perf_counter()
        res = engine.serve(ids)
        dt = (time.perf_counter() - t0) * 1e3
        print(f"[req {i:2d}] {dt:7.1f} ms  decoded "
              f"{res.rows_decoded:5d}/{res.rows_total} rows  "
              f"top classes {np.bincount(res.predictions).argmax()}")
    stats = engine.stats()
    print(f"[done] hit_rate={stats.get('hit_rate', 0.0):.2f}  "
          f"rows_decoded={stats['rows_decoded']}/{stats['rows_total']} "
          f"({1 - stats['rows_decoded'] / stats['rows_total']:.0%} of decode "
          f"work served from the hot-node cache)")
    rt.close()


if __name__ == "__main__":
    main()
