"""End-to-end driver (paper §5.2/§5.3 scenario) through the GraphRuntime:
GraphSAGE + hash-compressed node embeddings trained jointly, evaluated on
the held-out splits, all from ONE declarative ``RuntimeSpec``.

The runtime owns the whole pipeline (graph → codes → state → sampler →
batch source → prefetch → train step → fault-tolerant loop), so this file
contains zero wiring: scaling to N shards, switching the decode backend or
enabling the hot-node cache are spec field changes (`--shards`,
``spec.with_updates(lookup_impl=..., cache_capacity=...)``).  Checkpoints
carry the spec, so killing this script mid-run and re-running continues
from the last checkpoint.

Run:  PYTHONPATH=src python examples/train_gnn_hash.py [--steps 300]
      [--kind hash_full|random_full|dense] [--nodes 20000] [--no-prefetch]
      [--shards N]
"""

import argparse
import time

from repro.configs.paper_gnn import paper_gnn_config
from repro.graph.runtime import GraphRuntime, GraphSource, RuntimeSpec
from repro.optim import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--nodes", type=int, default=20000)
    ap.add_argument("--classes", type=int, default=16)
    ap.add_argument("--kind", default="hash_full")
    ap.add_argument("--ckpt-dir", default="/tmp/hashemb_gnn_run")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable the async host->device pipeline")
    ap.add_argument("--shards", type=int, default=1,
                    help="data-parallel shards (needs >= N jax devices)")
    args = ap.parse_args()

    spec = RuntimeSpec(
        graph=GraphSource(kind="powerlaw", seed=0, n_nodes=args.nodes,
                          n_classes=args.classes, avg_degree=10,
                          homophily=0.85),
        model=paper_gnn_config("sage", n_nodes=args.nodes,
                               n_classes=args.classes, kind=args.kind,
                               fanout=10),
        optimizer=AdamWConfig(lr=1e-2, weight_decay=0.0),
        batch_size=256,
        prefetch_depth=0 if args.no_prefetch else 2,
        n_shards=args.shards,
        total_steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=100,
        log_every=25,
    )

    t0 = time.time()
    rt = GraphRuntime.from_spec(spec)
    print(f"[build] {args.nodes} nodes / {rt.adj.nnz} edges, "
          f"codes {None if rt.codes is None else tuple(rt.codes.shape)}, "
          f"{args.shards} shard(s) in {time.time()-t0:.1f}s")

    def on_metrics(step, m):
        print(f"[step {step:4d}] loss={m['loss']:.4f} "
              f"({m['step_time']*1e3:.0f} ms/step, ewma {m['ewma']*1e3:.0f} ms)")

    t0 = time.time()
    res = rt.train(on_metrics=on_metrics)
    if res.resumed_from is not None:
        print(f"[resume] continued from step {res.resumed_from}")
    print(f"[train] {len(res.losses)} steps in {time.time()-t0:.1f}s "
          f"({res.stragglers} stragglers)")

    # held-out splits: the runtime evaluates val AND test (paper protocol:
    # model selection on val, report test)
    va = rt.evaluate("val")
    te = rt.evaluate("test")
    print(f"[eval] val  acc = {va['accuracy']:.4f}  (loss {va['loss']:.4f}, "
          f"n={va['n']})")
    print(f"[eval] test acc = {te['accuracy']:.4f}  (loss {te['loss']:.4f}, "
          f"n={te['n']}, chance = {1/args.classes:.4f})")
    rt.close()


if __name__ == "__main__":
    main()
