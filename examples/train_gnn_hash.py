"""End-to-end driver (paper §5.2/§5.3 scenario): GraphSAGE + hash-compressed
node embeddings trained jointly for a few hundred steps, with checkpointing
and auto-resume — kill it mid-run and re-run to watch it continue.

Run:  PYTHONPATH=src python examples/train_gnn_hash.py [--steps 300]
      [--kind hash_full|random_full|dense] [--nodes 20000]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_gnn import paper_gnn_config
from repro.core import lsh
from repro.graph import NeighborSampler, powerlaw_graph
from repro.graph.generate import train_val_test_split
from repro.models import gnn
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.train.checkpoint import CheckpointManager


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--nodes", type=int, default=20000)
    ap.add_argument("--classes", type=int, default=16)
    ap.add_argument("--kind", default="hash_full")
    ap.add_argument("--ckpt-dir", default="/tmp/hashemb_gnn_run")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    t0 = time.time()
    adj, labels = powerlaw_graph(0, args.nodes, avg_degree=10,
                                 n_classes=args.classes, homophily=0.85)
    print(f"[data] {args.nodes} nodes / {adj.nnz} edges in {time.time()-t0:.1f}s")

    cfg = paper_gnn_config("sage", n_nodes=args.nodes, n_classes=args.classes,
                           kind=args.kind, fanout=10)
    codes = None
    if args.kind.startswith("hash"):
        t0 = time.time()
        codes = lsh.encode_lsh(key, adj, cfg.embedding.c, cfg.embedding.m)
        print(f"[encode] Algorithm 1 in {time.time()-t0:.1f}s; "
              f"codes {tuple(codes.shape)}")
    elif args.kind.startswith("random"):
        codes = lsh.encode_random(key, args.nodes, cfg.embedding.c, cfg.embedding.m)

    params = gnn.init_gnn(key, cfg, codes=codes)
    opt = adamw_init(params)
    state = {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}
    sampler = NeighborSampler(adj, cfg.fanouts, max_deg=64, seed=0)
    tr, va, te = train_val_test_split(0, args.nodes)
    labels_j = jnp.asarray(labels)
    ocfg = AdamWConfig(lr=1e-2, weight_decay=0.0)

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    restored = ckpt.restore_latest(state)
    start = 0
    if restored:
        start, state, _ = restored
        print(f"[resume] from step {start}")

    @jax.jit
    def step_fn(state, levels, y):
        def loss_fn(p):
            h = gnn.sage_forward(p, levels, cfg)
            return gnn.node_loss(gnn.node_logits(p, h, cfg), y)
        loss, g = jax.value_and_grad(loss_fn, allow_int=True)(state["params"])
        p, opt = adamw_update(state["params"], g, state["opt"], ocfg)
        return {"params": p, "opt": opt, "step": state["step"] + 1}, loss

    rng = np.random.default_rng(start)  # deterministic-per-step sampling
    t0 = time.time()
    for step in range(start, args.steps):
        batch = rng.choice(tr, 256, replace=False)
        levels = [jnp.asarray(l) for l in sampler.sample(batch)]
        state, loss = step_fn(state, levels, labels_j[jnp.asarray(batch)])
        if step % 25 == 0:
            print(f"[step {step:4d}] loss={float(loss):.4f} "
                  f"({(time.time()-t0)/max(step-start,1)*1e3:.0f} ms/step)")
        if (step + 1) % 100 == 0:
            ckpt.save(step + 1, state)
    ckpt.save(args.steps, state)
    ckpt.wait()

    levels, batch = next(sampler.minibatches(te, 1000, shuffle=False))
    h = gnn.sage_forward(state["params"], [jnp.asarray(l) for l in levels], cfg)
    acc = gnn.accuracy(gnn.node_logits(state["params"], h, cfg), labels[batch])
    print(f"[done] test acc = {acc:.4f}  (chance = {1/args.classes:.4f})")


if __name__ == "__main__":
    main()
