"""End-to-end driver (paper §5.2/§5.3 scenario) on the streaming graph
engine: GraphSAGE + hash-compressed node embeddings trained jointly with

  * dedup-decode minibatches — ``SageBatchSource`` emits unique-node
    frontiers (``repro.graph.sampler.FrontierBatch``) so the decoder runs
    once per unique node, not once per sampled position;
  * async prefetch — ``PrefetchIterator`` samples and ``device_put``s the
    next batch in a background thread while the jitted step runs;
  * the unified model API — ``GNNModel.apply(params, batch)`` +
    ``make_gnn_train_step`` drive training through the generic
    fault-tolerant loop (``repro.train.run_training``), so checkpointing,
    auto-resume and straggler monitoring come for free: kill this script
    mid-run and re-run to watch it continue from the last checkpoint.

Run:  PYTHONPATH=src python examples/train_gnn_hash.py [--steps 300]
      [--kind hash_full|random_full|dense] [--nodes 20000] [--no-prefetch]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs.paper_gnn import paper_gnn_config
from repro.core import embedding as emb_lib
from repro.graph import NeighborSampler, powerlaw_graph
from repro.graph.engine import GNNModel, PrefetchIterator, SageBatchSource
from repro.graph.generate import train_val_test_split
from repro.models import gnn
from repro.optim import AdamWConfig
from repro.train import (CheckpointManager, LoopConfig, init_gnn_train_state,
                         make_gnn_train_step, run_training)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--nodes", type=int, default=20000)
    ap.add_argument("--classes", type=int, default=16)
    ap.add_argument("--kind", default="hash_full")
    ap.add_argument("--ckpt-dir", default="/tmp/hashemb_gnn_run")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable the async host->device pipeline")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    t0 = time.time()
    adj, labels = powerlaw_graph(0, args.nodes, avg_degree=10,
                                 n_classes=args.classes, homophily=0.85)
    print(f"[data] {args.nodes} nodes / {adj.nnz} edges in {time.time()-t0:.1f}s")

    cfg = paper_gnn_config("sage", n_nodes=args.nodes, n_classes=args.classes,
                           kind=args.kind, fanout=10)
    codes = None
    if cfg.embedding_config().is_compressed:
        t0 = time.time()
        codes = emb_lib.make_codes(key, cfg.embedding_config(), aux=adj)
        print(f"[encode] Algorithm 1 in {time.time()-t0:.1f}s; "
              f"codes {tuple(codes.shape)}")

    state = init_gnn_train_state(key, cfg, codes=codes)
    train_step = make_gnn_train_step(cfg, AdamWConfig(lr=1e-2, weight_decay=0.0))

    sampler = NeighborSampler(adj, cfg.fanouts, max_deg=64, seed=0)
    tr, va, te = train_val_test_split(0, args.nodes)
    source = SageBatchSource(sampler, tr, labels, batch_size=256, seed=0)
    data_iter = source if args.no_prefetch else PrefetchIterator(source, depth=2)

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    t0 = time.time()

    def on_metrics(step, m):
        print(f"[step {step:4d}] loss={m['loss']:.4f} "
              f"({m['step_time']*1e3:.0f} ms/step, ewma {m['ewma']*1e3:.0f} ms)")

    res = run_training(train_step, state, data_iter,
                       LoopConfig(total_steps=args.steps, ckpt_every=100,
                                  log_every=25),
                       ckpt=ckpt, on_metrics=on_metrics)
    if res.resumed_from is not None:
        print(f"[resume] continued from step {res.resumed_from}")
    print(f"[train] {len(res.losses)} steps in {time.time()-t0:.1f}s "
          f"({res.stragglers} stragglers)")

    model = GNNModel(cfg)
    fb, batch = next(sampler.frontier_minibatches(te, 1000, shuffle=False))
    h = model.apply(res.state["params"], jax.device_put(fb))
    acc = gnn.accuracy(model.logits(res.state["params"], h), labels[batch])
    print(f"[done] test acc = {acc:.4f}  (chance = {1/args.classes:.4f})")


if __name__ == "__main__":
    main()
