"""Serve a (reduced) assigned-architecture LM with batched requests through
the decode engine — prefill once, then step the KV/SSM caches.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch mamba2-2.7b
      (any of the 10 assigned archs; reduced config so it runs on CPU)
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import init_lm
from repro.serving import DecodeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    print(f"[init] {cfg.name} ({cfg.family}), reduced config, "
          f"embedding={cfg.embedding.kind}")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    engine = DecodeEngine(cfg, params, s_max=args.prompt_len + args.new_tokens)

    rng = np.random.default_rng(0)
    shape = ((args.batch, args.prompt_len, cfg.n_codebooks)
             if cfg.input_mode == "audio_tokens"
             else (args.batch, args.prompt_len))
    prompts = rng.integers(0, cfg.vocab_size, shape).astype(np.int32)

    t0 = time.time()
    res = engine.generate(prompts, args.new_tokens, args.temperature)
    dt = time.time() - t0
    toks = args.batch * args.new_tokens
    print(f"[serve] {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. prefill+compile)")
    print(f"[out] shape {res.tokens.shape}; first row: {res.tokens[0][:24]}...")


if __name__ == "__main__":
    main()
