"""Quickstart: compress a node-embedding table with the paper's pipeline.

1. Build a graph (adjacency = the auxiliary information).
2. Encode every node into a compositional code (Algorithm 1 — training-free).
3. Train the shared decoder end-to-end against a downstream objective.
4. Compare the memory footprint with the uncompressed table.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import codes as codes_lib
from repro.core import lsh
from repro.core.embedding import EmbeddingConfig, embed_lookup, init_embedding
from repro.core.memory import memory_breakdown, MiB
from repro.graph.generate import powerlaw_graph
from repro.nn.module import param_bytes, trainable_mask
from repro.optim import AdamWConfig, adamw_init, adamw_update

N_NODES = 20_000
key = jax.random.PRNGKey(0)

# -- 1. graph ----------------------------------------------------------------
adj, labels = powerlaw_graph(0, N_NODES, avg_degree=8, n_classes=16)
print(f"graph: {N_NODES} nodes, {adj.nnz} edges")

# -- 2. encode (Algorithm 1: random projection, median threshold) -------------
cfg = EmbeddingConfig(kind="hash_full", n_entities=N_NODES, d_e=64,
                      c=256, m=16, d_c=512, d_m=512, compute_dtype="float32")
codes = lsh.encode_lsh(key, adj, cfg.c, cfg.m)
print(f"codes: {codes.shape} uint32 "
      f"({codes_lib.n_bits(cfg.c, cfg.m)} bits/node, "
      f"collisions={codes_lib.count_collisions(codes)})")

# -- 3. decoder trains with the downstream task -------------------------------
params = init_embedding(key, cfg, codes=codes)
w_cls = jax.random.normal(key, (64, 16)) * 0.05
opt_state = adamw_init(params)
labels_j = jnp.asarray(labels)


@jax.jit
def train_step(params, opt_state, ids):
    def loss_fn(p):
        emb = embed_lookup(p, ids, cfg)
        logits = emb @ w_cls
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, labels_j[ids][:, None], 1)[:, 0]
        return jnp.mean(logz - gold)
    loss, grads = jax.value_and_grad(loss_fn, allow_int=True)(params)
    params, opt_state = adamw_update(params, grads, opt_state,
                                     AdamWConfig(lr=1e-3))
    return params, opt_state, loss


for step in range(100):
    ids = jax.random.randint(jax.random.fold_in(key, step), (512,), 0, N_NODES)
    params, opt_state, loss = train_step(params, opt_state, ids)
    if step % 25 == 0:
        print(f"step {step:3d}  loss {float(loss):.4f}")

# -- 4. memory ----------------------------------------------------------------
b = memory_breakdown(N_NODES, cfg.d_e, cfg.c, cfg.m, cfg.d_c, cfg.d_m, 3)
print(f"\nraw table    : {b.raw_table_bytes / MiB:8.2f} MiB")
print(f"codes        : {b.binary_code_bytes / MiB:8.2f} MiB")
print(f"decoder      : {b.trainable_decoder_bytes / MiB:8.2f} MiB")
print(f"ratio        : {b.ratio_total:8.2f}x")
print(f"trainable params do not grow with nodes: "
      f"{param_bytes(params, trainable_only=True) / MiB:.2f} MiB")
# the decoder is a FIXED cost — the ratio grows with n (paper Table 4):
for n in (100_000, 1_871_031, 1_000_000_000):
    bb = memory_breakdown(n, cfg.d_e, cfg.c, cfg.m, cfg.d_c, cfg.d_m, 3)
    print(f"  at n={n:>13,}: raw {bb.raw_table_bytes/MiB:10.1f} MiB -> "
          f"compressed {bb.compressed_total/MiB:8.1f} MiB  ({bb.ratio_total:6.1f}x)")
