"""Quickstart: the paper's pipeline end to end through ONE declarative spec.

1. Describe everything — graph, GNN + compressed embedding, optimizer,
   pipeline knobs — in a ``RuntimeSpec`` (plain values, JSON round-trip).
2. ``GraphRuntime.from_spec`` builds the whole thing: the graph, Algorithm-1
   codes (training-free), the decoder + GNN state, the dedup-decode sampler
   pipeline.
3. Train the decoder jointly with the task, evaluate the held-out splits.
4. Serve batched requests through the ``GraphInferenceEngine`` (miss-only
   hot-node cached decode — only cache misses pay the decoder).
5. Compare the memory footprint with the uncompressed table.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.configs.paper_gnn import paper_gnn_config
from repro.core.memory import memory_breakdown, MiB
from repro.graph.runtime import GraphRuntime, GraphSource, RuntimeSpec
from repro.nn.module import param_bytes
from repro.optim import AdamWConfig

N_NODES = 20_000


def main():
    # -- 1. one spec = the whole pipeline ---------------------------------
    spec = RuntimeSpec(
        graph=GraphSource(kind="powerlaw", seed=0, n_nodes=N_NODES,
                          n_classes=16, avg_degree=8),
        model=paper_gnn_config("sage", n_nodes=N_NODES, n_classes=16,
                               kind="hash_full", fanout=10),
        optimizer=AdamWConfig(lr=1e-2, weight_decay=0.0),
        batch_size=256,
        total_steps=100,
        log_every=25,
    ).with_updates(d_c=128, d_m=128)     # reduced decoder so CPU stays snappy
    print(f"spec round-trips to {len(spec.to_json())} bytes of JSON")

    # -- 2. build: graph + Algorithm-1 codes + state ----------------------
    rt = GraphRuntime.from_spec(spec)
    cfg = spec.model.embedding
    print(f"graph: {N_NODES} nodes, {rt.adj.nnz} edges")
    print(f"codes: {rt.codes.shape} uint32 (c={cfg.c}, m={cfg.m} per node)")

    # -- 3. decoder trains with the downstream task -----------------------
    rt.train(on_metrics=lambda s, m: print(f"step {s:3d}  loss {m['loss']:.4f}"))
    va, te = rt.evaluate("val"), rt.evaluate("test")
    print(f"val acc {va['accuracy']:.4f} / test acc {te['accuracy']:.4f} "
          f"(chance {1/16:.4f})")

    # -- 4. serve: hot nodes decode once, repeats hit the cache -----------
    engine = rt.serve(serve_batch=128)
    rng = np.random.default_rng(0)
    for i in range(3):
        res = engine.serve(rng.integers(0, N_NODES, 128))
        print(f"request {i}: decoded {res.rows_decoded}/{res.rows_total} "
              f"frontier rows, predictions {res.predictions[:6]}...")
    print(f"serving stats: {engine.stats()}")
    rt.close()

    # -- 5. memory: the decoder is a FIXED cost (paper Table 4) -----------
    b = memory_breakdown(N_NODES, 64, cfg.c, cfg.m, cfg.d_c, cfg.d_m, 3)
    print(f"\nraw table    : {b.raw_table_bytes / MiB:8.2f} MiB")
    print(f"codes        : {b.binary_code_bytes / MiB:8.2f} MiB")
    print(f"decoder      : {b.trainable_decoder_bytes / MiB:8.2f} MiB")
    print(f"ratio        : {b.ratio_total:8.2f}x")
    emb_params = rt.params["embed"]
    print(f"trainable params do not grow with nodes: "
          f"{param_bytes(emb_params, trainable_only=True) / MiB:.2f} MiB")
    for n in (100_000, 1_871_031, 1_000_000_000):
        bb = memory_breakdown(n, 64, cfg.c, cfg.m, cfg.d_c, cfg.d_m, 3)
        print(f"  at n={n:>13,}: raw {bb.raw_table_bytes/MiB:10.1f} MiB -> "
              f"compressed {bb.compressed_total/MiB:8.1f} MiB  "
              f"({bb.ratio_total:6.1f}x)")


if __name__ == "__main__":
    main()
