"""Figure-1-style demo: watch random coding fall behind hashing as the
number of compressed entities grows (the paper's core observation).

Run:  PYTHONPATH=src python examples/reconstruction_demo.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import kmeans, nmi
from benchmarks.fig1_reconstruction import _train_decoder_on_reconstruction
from repro.core import lsh
from repro.core.embedding import decode_all
from repro.graph.generate import clustered_embeddings


def main():
    key = jax.random.PRNGKey(0)
    print(f"{'entities':>9} {'raw':>7} {'random':>7} {'hashing':>8}")
    for n in (1000, 4000, 8000):
        emb, labels = clustered_embeddings(0, n, 64, 8, noise=0.35)
        embj = jnp.asarray(emb)
        raw = nmi(kmeans(emb[:1000], 8), labels[:1000])
        row = {"raw": raw}
        for scheme in ("random", "hashing"):
            codes = (lsh.encode_random(key, n, 16, 16) if scheme == "random"
                     else lsh.encode_lsh(key, embj, 16, 16))
            params, cfg, _ = _train_decoder_on_reconstruction(key, embj, codes,
                                                              n_steps=200)
            rec = np.asarray(decode_all(params, cfg))
            row[scheme] = nmi(kmeans(rec[:1000], 8), labels[:1000])
        print(f"{n:>9} {row['raw']:7.3f} {row['random']:7.3f} "
              f"{row['hashing']:8.3f}")
    print("\nexpected: the hashing column stays near raw; random decays with n.")


if __name__ == "__main__":
    main()
