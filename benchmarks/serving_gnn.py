"""Runtime-based GNN serving benchmark (ISSUE 4).

Drives the full spec → train → ``serve()`` path: a short joint-training run
through ``GraphRuntime``, then a request stream against the
``GraphInferenceEngine`` — frontier sampling, host-side miss partition,
miss-only cached decode, fixed-shape jitted forward.

Reported axes:

  * ``request``        steady-state latency per request batch (a warmup
                       request pays compile + the cold cache, then
                       ``engine.reset()`` opens the measured window);
  * ``rows_decoded``   decoder rows actually paid per request vs the full
                       frontier — the hot-node-cache win at serving time,
                       where frozen params mean cached embeddings never go
                       stale;
  * ``uncached`` baseline: the same engine with the cache disabled decodes
                       every frontier row every request.

Registered in ``benchmarks.run`` so ``--smoke`` (2 requests) exercises the
whole serving path in CI and it can't silently rot.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, steps
from repro.configs.paper_gnn import paper_gnn_config
from repro.graph.runtime import GraphRuntime, GraphSource, RuntimeSpec
from repro.optim import AdamWConfig

N_NODES = 8000
N_CLASSES = 8
SERVE_BATCH = 256


def _request_loop(engine, n_req: int, seed: int):
    rng = np.random.default_rng(seed)
    # warmup request pays compile + the cold cache; reset() zeroes the
    # counters so the measured window is steady state only (the compile
    # bill stays visible as stats()["compile_count"])
    engine.serve(rng.integers(0, N_NODES, SERVE_BATCH))
    engine.reset()
    decoded, t0 = [], time.perf_counter()
    for _ in range(n_req):
        res = engine.serve(rng.integers(0, N_NODES, SERVE_BATCH))
        decoded.append(res.rows_decoded)
    per_req = (time.perf_counter() - t0) / max(n_req, 1) * 1e6
    return per_req, decoded, res


def run():
    spec = RuntimeSpec(
        graph=GraphSource(kind="powerlaw", seed=0, n_nodes=N_NODES,
                          n_classes=N_CLASSES, avg_degree=10, homophily=0.9),
        model=paper_gnn_config("sage", n_nodes=N_NODES, n_classes=N_CLASSES,
                               kind="hash_full", fanout=10),
        optimizer=AdamWConfig(lr=1e-2, weight_decay=0.0),
        batch_size=256, data_seed=1, prefetch_depth=2,
    ).with_updates(c=16, m=8, d_c=128, d_m=64)

    rt = GraphRuntime.from_spec(spec)
    rt.train(steps(30))
    acc = rt.evaluate("val")["accuracy"]
    n_req = steps(16)

    cached = rt.serve(serve_batch=SERVE_BATCH)
    t_cached, decoded, last = _request_loop(cached, n_req, seed=7)
    stats = cached.stats()
    emit("serving_gnn/cached/request", t_cached,
         f"rows_decoded_steady={last.rows_decoded}/{last.rows_total} "
         f"hit_rate={stats.get('hit_rate', 0.0):.2f} val_acc={acc:.3f}")
    emit("serving_gnn/cached/rows_decoded", float(np.mean(decoded)),
         f"steady-state mean over {n_req} requests "
         f"(warmup excluded via reset(), compiles={stats['compile_count']})")

    uncached = rt.serve(serve_batch=SERVE_BATCH, cache_capacity=0)
    t_unc, decoded_unc, last_unc = _request_loop(uncached, n_req, seed=7)
    emit("serving_gnn/uncached/request", t_unc,
         f"rows_decoded={last_unc.rows_decoded}/{last_unc.rows_total} "
         f"speedup_cached={t_unc / max(t_cached, 1e-9):.2f}x")
    rt.close()

    # the cache must strictly reduce decode work once warm
    if len(decoded) > 1 and decoded[-1] >= decoded_unc[-1]:
        raise AssertionError(
            f"miss-only cache did not reduce decoded rows: "
            f"{decoded[-1]} >= {decoded_unc[-1]}")
