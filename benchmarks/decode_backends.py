"""Decode-backend comparison on the frontier workload (ISSUE 2; pipeline
construction through ``GraphRuntime`` since ISSUE 4).

Times the unique-frontier embedding decode — the hot op of compressed-
embedding GNN training — through each registered ``DecodeBackend`` (gather /
onehot / pallas), through the hot-node ``CachedDecodeBackend`` during
training, and through the miss-only serving path
(``GraphInferenceEngine``), on the sampler_pipeline workload: B=256
targets, fanout (10, 10), power-law graph.

Emits the usual CSV rows AND writes ``BENCH_decode.json`` next to the repo
root so the decode-path perf trajectory has a machine-readable datapoint per
commit.

Reading the numbers on a CPU container: ``pallas`` runs in interpret mode
(a semantics check, orders of magnitude off kernel speed — compare backends
on a TPU runtime).  Every entry reports ``rows_decoded`` (plain backends
decode the whole padded frontier; stating it explicitly keeps gather /
onehot / pallas comparable in one table with the cached rows here, the
sharded/owner rows in ``BENCH_shard.json``, and the serving path).  The
cache's win column is that ``rows_decoded``: during training the
select-based cache still decodes every row (misses are the *claimable*
win), but the ``cached_missonly`` serving row pays the decoder for
**misses only** — the frontier is partitioned host-side into a padded
miss-prefix (``CachedDecodeBackend.plan_missonly``), so ``rows_decoded``
there is work actually skipped, not an accounting fiction.
"""

from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import bench_entry, emit, steps, time_fn
from repro.configs.paper_gnn import paper_gnn_config
from repro.core import backend as backend_mod
from repro.core import embedding as emb_lib
from repro.graph.runtime import GraphRuntime, GraphSource, RuntimeSpec
from repro.optim import AdamWConfig

N_NODES = 8000
N_CLASSES = 8
BATCH = 256
FANOUT = 10
OUT_PATH = Path(__file__).resolve().parents[1] / "BENCH_decode.json"


def _spec(**updates) -> RuntimeSpec:
    spec = RuntimeSpec(
        graph=GraphSource(kind="powerlaw", seed=0, n_nodes=N_NODES,
                          n_classes=N_CLASSES, avg_degree=10, homophily=0.9),
        model=paper_gnn_config("sage", n_nodes=N_NODES, n_classes=N_CLASSES,
                               kind="hash_full", fanout=FANOUT),
        optimizer=AdamWConfig(lr=1e-2, weight_decay=0.0),
        batch_size=BATCH, data_seed=1, prefetch_depth=0,
        # lane-aligned d_c so the pallas backend never pads
    ).with_updates(c=16, m=8, d_c=128, d_m=64)
    return spec.with_updates(**updates) if updates else spec


def run():
    import time as _time

    spec = _spec()
    graph = spec.graph.build()
    ecfg = spec.model.embedding_config()
    rt = GraphRuntime.from_spec(spec, graph=graph)
    params = rt.state["params"]
    fb = jax.device_put(rt.data_iter.next_batch()["frontier"])
    rows = int(fb.unique.shape[0])

    report = {
        "workload": {"n_nodes": N_NODES, "batch": BATCH,
                     "fanouts": list(spec.model.fanouts),
                     "frontier_rows": rows,
                     "c": ecfg.c, "m": ecfg.m, "d_c": ecfg.d_c},
        "device": jax.default_backend(),
        "backends": {},
    }

    # ---- per-backend frontier decode: forward and forward+backward ------
    for name in ("gather", "onehot", "pallas"):
        be = backend_mod.get_backend(
            name, interpret=(jax.default_backend() != "tpu"))

        fwd = jax.jit(lambda p, ids, be=be: emb_lib.embed_lookup(
            p, ids, ecfg, backend=be))
        grad = jax.jit(jax.grad(
            lambda p, ids, be=be: emb_lib.embed_lookup(
                p, ids, ecfg, backend=be).sum(), allow_int=True))

        t_fwd = time_fn(fwd, params["embed"], fb.unique)
        t_bwd = time_fn(grad, params["embed"], fb.unique)
        note = "interpret" if (name == "pallas"
                               and jax.default_backend() != "tpu") else "native"
        # rows_decoded on EVERY entry (not just cached ones) so plain /
        # sharded / owner / cached backends compare in one table: a plain
        # backend's decoder runs on the whole padded frontier
        emit(f"decode_backends/{name}/fwd", t_fwd,
             f"rows={rows} rows_decoded={rows} {note}")
        emit(f"decode_backends/{name}/fwd_bwd", t_bwd,
             f"rows={rows} rows_decoded={rows} {note}")
        report["backends"][name] = bench_entry(
            name, mode=note, dtype=ecfg.compute_dtype,
            fwd_us=t_fwd, fwd_bwd_us=t_bwd, rows=rows, rows_decoded=rows)
    rt.close()

    # ---- cached decode: training throughput + hit accounting ------------
    n_steps = steps(20)
    variants = {
        "uncached": spec,
        "cached_s2": _spec(cache_capacity=4096, cache_staleness=2),
    }
    for label, vspec in variants.items():
        vrt = GraphRuntime.from_spec(vspec, graph=graph)
        vstate, step = vrt.state, vrt.jitted_step
        metrics = {}
        t0 = None
        for i in range(n_steps):
            vstate, metrics = step(vstate, vrt.data_iter.next_batch())
            jax.block_until_ready(metrics["loss"])
            if i == 0:        # first step pays compile
                t0 = _time.perf_counter()
        vrt.close()
        per_step = (_time.perf_counter() - t0) / max(n_steps - 1, 1) * 1e6
        entry = bench_entry(label, mode="native", dtype=ecfg.compute_dtype,
                            step_us=per_step, steps=n_steps,
                            final_loss=float(metrics["loss"]))
        derived = f"final_loss={entry['final_loss']:.4f}"
        if "cache_hits" in metrics:
            hits = int(metrics["cache_hits"])
            misses = int(metrics["cache_misses"])
            total = max(hits + misses, 1)
            entry.update(hits=hits, misses=misses,
                         hit_rate=hits / total,
                         rows_decoded_per_step=misses / n_steps)
            derived += (f" hit_rate={hits / total:.2f}"
                        f" rows_decoded={misses / n_steps:.0f}/{rows}")
        else:
            # uncached training decodes the whole padded frontier per step —
            # stated explicitly so every row of the table carries the same
            # rows_decoded accounting
            entry["rows_decoded_per_step"] = rows
            derived += f" rows_decoded={rows}/{rows}"
        emit(f"decode_backends/{label}/step", per_step, derived)
        report["backends"][label] = entry

    # ---- miss-only cached decode (serving path): only misses pay --------
    # The serving engine partitions each frontier host-side into a padded
    # miss-prefix, so rows_decoded here is decoder work actually performed.
    srt = GraphRuntime.from_spec(spec, graph=graph)
    engine = srt.serve(serve_batch=BATCH)
    n_req = steps(20)
    rng = np.random.default_rng(3)
    t0 = None
    for i in range(n_req):
        res = engine.serve(rng.integers(0, N_NODES, BATCH))
        if i == 0:            # first request pays compile
            t0 = _time.perf_counter()
    per_req = (_time.perf_counter() - t0) / max(n_req - 1, 1) * 1e6
    stats = engine.stats()
    srt.close()
    entry = bench_entry(
        "cached_missonly", mode="native", dtype=ecfg.compute_dtype,
        request_us=per_req, requests=n_req,
        rows_decoded_per_request=stats["rows_decoded"] / n_req,
        rows_per_request=stats["rows_total"] / n_req,
        hit_rate=stats.get("hit_rate", 0.0),
        last_request_rows_decoded=res.rows_decoded)
    emit("decode_backends/cached_missonly/request", per_req,
         f"rows_decoded={entry['rows_decoded_per_request']:.0f}"
         f"/{entry['rows_per_request']:.0f}"
         f" hit_rate={entry['hit_rate']:.2f}"
         f" steady_state_rows={res.rows_decoded}")
    report["backends"]["cached_missonly"] = entry

    # smoke runs exercise the code path but must not clobber the committed
    # real-measurement datapoint with 1-2-iteration throwaway numbers
    from benchmarks import common
    if common.SMOKE:
        emit("decode_backends/json", 0.0,
             f"smoke: skipped writing {OUT_PATH.name}")
    else:
        OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
        emit("decode_backends/json", 0.0, f"wrote {OUT_PATH.name}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
