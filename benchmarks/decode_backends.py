"""Decode-backend comparison on the frontier workload (ISSUE 2).

Times the unique-frontier embedding decode — the hot op of compressed-
embedding GNN training — through each registered ``DecodeBackend`` (gather /
onehot / pallas) and through the hot-node ``CachedDecodeBackend``, on the
sampler_pipeline workload: B=256 targets, fanout (10, 10), power-law graph.

Emits the usual CSV rows AND writes ``BENCH_decode.json`` next to the repo
root so the decode-path perf trajectory has a machine-readable datapoint per
commit.

Reading the numbers on a CPU container: ``pallas`` runs in interpret mode
(a semantics check, orders of magnitude off kernel speed — compare backends
on a TPU runtime); the cache's win column is ``rows_decoded`` (misses), the
decode work a miss-only implementation performs, not wall-clock (the
select-based cache still decodes every row on CPU).
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, steps, time_fn
from repro.configs.paper_gnn import paper_gnn_config
from repro.core import backend as backend_mod
from repro.core import embedding as emb_lib
from repro.graph import NeighborSampler, powerlaw_graph
from repro.graph.engine import SageBatchSource
from repro.train.step import init_gnn_train_state, make_gnn_train_step

N_NODES = 8000
N_CLASSES = 8
BATCH = 256
FANOUT = 10
KEY = jax.random.PRNGKey(0)
OUT_PATH = Path(__file__).resolve().parents[1] / "BENCH_decode.json"


def _setup():
    adj, labels = powerlaw_graph(0, N_NODES, avg_degree=10,
                                 n_classes=N_CLASSES, homophily=0.9)
    cfg = paper_gnn_config("sage", n_nodes=N_NODES, n_classes=N_CLASSES,
                           kind="hash_full", fanout=FANOUT)
    # lane-aligned d_c so the pallas backend never pads
    cfg = dataclasses.replace(
        cfg, embedding=dataclasses.replace(cfg.embedding, c=16, m=8,
                                           d_c=128, d_m=64))
    codes = emb_lib.make_codes(KEY, cfg.embedding_config(), aux=adj)
    return adj, labels, cfg, codes


def run():
    adj, labels, cfg, codes = _setup()
    ecfg = cfg.embedding_config()
    state = init_gnn_train_state(KEY, cfg, codes=codes)
    params = state["params"]

    sampler = NeighborSampler(adj, cfg.fanouts, max_deg=64, seed=0)
    src = SageBatchSource(sampler, np.arange(N_NODES), labels, BATCH, seed=1)
    fb = jax.device_put(src.next_batch()["frontier"])
    rows = int(fb.unique.shape[0])

    report = {
        "workload": {"n_nodes": N_NODES, "batch": BATCH,
                     "fanouts": list(cfg.fanouts), "frontier_rows": rows,
                     "c": ecfg.c, "m": ecfg.m, "d_c": ecfg.d_c},
        "device": jax.default_backend(),
        "backends": {},
    }

    # ---- per-backend frontier decode: forward and forward+backward ------
    for name in ("gather", "onehot", "pallas"):
        be = backend_mod.get_backend(
            name, interpret=(jax.default_backend() != "tpu"))

        fwd = jax.jit(lambda p, ids, be=be: emb_lib.embed_lookup(
            p, ids, ecfg, backend=be))
        grad = jax.jit(jax.grad(
            lambda p, ids, be=be: emb_lib.embed_lookup(
                p, ids, ecfg, backend=be).sum(), allow_int=True))

        t_fwd = time_fn(fwd, params["embed"], fb.unique)
        t_bwd = time_fn(grad, params["embed"], fb.unique)
        note = "interpret" if (name == "pallas"
                               and jax.default_backend() != "tpu") else "native"
        emit(f"decode_backends/{name}/fwd", t_fwd, f"rows={rows} {note}")
        emit(f"decode_backends/{name}/fwd_bwd", t_bwd, f"rows={rows} {note}")
        report["backends"][name] = {
            "fwd_us": t_fwd, "fwd_bwd_us": t_bwd, "rows": rows, "mode": note}

    # ---- cached decode: training throughput + hit accounting ------------
    n_steps = steps(20)
    variants = {
        "uncached": cfg,
        "cached_s2": dataclasses.replace(cfg, embedding=dataclasses.replace(
            cfg.embedding, cache_capacity=4096, cache_staleness=2)),
    }
    import time as _time
    for label, c in variants.items():
        vsrc = SageBatchSource(sampler, np.arange(N_NODES), labels, BATCH,
                               seed=1)
        vstate = init_gnn_train_state(KEY, c, codes=codes)
        step = jax.jit(make_gnn_train_step(c))
        metrics = {}
        t0 = None
        for i in range(n_steps):
            vstate, metrics = step(vstate, jax.device_put(vsrc.next_batch()))
            jax.block_until_ready(metrics["loss"])
            if i == 0:        # first step pays compile
                t0 = _time.perf_counter()
        per_step = (_time.perf_counter() - t0) / max(n_steps - 1, 1) * 1e6
        entry = {"step_us": per_step, "steps": n_steps,
                 "final_loss": float(metrics["loss"])}
        derived = f"final_loss={entry['final_loss']:.4f}"
        if "cache_hits" in metrics:
            hits = int(metrics["cache_hits"])
            misses = int(metrics["cache_misses"])
            total = max(hits + misses, 1)
            entry.update(hits=hits, misses=misses,
                         hit_rate=hits / total,
                         rows_decoded_per_step=misses / n_steps)
            derived += (f" hit_rate={hits / total:.2f}"
                        f" rows_decoded={misses / n_steps:.0f}/{rows}")
        emit(f"decode_backends/{label}/step", per_step, derived)
        report["backends"][label] = entry

    # smoke runs exercise the code path but must not clobber the committed
    # real-measurement datapoint with 1-2-iteration throwaway numbers
    from benchmarks import common
    if common.SMOKE:
        emit("decode_backends/json", 0.0,
             f"smoke: skipped writing {OUT_PATH.name}")
    else:
        OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
        emit("decode_backends/json", 0.0, f"wrote {OUT_PATH.name}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
