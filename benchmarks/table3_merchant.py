"""Paper Table 3 — merchant-category identification (§5.3), reduced scale.

Consumer×merchant bipartite transaction graph with Zipf-imbalanced
categories and degree imbalance (the §5.3 difficulty notes); GraphSAGE with
fanout 5 per §5.3.2; Rand vs Hash coding (NC is infeasible at the paper's
scale — here we keep the same omission).  Metrics: accuracy + hit@k.
Claim: Hash > Rand on all metrics.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, steps
from repro.configs.paper_gnn import merchant_config
from repro.core import lsh
from repro.graph import NeighborSampler
from repro.graph.generate import bipartite_transaction_graph, train_val_test_split
from repro.models import gnn
from repro.optim import AdamWConfig, adamw_init, adamw_update

N_CONSUMERS = 6000
N_MERCHANTS = 4000
N_CATEGORIES = 32
KEY = jax.random.PRNGKey(0)


def run():
    adj, merchant_cat, n_cons = bipartite_transaction_graph(
        0, N_CONSUMERS, N_MERCHANTS, N_CATEGORIES)
    n_nodes = N_CONSUMERS + N_MERCHANTS
    merchants = np.arange(N_MERCHANTS) + n_cons
    tr_i, va_i, te_i = train_val_test_split(0, N_MERCHANTS)   # 70/10/20 (§5.3.1)
    labels = merchant_cat
    ocfg = AdamWConfig(lr=1e-2, weight_decay=0.0)             # §5.3.2

    for kind in ("random_full", "hash_full"):
        cfg = merchant_config(n_nodes, N_CATEGORIES, kind)
        cfg = dataclasses.replace(
            cfg, embedding=dataclasses.replace(cfg.embedding, c=16, m=8,
                                               d_c=64, d_m=64))
        codes = (lsh.encode_lsh(KEY, adj, 16, 8) if kind == "hash_full"
                 else lsh.encode_random(KEY, n_nodes, 16, 8))
        p = gnn.init_gnn(KEY, cfg, codes=codes)
        sampler = NeighborSampler(adj, cfg.fanouts, max_deg=64, seed=0)
        st = adamw_init(p)

        @jax.jit
        def step(p, st, levels, y):
            def loss_fn(p):
                h = gnn.sage_forward(p, levels, cfg)
                return gnn.node_loss(gnn.node_logits(p, h, cfg), y)
            loss, g = jax.value_and_grad(loss_fn, allow_int=True)(p)
            p, st = adamw_update(p, g, st, ocfg)
            return p, st, loss

        t0 = time.time()
        nsteps = 0
        for epoch in range(steps(4, 1)):
            for levels, batch in sampler.minibatches(merchants[tr_i], 256):
                if nsteps >= steps(10**9):
                    break
                y = jnp.asarray(labels[batch - n_cons])
                p, st, _ = step(p, st, [jnp.asarray(l) for l in levels], y)
                nsteps += 1

        levels, batch = next(sampler.minibatches(merchants[te_i], 800, shuffle=False))
        h = gnn.sage_forward(p, [jnp.asarray(l) for l in levels], cfg)
        logits = gnn.node_logits(p, h, cfg)
        y = labels[batch - n_cons]
        acc = gnn.accuracy(logits, y)
        name = "Hash" if kind == "hash_full" else "Rand"
        emit(f"table3/{name}", (time.time() - t0) / nsteps * 1e6,
             f"acc={acc:.4f};hit@5={gnn.hit_rate_at_k(logits, y, 5):.4f};"
             f"hit@10={gnn.hit_rate_at_k(logits, y, 10):.4f}")
