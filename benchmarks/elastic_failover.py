"""Elastic failover benchmark (ISSUE 9): kill → detect → transfer → rescale.

Drives a 4-shard ``GraphRuntime`` through an ``ElasticManager`` with a
deterministic ``FailurePlan`` (shard 2 dies at step 10, one transfer chunk
arrives corrupted) and reports what recovery actually costs in the units
that transfer to a fleet: **steps lost** to detection latency and **bytes
moved** over the peer wire (chunks, retransmits, payload) — plus the
post-recovery bitwise-equality flag against a never-failed run rescaled
from the same state.  Recovery wall-clock rides along as a non-headline
column (``recovery_wall_s_cpu``): forced host devices share cores, so on
this container it measures interpreter overhead, not fleet behaviour
(ROADMAP "CPU timings lie").

Runs in a SUBPROCESS with ``XLA_FLAGS=--xla_force_host_platform_device_
count=8`` so the 4-shard mesh is real while the benchmark suite keeps its
single-device view (tests/conftest.py).  Emits the usual CSV rows AND
writes ``BENCH_elastic.json`` (smoke mode exercises the path but never
clobbers the committed datapoint).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from benchmarks.common import emit, steps

ROOT = Path(__file__).resolve().parents[1]
OUT_PATH = ROOT / "BENCH_elastic.json"

_WORKER = """
import dataclasses, json, sys, time
import jax, numpy as np
from repro.configs.paper_gnn import paper_gnn_config
from repro.elastic import ElasticManager, ElasticSpec, FailurePlan
from repro.graph.runtime import GraphRuntime, GraphSource, RuntimeSpec
from repro.optim import AdamWConfig

N_NODES, N_CLASSES, BATCH, FANOUT = 4000, 8, 48, 5
total_steps = int(sys.argv[1])
kill_at = int(sys.argv[2])

spec = RuntimeSpec(
    graph=GraphSource(kind="powerlaw", seed=0, n_nodes=N_NODES,
                      n_classes=N_CLASSES, avg_degree=10, homophily=0.9),
    model=paper_gnn_config("sage", n_nodes=N_NODES, n_classes=N_CLASSES,
                           fanout=FANOUT),
    optimizer=AdamWConfig(lr=1e-2, weight_decay=0.0),
    batch_size=BATCH, data_seed=1, prefetch_depth=2, n_shards=4,
    elastic=ElasticSpec(lease_steps=1, chunk_bytes=1 << 16),
).with_updates(c=16, m=8, d_c=128, d_m=64, lookup_impl="sharded:gather")
graph = spec.graph.build()

plan = FailurePlan(kill=((2, kill_at),), corrupt_chunks=(1,))
rt = GraphRuntime.from_spec(spec, graph=graph)
mgr = ElasticManager(rt, plan=plan)

t0 = time.perf_counter()
res = mgr.run(total_steps)
total_wall = time.perf_counter() - t0
rep = res.reports[0]
res.runtime.close()

# reference: never-failed run to the interrupt point, same exact rescale to
# the survivor count, same remaining steps — the post-recovery curve must
# be bitwise this one (the core elastic invariant, tests/test_elastic.py)
recovered_at = rep.detected_at_step + 1
rt4 = GraphRuntime.from_spec(spec, graph=graph)
head = rt4.train(recovered_at)
t1 = time.perf_counter()
rt3 = rt4.rescale(rep.n_after)
rescale_wall = time.perf_counter() - t1
rt4.close()
tail = rt3.train(total_steps - recovered_at)
rt3.close()
bitwise = res.losses == head.losses + tail.losses

out = {
    "device_count": jax.device_count(),
    "workload": {"n_nodes": N_NODES, "batch": BATCH,
                 "fanouts": [FANOUT, FANOUT], "steps": total_steps,
                 "kill": {"shard": 2, "step": kill_at},
                 "lease_steps": mgr.spec.lease_steps,
                 "chunk_bytes": mgr.spec.chunk_bytes,
                 "lookup_impl": spec.model.embedding.lookup_impl},
    # the decode path is XLA-native at the model compute dtype; wall-clock
    # columns are CPU-container numbers and explicitly non-headline
    "mode": "native", "dtype": spec.model.compute_dtype,
    "topology": {"before": rep.n_before, "after": rep.n_after},
    "steps_lost": rep.steps_lost,
    "detected_at_step": rep.detected_at_step,
    "payload_bytes": rep.payload_bytes,
    "bytes_transferred": rep.bytes_transferred,
    "chunks": rep.chunks,
    "retransmits": rep.retransmits,
    "post_recovery_bitwise": bitwise,
    "recovery_wall_s_cpu": rescale_wall,
    "run_wall_s_cpu": total_wall,
    "history": res.history,
}
print("BENCH_JSON:" + json.dumps(out))
"""


def run():
    # smoke compresses the schedule (kill at step 1, 4 total) so the full
    # kill/transfer/rescale path runs in seconds
    total, kill_at = (14, 10) if steps(14) == 14 else (4, 1)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src") + (
        ":" + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-c", _WORKER, str(total), str(kill_at)],
        capture_output=True, text=True, env=env, cwd=str(ROOT), timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(
            f"elastic_failover worker failed:\n{proc.stdout}\n{proc.stderr}")
    payload = [l for l in proc.stdout.splitlines()
               if l.startswith("BENCH_JSON:")]
    report = json.loads(payload[-1][len("BENCH_JSON:"):])

    topo = report["topology"]
    emit("elastic_failover/recovery", 0.0,
         f"shards={topo['before']}->{topo['after']} "
         f"steps_lost={report['steps_lost']} "
         f"bytes_transferred={report['bytes_transferred']} "
         f"chunks={report['chunks']} retransmits={report['retransmits']}")
    emit("elastic_failover/post_recovery_bitwise", 0.0,
         str(report["post_recovery_bitwise"]))
    if not report["post_recovery_bitwise"]:
        raise AssertionError(
            "post-recovery loss curve diverged from the never-failed "
            "rescaled reference — the exact-rescale invariant regressed")
    if report["retransmits"] < 1:
        raise AssertionError(
            "the corrupted transfer chunk was not retransmitted — CRC "
            "verification on the peer wire regressed")

    from benchmarks import common
    if common.SMOKE:
        emit("elastic_failover/json", 0.0,
             f"smoke: skipped writing {OUT_PATH.name}")
    else:
        OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
        emit("elastic_failover/json", 0.0, f"wrote {OUT_PATH.name}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
