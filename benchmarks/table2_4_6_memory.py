"""Paper Tables 2, 4 and 6 — memory cost + compression ratios, reproduced
EXACTLY by the closed-form calculators (core.memory).  Derived column shows
ours vs the published value."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core import memory as M


def run():
    # Table 2 (ogbn-products, n=1,871,031)
    t = M.PAPER_TABLE2
    light = M.memory_breakdown(t["n"], t["d_e"], 256, 16, 512, 512, 3, "light")
    full = M.memory_breakdown(t["n"], t["d_e"], 256, 16, 512, 512, 3, "full")
    rows = [
        ("raw_gpu_mib", light.raw_table_bytes / M.MiB, t["raw_gpu_mib"]),
        ("binary_code_mib", light.binary_code_bytes / M.MiB, t["binary_code_mib"]),
        ("light_decoder_gpu_mib", light.trainable_decoder_bytes / M.MiB,
         t["light_decoder_gpu_mib"]),
        ("light_codebooks_cpu_mib", light.frozen_decoder_bytes / M.MiB,
         t["light_codebooks_cpu_mib"]),
        ("full_decoder_gpu_mib", full.trainable_decoder_bytes / M.MiB,
         t["full_decoder_gpu_mib"]),
    ]
    for name, ours, ref in rows:
        emit(f"table2/{name}", 0.0, f"ours={ours:.2f};paper={ref:.2f}")
    gnn = t["gnn_mib"] * M.MiB
    ratio = (full.raw_table_bytes + gnn) / (full.trainable_decoder_bytes + gnn)
    emit("table2/full_ratio_gpu", 0.0, f"ours={ratio:.2f};paper={t['full_ratio_gpu']}")

    # Table 4
    for n, ref in M.PAPER_TABLE4_GLOVE.items():
        emit(f"table4/glove/n{n}", 0.0,
             f"ours={M.compression_ratio(n, 300, 2, 128):.2f};paper={ref}")
    for n, ref in M.PAPER_TABLE4_M2V.items():
        emit(f"table4/m2v/n{n}", 0.0,
             f"ours={M.compression_ratio(n, 128, 2, 128):.2f};paper={ref}")

    # Table 6
    for (c, m), d in M.PAPER_TABLE6_GLOVE.items():
        for n, ref in d.items():
            emit(f"table6/glove/c{c}m{m}/n{n}", 0.0,
                 f"ours={M.compression_ratio(n, 300, c, m):.2f};paper={ref}")
    for (c, m), d in M.PAPER_TABLE6_M2V.items():
        for n, ref in d.items():
            emit(f"table6/m2v/c{c}m{m}/n{n}", 0.0,
                 f"ours={M.compression_ratio(n, 128, c, m):.2f};paper={ref}")
