"""Sharded streaming pipeline benchmark (ISSUE 3, runtime-fronted in
ISSUE 4, owner-computes decode in ISSUE 5).

Times the end-to-end streaming GNN train step at 1 and 4 shards — plus a
4-shard **owner-computes** run (``lookup_impl="owner:gather"``, hub rows
deduped across shards) — and checks the step-0 forward-loss bit-identity
contract the tests assert.  The whole pipeline — batch source selection,
mesh, frontier placement, owner plan, prefetch — comes from
``GraphRuntime.from_spec``; the three legs differ by exactly two
``RuntimeSpec`` fields (``n_shards``, ``lookup_impl``).  Emits the usual
CSV rows AND writes ``BENCH_shard.json``.

The measurement runs in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the 4-shard legs
exercise a real 4-device mesh even though the benchmark suite itself must
keep a single-device view (tests/conftest.py).  Reading the numbers on this
CPU container: forced host devices share the same cores, so the 4-shard
``step_us`` measures *overhead* of the sharded path (shard_map + collectives),
not speedup — the decode-row columns are the scaling axis on real multi-host
hardware.  Per run: ``frontier_rows_per_device`` is the local frontier
block (``frontier_cap``, padding included), ``unique_rows_per_device`` the
measured mean per-shard unique count, and ``rows_decoded_per_device`` the
rows each device's decoder actually runs per step — a STATIC padded shape
under the same accounting for every run: the full local block for the
local-decode runs, the per-owner decode capacity (``owner_unique_cap``)
for the owner run, whose measured post-dedup floor rides along as
``owned_unique_rows_per_device`` (hubs decode once on their owner instead
of once per shard — the reclaim the ``--bench`` smoke asserts can't
regress).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from benchmarks.common import emit, steps

ROOT = Path(__file__).resolve().parents[1]
OUT_PATH = ROOT / "BENCH_shard.json"

_WORKER = """
import json, sys, time
import jax, numpy as np
from repro.configs.paper_gnn import paper_gnn_config
from repro.graph.engine import default_frontier_cap
from repro.graph.runtime import GraphRuntime, GraphSource, RuntimeSpec
from repro.optim import AdamWConfig

N_NODES, N_CLASSES, BATCH, FANOUT = 8000, 8, 256, 10
n_steps = int(sys.argv[1])

spec = RuntimeSpec(
    graph=GraphSource(kind="powerlaw", seed=0, n_nodes=N_NODES,
                      n_classes=N_CLASSES, avg_degree=10, homophily=0.9),
    model=paper_gnn_config("sage", n_nodes=N_NODES, n_classes=N_CLASSES,
                           fanout=FANOUT),
    optimizer=AdamWConfig(lr=1e-2, weight_decay=0.0),
    batch_size=BATCH, data_seed=1, prefetch_depth=2,
).with_updates(c=16, m=8, d_c=128, d_m=64, lookup_impl="sharded:gather")
graph = spec.graph.build()

def run(n_shards, impl=None):
    # fix the per-shard frontier cap at its worst case so every step keeps
    # one jit shape (a varying round-up cap would recompile mid-measurement)
    cap = default_frontier_cap(BATCH // n_shards, spec.model.fanouts,
                               spec.pad_to, N_NODES)
    sp = spec.with_updates(n_shards=n_shards, frontier_cap=cap)
    if impl is not None:
        sp = sp.with_updates(lookup_impl=impl)
    rt = GraphRuntime.from_spec(sp, graph=graph)
    state, step = rt.state, rt.jitted_step
    losses, uniq, decoded, owned, t0 = [], [], [], [], None
    try:
        for i in range(n_steps):
            batch = rt.data_iter.next_batch()
            fb = batch["frontier"]
            uniq.append(int(np.asarray(fb.n_unique)))
            # rows each device's decoder actually runs per step (STATIC
            # padded shapes, same accounting for every run): the owner
            # plan's per-owner decode capacity, else the full local block.
            # The owner run additionally reports its measured owned-unique
            # mean — the floor the capacity is padded up from.
            plan = getattr(fb, "plan", None)
            if plan is not None:
                decoded.append(plan.owned_src.shape[1])
                owned.append(int(np.asarray(plan.n_owned).sum()) / n_shards)
            else:
                decoded.append(fb.unique.shape[0] // n_shards)
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))   # blocks
            if i == 0:
                t0 = time.perf_counter()            # first step pays compile
    finally:
        rt.close()
    per_step = (time.perf_counter() - t0) / max(n_steps - 1, 1) * 1e6
    rows_total = batch["frontier"].unique.shape[0]
    out = {"n_shards": n_shards,
           "lookup_impl": sp.model.embedding.lookup_impl,
           # every BENCH entry carries mode + dtype (tools/ci.sh gate);
           # the sharded lookups are XLA-native at the model compute dtype
           "mode": "native", "dtype": sp.model.compute_dtype,
           "step_us": per_step, "losses": losses,
           "frontier_rows_total": rows_total,
           "frontier_rows_per_device": rows_total // n_shards,
           "unique_rows_per_device": sum(uniq) / len(uniq) / n_shards,
           "rows_decoded_per_device": sum(decoded) / len(decoded)}
    if owned:
        out["owned_unique_rows_per_device"] = sum(owned) / len(owned)
    return out

out = {"device_count": jax.device_count(),
       "workload": {"n_nodes": N_NODES, "batch": BATCH,
                    "fanouts": [FANOUT, FANOUT], "steps": n_steps,
                    "lookup_impl": spec.model.embedding.lookup_impl},
       "runs": {"1shard": run(1), "4shard": run(4),
                "owner": run(4, impl="owner:gather")}}
out["step0_loss_bit_identical"] = (
    out["runs"]["1shard"]["losses"][0] == out["runs"]["4shard"]["losses"][0]
    == out["runs"]["owner"]["losses"][0])
print("BENCH_JSON:" + json.dumps(out))
"""


def run():
    n_steps = steps(12)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src") + (
        ":" + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-c", _WORKER, str(n_steps)],
        capture_output=True, text=True, env=env, cwd=str(ROOT), timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(
            f"sharded_pipeline worker failed:\n{proc.stdout}\n{proc.stderr}")
    payload = [l for l in proc.stdout.splitlines() if l.startswith("BENCH_JSON:")]
    report = json.loads(payload[-1][len("BENCH_JSON:"):])

    for label, r in report["runs"].items():
        owned = ("" if "owned_unique_rows_per_device" not in r else
                 f"owned_unique/device={r['owned_unique_rows_per_device']:.0f} ")
        emit(f"sharded_pipeline/{label}/step", r["step_us"],
             f"rows/device={r['frontier_rows_per_device']} "
             f"unique/device={r['unique_rows_per_device']:.0f} "
             f"decoded/device={r['rows_decoded_per_device']:.0f} "
             f"{owned}loss0={r['losses'][0]:.6f}")
    ident = report["step0_loss_bit_identical"]
    emit("sharded_pipeline/step0_bit_identical", 0.0, str(ident))
    if not ident:
        raise AssertionError(
            "1-shard vs 4-shard vs owner step-0 forward loss diverged: "
            f"{report['runs']['1shard']['losses'][0]} vs "
            f"{report['runs']['4shard']['losses'][0]} vs "
            f"{report['runs']['owner']['losses'][0]}")
    # the owner run's whole point: cross-shard dedup must actually reclaim
    # decode rows (asserted in --bench smoke so it can't silently regress)
    own = report["runs"]["owner"]
    if not own["rows_decoded_per_device"] < own["frontier_rows_per_device"]:
        raise AssertionError(
            "owner run decoded "
            f"{own['rows_decoded_per_device']:.0f} rows/device, expected "
            f"< frontier_rows_per_device={own['frontier_rows_per_device']} "
            "(cross-shard dedup regressed — did the owner plan fall back?)")

    # smoke runs exercise the code path but must not clobber the committed
    # real-measurement datapoint with 2-step throwaway numbers
    from benchmarks import common
    if common.SMOKE:
        emit("sharded_pipeline/json", 0.0,
             f"smoke: skipped writing {OUT_PATH.name}")
    else:
        OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
        emit("sharded_pipeline/json", 0.0, f"wrote {OUT_PATH.name}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
