"""Codes-placement benchmark (ISSUE 10): O(frontier) vs O(nodes) device
code memory, measured as an accounting sweep — not wall-clock.

``codes_placement="device"`` replicates the packed ``codes_buf`` into the
params, so device code bytes grow linearly with the graph.
``codes_placement="host"`` keeps the buffer in host RAM and the prefetch
producer gathers each frontier's rows into the batch — device code bytes
are then bounded by the *frontier cap*, which this sweep holds fixed while
the graph grows >= 8x.  The claim lands as two columns:

  ``device_resident_code_bytes``       bytes of packed codes inside the
                                       device train state (codes_buf nbytes;
                                       0 for host placement)
  ``transferred_code_bytes_per_batch`` bytes of packed code rows the host
                                       streams per batch (U_pad * n_words *
                                       4; 0 for device placement — its rows
                                       ride in the resident buffer)

plus the per-stage producer timings the PrefetchIterator now accounts
(``sample_us`` / ``code_gather_us`` / ``put_us``) — reported, not asserted:
CPU wall-clock on this container says nothing about TPU H2D overlap, but
the stage split shows where the producer's time actually goes.

Bit-exactness is asserted, not sampled: the host-placement loss sequence
must equal the replicated run bitwise at step 0 AND after 5 streaming
steps (the gather commutes with decode), and the run fails loudly if any
size breaks it.  Writes ``BENCH_offload.json`` (skipped under --smoke,
which still runs a reduced sweep and the step-0 bitwise check).
"""

from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np

from benchmarks import common
from benchmarks.common import bench_entry, emit

OUT_PATH = Path(__file__).resolve().parents[1] / "BENCH_offload.json"

# Fixed frontier shape across the sweep: batch 32 @ fanouts (5, 5) has a
# worst-case unique count of 32 + 160 + 800 = 992 -> cap 1024.  Every
# sweep size uses the SAME cap, so any growth in device code bytes is the
# graph, never the batch.
BATCH = 32
FANOUTS = (5, 5)
FRONTIER_CAP = 1024
SWEEP = (2_000, 4_000, 8_000, 16_000)      # 8x node growth
TRAIN_STEPS = 5


def _spec(n_nodes: int, placement: str):
    from repro.configs.base import EmbeddingSpec, GNNConfig
    from repro.graph.runtime import GraphSource, RuntimeSpec
    emb = EmbeddingSpec(kind="hash_full", c=16, m=8, d_c=64, d_m=64,
                        n_layers=2, lookup_impl="gather",
                        codes_placement=placement)
    model = GNNConfig(name=f"offload-{n_nodes}", model="sage",
                      n_nodes=n_nodes, n_classes=16, d_e=16, hidden=32,
                      fanouts=FANOUTS, embedding=emb)
    return RuntimeSpec(graph=GraphSource(n_nodes=n_nodes), model=model,
                       batch_size=BATCH, pad_to=64,
                       frontier_cap=FRONTIER_CAP, prefetch_depth=2,
                       total_steps=TRAIN_STEPS)


def device_resident_code_bytes(params) -> int:
    """Bytes of packed code rows living in the device param tree."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        keys = [str(getattr(p, "key", p)) for p in path]
        if keys and keys[-1] == "codes_buf":
            total += int(np.asarray(leaf).nbytes)
    return total


def _run_one(n_nodes: int, placement: str, steps: int):
    """Build, step ``steps`` batches through the prefetch pipeline, return
    (losses, resident_bytes, per_batch_bytes, producer_stats)."""
    from repro.graph.runtime import GraphRuntime
    rt = GraphRuntime.from_spec(_spec(n_nodes, placement))
    try:
        resident = device_resident_code_bytes(rt.state["params"])
        losses = []
        for _ in range(steps):
            b = rt.data_iter.next_batch()
            rt.state, m = rt.jitted_step(rt.state, rt._to_device(b))
            losses.append(float(np.asarray(m["loss"])))
        stats = (rt.data_iter.stats()
                 if hasattr(rt.data_iter, "stats") else {})
        per_batch = float(stats.get("transferred_code_bytes_per_batch", 0.0))
        return losses, resident, per_batch, stats
    finally:
        rt.close()


def run():
    interpret = jax.default_backend() != "tpu"
    mode = "interpret" if interpret else "native"
    sweep = SWEEP[:2] if common.SMOKE else SWEEP
    steps = 2 if common.SMOKE else TRAIN_STEPS

    entries = []
    bitwise_step0 = True
    bitwise_after = True
    for n_nodes in sweep:
        by_placement = {}
        for placement in ("device", "host"):
            losses, resident, per_batch, stats = _run_one(
                n_nodes, placement, steps)
            by_placement[placement] = (losses, resident, per_batch, stats)
        l_dev = by_placement["device"][0]
        l_host = by_placement["host"][0]
        eq0 = l_dev[0] == l_host[0]
        eqN = l_dev == l_host
        bitwise_step0 &= eq0
        bitwise_after &= eqN
        for placement, (losses, resident, per_batch, stats) in \
                by_placement.items():
            entries.append(bench_entry(
                f"codes_offload/{placement}/n{n_nodes}",
                mode=mode, dtype="float32",
                n_nodes=n_nodes, frontier_cap=FRONTIER_CAP,
                codes_placement=placement,
                device_resident_code_bytes=resident,
                transferred_code_bytes_per_batch=per_batch,
                bitwise_equal_vs_replicated=(True if placement == "device"
                                             else bool(eqN)),
                sample_us=float(stats.get("sample_us", 0.0)),
                code_gather_us=float(stats.get("code_gather_us", 0.0)),
                put_us=float(stats.get("put_us", 0.0)),
                loss_step0=losses[0], loss_last=losses[-1]))
            emit(f"codes_offload/{placement}/n{n_nodes}", 0.0,
                 f"resident={resident}B per_batch={per_batch:.0f}B "
                 f"bitwise_step0={eq0} bitwise_{steps}steps={eqN}")

    host = [e for e in entries if e["codes_placement"] == "host"]
    dev = [e for e in entries if e["codes_placement"] == "device"]
    # the tentpole claim, asserted on every run (smoke included):
    # host-placement device code bytes are O(frontier) — flat across the
    # sweep and strictly below the replicated buffer — while the replicated
    # baseline grows with the graph
    host_bytes = [e["device_resident_code_bytes"] for e in host]
    dev_bytes = [e["device_resident_code_bytes"] for e in dev]
    assert all(b == host_bytes[0] for b in host_bytes), \
        f"host device code bytes not flat across sweep: {host_bytes}"
    assert all(h < d for h, d in zip(host_bytes, dev_bytes)), \
        f"host placement not below replicated: {host_bytes} vs {dev_bytes}"
    assert all(b2 > b1 for b1, b2 in zip(dev_bytes, dev_bytes[1:])), \
        f"replicated baseline failed to grow with the graph: {dev_bytes}"
    if not bitwise_step0:
        raise AssertionError("host placement diverged from replicated at "
                             "step 0 — the gather must commute with decode")
    if not bitwise_after:
        raise AssertionError(
            f"host placement diverged from replicated within {steps} "
            f"streaming steps")
    emit("codes_offload/summary", 0.0,
         f"host resident flat at {host_bytes[0]}B over {sweep[0]}->"
         f"{sweep[-1]} nodes; replicated grows {dev_bytes[0]}->"
         f"{dev_bytes[-1]}B; bitwise={bitwise_after}")

    report = {
        "device": jax.default_backend(),
        "sweep": {"n_nodes": list(sweep), "batch": BATCH,
                  "fanouts": list(FANOUTS), "frontier_cap": FRONTIER_CAP,
                  "train_steps": steps},
        "bitwise_equal_step0": bool(bitwise_step0),
        "bitwise_equal_after_steps": bool(bitwise_after),
        "entries": entries,
    }
    if common.SMOKE:
        emit("codes_offload/json", 0.0,
             f"smoke: skipped writing {OUT_PATH.name}")
    else:
        OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
        emit("codes_offload/json", 0.0, f"wrote {OUT_PATH.name}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
