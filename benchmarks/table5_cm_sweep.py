"""Paper Table 5 — reconstruction quality across (c, m) settings for random
vs hashing coding, at fixed 128-bit codes.  Reduced CPU scale: 64-bit codes,
two entity counts; quality = k-means NMI (the metapath2vec protocol).
Claim: hashing >= random in (almost) all cells, gap grows with n."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import steps, emit, kmeans, nmi
from benchmarks.fig1_reconstruction import _train_decoder_on_reconstruction
from repro.core import lsh
from repro.core.embedding import decode_all
from repro.graph.generate import clustered_embeddings

SETTINGS = [(2, 64), (4, 32), (16, 16), (256, 8)]   # all 64-bit codes
DIM = 64
EVAL_N = 2000


def run():
    key = jax.random.PRNGKey(0)
    for n_entities in (2000, 8000):
        emb, labels = clustered_embeddings(0, n_entities, DIM, 8, noise=0.35)
        embj = jnp.asarray(emb)
        for c, m in SETTINGS:
            for scheme in ("random", "hashing"):
                codes = (lsh.encode_random(key, n_entities, c, m)
                         if scheme == "random" else lsh.encode_lsh(key, embj, c, m))
                import benchmarks.fig1_reconstruction as f1
                f1.C, f1.M = c, m     # reuse the trainer at this (c, m)
                t0 = time.time()
                params, cfg, loss = _train_decoder_on_reconstruction(
                    key, embj, codes, n_steps=steps(200))
                rec = np.asarray(decode_all(params, cfg))
                q = nmi(kmeans(rec[:EVAL_N], 8), labels[:EVAL_N])
                emit(f"table5/c{c}m{m}/{scheme}/n{n_entities}",
                     (time.time() - t0) / steps(200) * 1e6, f"nmi={q:.4f}")
    f1 = __import__("benchmarks.fig1_reconstruction", fromlist=["C"])
    f1.C, f1.M = 16, 16   # restore defaults
