"""Quality-vs-memory sweep across compression families (ISSUE 8).

Puts the paper's bit-code hashing head-to-head against position-based hash
embeddings (``lookup_impl="hashemb:gather"``, arXiv:2109.00101) and
tensor-train factorized codebooks (``lookup_impl="tt"``, arXiv:2206.10581)
at MATCHED memory budgets — the table1-style comparison ROADMAP item 4 asks
for.  Memory is the decode-stage *table bytes*: family parameters (codebooks
/ pools+wpos / TT cores) plus the per-entity ``codes_buf`` words (zero for
hashemb, whose position hashes are recomputed from the id); the MLP tail is
identical across families at fixed (d_c, d_m) and therefore excluded from
the matching axis.  For each budget a small per-family grid (c, and TT rank
r) picks the config closest to the target, every cell trains the same
GraphSAGE workload through ``GraphRuntime`` (same graph, seeds, optimizer,
steps) and reports val accuracy.

Emits the usual CSV rows AND writes ``BENCH_compression.json``, gated in
``tools/ci.sh --bench`` (>= 2 budgets x 3 families, ``mode``+``dtype`` on
every entry).  CPU wall-clock is reported but the honest axes are
``table_bytes`` vs ``val_accuracy``.
"""

from __future__ import annotations

import json
import math
from pathlib import Path

from benchmarks.common import bench_entry, emit, steps
from repro.core import codes as codes_lib
from repro.core.backend import tt_factor_pair

N_NODES = 2000
N_CLASSES = 8
BATCH = 64
M = 8
D_C = 64
D_M = 64
TRAIN_STEPS = 150
FAMILIES = ("paper", "hashemb", "tt")
# target decode-stage table bytes (params + codes_buf) per budget
BUDGETS = {"small_40k": 40_000, "large_512k": 520_000}
C_GRID = (16, 32, 64, 128, 256)
R_GRID = (2, 4, 8, 16, 32, 64)
OUT_PATH = Path(__file__).resolve().parents[1] / "BENCH_compression.json"


def table_bytes(family: str, c: int, r: int, n_entities: int = N_NODES,
                m: int = M, d_c: int = D_C) -> int:
    """f32 bytes of the decode-stage table + per-entity code storage."""
    codes = codes_lib.n_words(c, m) * 4 * n_entities
    if family == "paper":
        return m * c * d_c * 4 + codes
    if family == "hashemb":
        return (m * c * d_c + m * d_c) * 4     # no codes_buf at all
    if family == "tt":
        c1, c2 = tt_factor_pair(c)
        d1, d2 = tt_factor_pair(d_c)
        return m * r * (c1 * d1 + c2 * d2) * 4 + codes
    raise ValueError(family)


def pick_config(family: str, target: int):
    """Grid config whose table bytes land closest to ``target``."""
    best = None
    for c in C_GRID:
        for r in (R_GRID if family == "tt" else (0,)):
            b = table_bytes(family, c, r)
            if best is None or abs(b - target) < abs(best[2] - target):
                best = (c, r, b)
    return best


def _spec(lookup_impl: str, c: int, tt_rank: int):
    from repro.configs.paper_gnn import paper_gnn_config
    from repro.graph.runtime import GraphSource, RuntimeSpec
    from repro.optim import AdamWConfig
    return RuntimeSpec(
        graph=GraphSource(kind="powerlaw", seed=0, n_nodes=N_NODES,
                          n_classes=N_CLASSES, avg_degree=10, homophily=0.9),
        model=paper_gnn_config("sage", n_nodes=N_NODES, n_classes=N_CLASSES,
                               kind="hash_full", fanout=10),
        optimizer=AdamWConfig(lr=1e-2, weight_decay=0.0),
        batch_size=BATCH, data_seed=1, prefetch_depth=0,
    ).with_updates(c=c, m=M, d_c=D_C, d_m=D_M, lookup_impl=lookup_impl,
                   tt_rank=max(tt_rank, 1))


IMPLS = {"paper": "onehot", "hashemb": "hashemb:gather", "tt": "tt"}


def run():
    import time as _time

    from repro.graph.runtime import GraphRuntime

    n_steps = steps(TRAIN_STEPS)
    report = {
        "workload": {"n_nodes": N_NODES, "n_classes": N_CLASSES,
                     "batch": BATCH, "m": M, "d_c": D_C, "d_m": D_M,
                     "train_steps": n_steps},
        "budgets": {},
    }
    for bname, target in BUDGETS.items():
        row = {"target_bytes": target, "families": {}}
        for family in FAMILIES:
            c, r, bytes_ = pick_config(family, target)
            spec = _spec(IMPLS[family], c, r)
            rt = GraphRuntime.from_spec(spec)
            try:
                t0 = _time.perf_counter()
                res = rt.train(n_steps)
                train_s = _time.perf_counter() - t0
                ev = rt.evaluate("val")
                dcfg = rt.cfg.embedding_config().decoder_config()
                assert all(math.isfinite(l) for l in res.losses), family
                entry = bench_entry(
                    f"{bname}/{family}", mode="native",
                    dtype=rt.cfg.compute_dtype,
                    lookup_impl=IMPLS[family], c=c,
                    tt_rank=(r if family == "tt" else None),
                    table_bytes=bytes_,
                    trainable_params=dcfg.trainable_params(),
                    val_accuracy=float(ev["accuracy"]),
                    val_loss=float(ev["loss"]),
                    final_train_loss=float(res.losses[-1]),
                    train_s=train_s)
                row["families"][family] = entry
                emit(f"compression_sweep/{bname}/{family}",
                     train_s / max(n_steps, 1) * 1e6,
                     f"bytes={bytes_} c={c}"
                     + (f" r={r}" if family == "tt" else "")
                     + f" val_acc={ev['accuracy']:.3f}")
            finally:
                rt.close()
        report["budgets"][bname] = row

    # smoke runs exercise the code path but must not clobber the committed
    # real-measurement datapoint with 2-step throwaway numbers
    from benchmarks import common
    if common.SMOKE:
        emit("compression_sweep/json", 0.0,
             f"smoke: skipped writing {OUT_PATH.name}")
    else:
        OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
        emit("compression_sweep/json", 0.0, f"wrote {OUT_PATH.name}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
