"""Roofline summary over dry-run artifacts (EXPERIMENTS.md §Roofline source).

Reads results/dryrun_baseline/*.json (if present — the dry-run must be run
separately: it needs the 512-device XLA flag which benchmarks must NOT set)
and emits one row per cell with the three terms + dominant + fraction.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DIRS = ("results/dryrun_final", "results/dryrun_baseline")


def run():
    d = next((x for x in DIRS if os.path.isdir(x)), None)
    if d is None:
        emit("roofline/missing", 0.0, "run repro.launch.dryrun first")
        return
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        rec = json.load(open(path))
        name = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec["status"] == "skipped":
            emit(name, 0.0, "skipped:subquadratic-required")
            continue
        if rec["status"] != "ok":
            emit(name, 0.0, f"FAILED:{rec.get('error', '?')[:60]}")
            continue
        r = rec["roofline"]
        emit(name, r["step_s"] * 1e6,
             f"dom={r['dominant']};c={r['compute_s']:.4f};m={r['memory_s']:.4f};"
             f"x={r['collective_s']:.4f};frac={r['roofline_fraction']:.3f};"
             f"useful={r['useful_ratio']:.2f}")
