# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
# Modules (see each for the claim it validates):
#   fig1_reconstruction  Figure 1  — coding schemes vs entity count
#   fig3_collisions      Figure 3  — median vs zero LSH threshold
#   sampler_pipeline     ISSUE 1   — dedup-decode rows + prefetch steps/sec
#   codes_offload        ISSUE 10  — host codes placement: O(frontier) device bytes
#   decode_backends      ISSUE 2   — gather/onehot/pallas/cached frontier decode
#   sharded_pipeline     ISSUE 3   — 1- vs 4-shard streaming step (8 forced devices)
#   serving_gnn          ISSUE 4   — GraphRuntime serve(): miss-only cached decode
#   serving_load         ISSUE 7   — continuous batching under Zipfian load
#   elastic_failover     ISSUE 9   — kill/rescale recovery: steps lost, bytes moved
#   table1_gnn           Table 1   — NC/Rand/Hash with 4 GNNs + link pred
#   table2_4_6_memory    Tables 2/4/6 — memory arithmetic (EXACT)
#   table3_merchant      Table 3   — bipartite merchant classification
#   table5_cm_sweep      Table 5   — (c, m) sweep
#   compression_sweep    ISSUE 8   — quality-vs-memory: paper vs hashemb vs tt
#   kernels_micro        kernel CPU microbenchmarks
#   roofline_report      §Roofline summary from dry-run artifacts (if present)
#
# Run all:        PYTHONPATH=src python -m benchmarks.run
# Run a subset:   PYTHONPATH=src python -m benchmarks.run --only fig3,table2
# Smoke (CI):     PYTHONPATH=src python -m benchmarks.run --smoke
#                 (~2 steps per benchmark: exercises every module's code path
#                 quickly; emitted numbers are not measurements)
import argparse
import sys
import time
import traceback

MODULES = [
    "table2_4_6_memory",   # instant, exact — first
    "fig3_collisions",
    "sampler_pipeline",
    "codes_offload",
    "decode_backends",
    "sharded_pipeline",
    "serving_gnn",
    "serving_load",
    "elastic_failover",
    "kernels_micro",
    "roofline_report",
    "fig1_reconstruction",
    "table5_cm_sweep",
    "compression_sweep",
    "table1_gnn",
    "table3_merchant",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module-name substrings")
    ap.add_argument("--smoke", action="store_true",
                    help="run each benchmark for ~2 steps (rot check, not a "
                         "measurement)")
    args = ap.parse_args()

    if args.smoke:
        from benchmarks import common
        common.SMOKE = True

    print("name,us_per_call,derived")
    failures = 0
    for name in MODULES:
        if args.only and not any(s in name for s in args.only.split(",")):
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            mod.run()
            print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
