"""Kernel microbenchmarks + the fused hash-decode roofline datapoint.

Wall-clock on this container is CPU (interpret-mode Pallas is a semantics
check, not a perf number), so the honest comparison is:
  * XLA-path wall time of the decode/encode/attention ops on CPU (relative
    cost of onehot vs gather decode — the TPU adaptation argument), and
  * the roofline-derived TPU estimates from the dry-run artifacts.

The fused hash-decode section (ISSUE 6) measures the kernel at every decode
precision (f32 / bf16 codebooks / fused-int8) and writes
``BENCH_kernels.json``: per-dtype modeled HBM bytes
(``launch.roofline.decode_hbm_bytes``), the roofline step floor and the
achieved-vs-roofline ratio for the measured wall time.  Every entry carries
``mode`` ("native" on a TPU runtime, "interpret" here — in which case
``achieved_vs_roofline`` documents interpreter overhead, not kernel
efficiency) and ``dtype``, enforced by ``common.bench_entry``.  The run
asserts the fused int8 forward matches f32 within the documented drift
bound (``core.backend.DRIFT_BOUNDS``) and that int8 cuts codebook bytes by
>= 3.5x — the acceptance bars, checked on every --bench CI leg.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import bench_entry, emit, time_fn
from repro.core.decoder import DecoderConfig, apply_decoder, init_decoder
from repro.kernels.flash_attention.ref import mha_ref

KEY = jax.random.PRNGKey(0)
OUT_PATH = Path(__file__).resolve().parents[1] / "BENCH_kernels.json"

# Paper §5.3 decode shape (B is the padded unique-frontier row count)
DECODE_SHAPE = dict(c=256, m=16, d_c=512)
MIN_INT8_BYTE_REDUCTION = 3.5


def _fused_decode_bench(report: dict) -> None:
    from repro.core.backend import DRIFT_BOUNDS
    from repro.kernels.hash_decode import ops as hd_ops
    from repro.launch.roofline import decode_hbm_bytes, decode_roofline

    c, m, d_c = DECODE_SHAPE["c"], DECODE_SHAPE["m"], DECODE_SHAPE["d_c"]
    B = 1024 if common.SMOKE else 8192
    interpret = jax.default_backend() != "tpu"
    mode = "interpret" if interpret else "native"

    codes = jax.random.randint(KEY, (B, m), 0, c, jnp.int32)
    cb = jax.random.normal(jax.random.fold_in(KEY, 1), (m, c, d_c),
                           jnp.float32) / np.sqrt(m)

    # One jitted callable per (variant, direction), built once and reused
    # for warm-up, timing AND the drift-check output — a fresh jax.jit
    # wrapper per call site would re-pay compilation on the call the timing
    # loop doesn't see.  fwd_bwd times value_and_grad, NOT grad-of-sum: the
    # sum's cotangent needs no primal value, so XLA dead-code-eliminates
    # the (interpret-mode, expensive) forward kernel out of a pure grad —
    # which is how fwd_bwd_us used to come out *below* fwd_us.  Returning
    # the loss keeps the forward in the measured computation, so
    # fwd_bwd >= fwd holds by construction.
    def fwd_fn(quantize):
        return jax.jit(lambda codes, cb: hd_ops.hash_decode(
            codes, cb, interpret=interpret, quantize=quantize))

    def fwd_bwd_fn(quantize):
        return jax.jit(jax.value_and_grad(
            lambda cb, codes: hd_ops.hash_decode(
                codes, cb, interpret=interpret, quantize=quantize).sum()))

    f32_fwd = fwd_fn("none")
    out_f32 = f32_fwd(codes, cb)
    variants = {
        "float32": (cb, "none"),
        "bfloat16": (cb.astype(jnp.bfloat16), "none"),
        "int8": (cb, "int8"),      # quantized + dequant fused in the kernel
    }
    entries = []
    for dtype, (cb_v, quantize) in variants.items():
        fwd = f32_fwd if quantize == "none" and dtype == "float32" \
            else fwd_fn(quantize)
        fwd_bwd = fwd_bwd_fn(quantize)
        t_fwd = time_fn(fwd, codes, cb_v)
        t_bwd = time_fn(fwd_bwd, cb_v, codes)
        out = fwd(codes, cb_v)
        rel = float(jnp.linalg.norm(out.astype(jnp.float32) - out_f32)
                    / jnp.linalg.norm(out_f32))
        bound = DRIFT_BOUNDS.get(dtype)
        if bound is not None and rel > bound:
            raise AssertionError(
                f"fused decode {dtype} drift {rel:.4g} exceeds the "
                f"documented bound {bound} (core.backend.DRIFT_BOUNDS)")
        roof = decode_roofline(B, c, m, d_c, dtype, measured_us=t_fwd)
        entries.append(bench_entry(
            f"hash_decode_fused/{dtype}", mode=mode, dtype=dtype,
            fwd_us=t_fwd, fwd_bwd_us=t_bwd,
            rel_err_vs_f32=rel, drift_bound=bound,
            modeled=roof,
            hbm_bytes=decode_hbm_bytes(B, c, m, d_c, dtype)))
        emit(f"kernels/hash_decode_fused/{dtype}/fwd", t_fwd,
             f"B={B},c={c},m={m},d_c={d_c} mode={mode} "
             f"hbm_bytes={roof['hbm_bytes']:.0f} "
             f"roofline_step_us={roof['step_us']:.2f} "
             f"achieved_vs_roofline={roof['achieved_vs_roofline']:.2e} "
             f"rel_err={rel:.2e}")
        emit(f"kernels/hash_decode_fused/{dtype}/fwd_bwd", t_bwd,
             f"B={B},c={c},m={m},d_c={d_c} mode={mode}")

    by_dtype = {e["dtype"]: e for e in entries}
    cb_f32 = by_dtype["float32"]["modeled"]["hbm_bytes_codebooks"]
    cb_int8 = by_dtype["int8"]["modeled"]["hbm_bytes_codebooks"]
    reduction = cb_f32 / cb_int8
    if reduction < MIN_INT8_BYTE_REDUCTION:
        raise AssertionError(
            f"int8 codebook byte reduction {reduction:.2f}x < "
            f"{MIN_INT8_BYTE_REDUCTION}x")
    emit("kernels/hash_decode_fused/int8_codebook_byte_reduction",
         0.0, f"{reduction:.2f}x vs f32 (>= {MIN_INT8_BYTE_REDUCTION}x)")

    report["fused_hash_decode"] = {
        "shape": {"B": B, **DECODE_SHAPE},
        "int8_codebook_byte_reduction_vs_f32": reduction,
        "entries": entries,
    }


def run():
    report = {"device": jax.default_backend()}

    # decode: gather vs onehot (B=8192 tokens, paper §5.3 c/m, d_c=512)
    cfg = DecoderConfig(c=256, m=16, d_c=512, d_m=512, d_e=64,
                        compute_dtype="float32")
    p = init_decoder(KEY, cfg)
    codes = jax.random.randint(KEY, (8192, cfg.m), 0, cfg.c)
    for impl in ("gather", "onehot"):
        c2 = dataclasses.replace(cfg, lookup_impl=impl)
        f = jax.jit(lambda p, c: apply_decoder(p, c, c2))
        us = time_fn(f, p, codes)
        emit(f"kernels/hash_decode/{impl}/cpu", us,
             "B=8192,c=256,m=16,d_c=512 (CPU favors gather; onehot targets the MXU)")

    # fused pallas kernel at every decode precision -> BENCH_kernels.json
    _fused_decode_bench(report)

    # dense-table lookup baseline (what compression replaces)
    table = jax.random.normal(KEY, (200_000, 64))
    ids = jax.random.randint(KEY, (8192,), 0, 200_000)
    us = time_fn(jax.jit(lambda t, i: t[i]), table, ids)
    emit("kernels/dense_table_lookup/cpu", us, "n=200k,d=64")

    # lsh encode: one 32-bit word over (65536, 256)
    A = jax.random.normal(KEY, (65536, 256))
    V = jax.random.normal(jax.random.fold_in(KEY, 1), (256, 32))
    t = jnp.zeros((32,))
    from repro.kernels.lsh_encode.ref import lsh_encode_word_ref
    us = time_fn(jax.jit(lsh_encode_word_ref), A, V, t)
    emit("kernels/lsh_encode_word/cpu", us, "n=65536,d=256,w=32")

    # attention reference at a prefill-ish shape
    q = jax.random.normal(KEY, (1, 8, 1024, 64), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 2, 1024, 64))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 2, 1024, 64))
    us = time_fn(jax.jit(lambda q, k, v: mha_ref(q, k, v, causal=True)), q, k, v)
    emit("kernels/attention_xla/cpu", us, "B1,H8,K2,S1024,D64")

    # smoke runs exercise the path with 1-iteration throwaway timings —
    # never overwrite the committed measurement
    if common.SMOKE:
        emit("kernels/json", 0.0, f"smoke: skipped writing {OUT_PATH.name}")
    else:
        OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
        emit("kernels/json", 0.0, f"wrote {OUT_PATH.name}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
