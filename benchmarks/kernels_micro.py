"""Kernel microbenchmarks.

Wall-clock on this container is CPU (interpret-mode Pallas is a semantics
check, not a perf number), so the honest comparison is:
  * XLA-path wall time of the decode/encode/attention ops on CPU (relative
    cost of onehot vs gather decode — the TPU adaptation argument), and
  * the roofline-derived TPU estimates from the dry-run artifacts.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.decoder import DecoderConfig, apply_decoder, init_decoder
from repro.kernels.flash_attention.ref import mha_ref

KEY = jax.random.PRNGKey(0)


def run():
    # decode: gather vs onehot (B=8192 tokens, paper §5.3 c/m, d_c=512)
    cfg = DecoderConfig(c=256, m=16, d_c=512, d_m=512, d_e=64,
                        compute_dtype="float32")
    p = init_decoder(KEY, cfg)
    codes = jax.random.randint(KEY, (8192, cfg.m), 0, cfg.c)
    for impl in ("gather", "onehot"):
        c2 = dataclasses.replace(cfg, lookup_impl=impl)
        f = jax.jit(lambda p, c: apply_decoder(p, c, c2))
        us = time_fn(f, p, codes)
        emit(f"kernels/hash_decode/{impl}/cpu", us,
             "B=8192,c=256,m=16,d_c=512 (CPU favors gather; onehot targets the MXU)")

    # dense-table lookup baseline (what compression replaces)
    table = jax.random.normal(KEY, (200_000, 64))
    ids = jax.random.randint(KEY, (8192,), 0, 200_000)
    us = time_fn(jax.jit(lambda t, i: t[i]), table, ids)
    emit("kernels/dense_table_lookup/cpu", us, "n=200k,d=64")

    # lsh encode: one 32-bit word over (65536, 256)
    A = jax.random.normal(KEY, (65536, 256))
    V = jax.random.normal(jax.random.fold_in(KEY, 1), (256, 32))
    t = jnp.zeros((32,))
    from repro.kernels.lsh_encode.ref import lsh_encode_word_ref
    us = time_fn(jax.jit(lsh_encode_word_ref), A, V, t)
    emit("kernels/lsh_encode_word/cpu", us, "n=65536,d=256,w=32")

    # attention reference at a prefill-ish shape
    q = jax.random.normal(KEY, (1, 8, 1024, 64), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 2, 1024, 64))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 2, 1024, 64))
    us = time_fn(jax.jit(lambda q, k, v: mha_ref(q, k, v, causal=True)), q, k, v)
    emit("kernels/attention_xla/cpu", us, "B1,H8,K2,S1024,D64")
