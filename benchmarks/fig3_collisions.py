"""Paper Figure 3 / Appendix A — median vs zero LSH threshold collisions.

Protocol matches the appendix: same projection basis per trial (same seed),
only the threshold differs; repeated trials; report collision counts.
Claim: median < zero, consistently.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import lsh
from repro.graph.generate import clustered_embeddings

N = 100000
DIM = 64
TRIALS = 20


def run():
    emb, _ = clustered_embeddings(3, N, DIM, n_clusters=8, noise=0.3)
    embj = jnp.asarray(emb)
    for bits, (c, m) in (("24bit", (8, 8)), ("32bit", (16, 8))):
        res = {}
        for thr in ("median", "zero"):
            t0 = time.time()
            cols = lsh.collision_experiment(
                jax.random.PRNGKey(42), embj, c, m, TRIALS, thr)
            res[thr] = cols
            emit(f"fig3/{bits}/{thr}", (time.time() - t0) / TRIALS * 1e6,
                 f"collisions_mean={cols.mean():.1f};min={cols.min()};max={cols.max()}")
        wins = int((res["median"] <= res["zero"]).sum())
        emit(f"fig3/{bits}/median_wins", 0.0, f"{wins}/{TRIALS}")
