"""Engine micro-benchmark — dedup-decode + async prefetch (ISSUE 1),
driven through ``GraphRuntime`` (ISSUE 4): every pipeline variant is a
``RuntimeSpec`` field change (``dedup``, ``prefetch_depth``), not bespoke
wiring.

Measures, on the quickstart-scale synthetic graph, the three claims the
``repro.graph.engine`` refactor makes:

  1. dedup-decode shrinks decoder rows per GraphSAGE batch from
     B + B·f1 + B·f1·f2 to the unique-frontier count (reported as the
     measured duplication factor);
  2. prefetched sampling overlaps host-side numpy with the jitted train
     step (steps/sec sync vs. prefetch);
  3. the engine's loss trajectory matches the naive pre-refactor path on a
     fixed seed to within numerical tolerance.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import emit, steps
from repro.configs.paper_gnn import paper_gnn_config
from repro.graph.runtime import GraphRuntime, GraphSource, RuntimeSpec
from repro.optim import AdamWConfig

N_NODES = 8000
N_CLASSES = 8
BATCH = 256
STEPS = 40


def _spec(**updates) -> RuntimeSpec:
    spec = RuntimeSpec(
        graph=GraphSource(kind="powerlaw", seed=0, n_nodes=N_NODES,
                          n_classes=N_CLASSES, avg_degree=10, homophily=0.9),
        model=paper_gnn_config("sage", n_nodes=N_NODES, n_classes=N_CLASSES,
                               kind="hash_full", fanout=10),
        optimizer=AdamWConfig(lr=1e-2, weight_decay=0.0),
        batch_size=BATCH, data_seed=1, prefetch_depth=0,
    ).with_updates(c=16, m=8, d_c=64, d_m=64)
    return spec.with_updates(**updates) if updates else spec


def _train(spec: RuntimeSpec, graph, n_steps: int):
    """Per-step times + losses from the runtime's own loop (the loop's
    ``float(loss)`` device sync makes the timings honest)."""
    rt = GraphRuntime.from_spec(spec, graph=graph)
    try:
        res = rt.train(n_steps)
    finally:
        rt.close()
    warm = min(4, n_steps - 1)              # skip compile steps
    per_step = float(np.mean(res.step_times[warm:])) if n_steps > warm else 0.0
    return np.asarray(res.losses), per_step


def run():
    graph = _spec().graph.build()           # share one build across variants

    # -- 1. decoded rows per batch: naive vs unique frontier ------------
    spec = _spec()
    f1, f2 = spec.model.fanouts
    naive_rows = BATCH * (1 + f1 + f1 * f2)
    probe = GraphRuntime.from_spec(spec, graph=graph)
    uniq, padded = [], []
    for _ in range(steps(20)):
        fb = probe.data_iter.next_batch()["frontier"]
        uniq.append(int(fb.n_unique))
        padded.append(fb.unique.shape[0])
    probe.close()
    emit("sampler_pipeline/decode_rows", float(np.mean(padded)),
         f"naive={naive_rows} unique={np.mean(uniq):.0f} "
         f"dup_factor={naive_rows / np.mean(padded):.2f}x")

    # -- 2. steps/sec: sync vs prefetched sampling ----------------------
    # Context for reading the delta: prefetch hides host sampling time behind
    # the device step, so the ceiling is sample_ms / (sample_ms + step_ms).
    # On a CPU backend XLA already saturates the cores during the step, so
    # the overlap win shrinks to ~breakeven; on an accelerator the host is
    # idle during the step and the full sampling time is recovered.
    t0 = time.perf_counter()
    probe = GraphRuntime.from_spec(spec, graph=graph)
    for _ in range(steps(20)):
        probe.data_iter.next_batch()
    probe.close()
    emit("sampler_pipeline/host_sample",
         (time.perf_counter() - t0) / steps(20) * 1e6,
         "host-side numpy sampling per batch")

    _, t_sync = _train(_spec(prefetch_depth=0), graph, steps(STEPS))
    _, t_pf = _train(_spec(prefetch_depth=2), graph, steps(STEPS))
    emit("sampler_pipeline/step_sync", t_sync * 1e6,
         f"steps_per_sec={1.0 / max(t_sync, 1e-9):.1f}")
    emit("sampler_pipeline/step_prefetch", t_pf * 1e6,
         f"steps_per_sec={1.0 / max(t_pf, 1e-9):.1f} "
         f"speedup={t_sync / max(t_pf, 1e-9):.2f}x")

    # -- 3. loss-trajectory parity: engine vs pre-refactor naive path ---
    # The forward pass is bit-identical (tests/test_engine.py); under
    # training the two paths reduce gradients in different orders (dedup
    # scatter-adds into unique rows), so trajectories track within float32
    # accumulation noise rather than exactly.
    losses_dedup, _ = _train(_spec(dedup=True), graph, steps(30))
    losses_naive, _ = _train(_spec(dedup=False), graph, steps(30))
    gaps = np.abs(losses_dedup - losses_naive)
    emit("sampler_pipeline/loss_parity", float(gaps.max()) * 1e6,
         f"max_abs_loss_gap={gaps.max():.3e} early_gap={gaps[:10].max():.3e} "
         f"final_loss={losses_dedup[-1]:.4f}")
    assert gaps[:10].max() < 1e-3, \
        f"dedup trajectory diverged early from naive path: {gaps[:10].max()}"
    assert gaps.max() < 1e-1, \
        f"dedup trajectory diverged from naive path: {gaps.max()}"
