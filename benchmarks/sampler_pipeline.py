"""Engine micro-benchmark — dedup-decode + async prefetch (ISSUE 1).

Measures, on the quickstart-scale synthetic graph, the three claims the
``repro.graph.engine`` refactor makes:

  1. dedup-decode shrinks decoder rows per GraphSAGE batch from
     B + B·f1 + B·f1·f2 to the unique-frontier count (reported as the
     measured duplication factor);
  2. prefetched sampling overlaps host-side numpy with the jitted train
     step (steps/sec sync vs. prefetch);
  3. the engine's loss trajectory matches the naive pre-refactor path on a
     fixed seed to within numerical tolerance.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, steps
from repro.configs.paper_gnn import paper_gnn_config
from repro.core import embedding as emb_lib
from repro.graph import NeighborSampler, powerlaw_graph
from repro.graph.engine import PrefetchIterator, SageBatchSource
from repro.train.step import init_gnn_train_state, make_gnn_train_step

N_NODES = 8000
N_CLASSES = 8
BATCH = 256
STEPS = 40
KEY = jax.random.PRNGKey(0)


def _setup():
    adj, labels = powerlaw_graph(0, N_NODES, avg_degree=10,
                                 n_classes=N_CLASSES, homophily=0.9)
    cfg = paper_gnn_config("sage", n_nodes=N_NODES, n_classes=N_CLASSES,
                           kind="hash_full", fanout=10)
    cfg = dataclasses.replace(
        cfg, embedding=dataclasses.replace(cfg.embedding, c=16, m=8, d_c=64, d_m=64))
    codes = emb_lib.make_codes(KEY, cfg.embedding_config(), aux=adj)
    state = init_gnn_train_state(KEY, cfg, codes=codes)
    return adj, labels, cfg, state


def _source(adj, labels, cfg, dedup: bool) -> SageBatchSource:
    sampler = NeighborSampler(adj, cfg.fanouts, max_deg=64, seed=0)
    return SageBatchSource(sampler, np.arange(N_NODES), labels, BATCH,
                           seed=1, dedup=dedup)


def _run(step_fn, state, data_iter, n_steps: int):
    state = jax.tree.map(jnp.copy, state)   # each run trains from the same init
    jitted = jax.jit(step_fn)
    warm = min(4, n_steps - 1)              # skip compile steps before timing
    losses, t0 = [], None
    for i in range(n_steps):
        batch = jax.device_put(data_iter.next_batch()) \
            if isinstance(data_iter, SageBatchSource) else data_iter.next_batch()
        state, metrics = jitted(state, batch)
        losses.append(float(metrics["loss"]))
        if i == warm:
            t0 = time.perf_counter()
    dt = time.perf_counter() - t0
    return np.asarray(losses), dt / max(n_steps - warm - 1, 1)


def run():
    adj, labels, cfg, state = _setup()
    step_fn = make_gnn_train_step(cfg)
    f1, f2 = cfg.fanouts
    naive_rows = BATCH * (1 + f1 + f1 * f2)

    # -- 1. decoded rows per batch: naive vs unique frontier ------------
    src = _source(adj, labels, cfg, dedup=True)
    uniq, padded = [], []
    for _ in range(steps(20)):
        fb = src.next_batch()["frontier"]
        uniq.append(int(fb.n_unique))
        padded.append(fb.unique.shape[0])
    emit("sampler_pipeline/decode_rows", float(np.mean(padded)),
         f"naive={naive_rows} unique={np.mean(uniq):.0f} "
         f"dup_factor={naive_rows / np.mean(padded):.2f}x")

    # -- 2. steps/sec: sync vs prefetched sampling ----------------------
    # Context for reading the delta: prefetch hides host sampling time behind
    # the device step, so the ceiling is sample_ms / (sample_ms + step_ms).
    # On a CPU backend XLA already saturates the cores during the step, so
    # the overlap win shrinks to ~breakeven; on an accelerator the host is
    # idle during the step and the full sampling time is recovered.
    t0 = time.perf_counter()
    probe = _source(adj, labels, cfg, dedup=True)
    for _ in range(steps(20)):
        probe.next_batch()
    emit("sampler_pipeline/host_sample", (time.perf_counter() - t0) / steps(20) * 1e6,
         "host-side numpy sampling per batch")

    sync_src = _source(adj, labels, cfg, dedup=True)
    _, t_sync = _run(step_fn, state, sync_src, steps(STEPS))
    pf = PrefetchIterator(_source(adj, labels, cfg, dedup=True), depth=2)
    try:
        _, t_pf = _run(step_fn, state, pf, steps(STEPS))
    finally:
        pf.close()
    emit("sampler_pipeline/step_sync", t_sync * 1e6,
         f"steps_per_sec={1.0 / t_sync:.1f}")
    emit("sampler_pipeline/step_prefetch", t_pf * 1e6,
         f"steps_per_sec={1.0 / t_pf:.1f} speedup={t_sync / t_pf:.2f}x")

    # -- 3. loss-trajectory parity: engine vs pre-refactor naive path ---
    # The forward pass is bit-identical (tests/test_engine.py); under
    # training the two paths reduce gradients in different orders (dedup
    # scatter-adds into unique rows), so trajectories track within float32
    # accumulation noise rather than exactly.
    losses_dedup, _ = _run(step_fn, state, _source(adj, labels, cfg, True), steps(30))
    losses_naive, _ = _run(step_fn, state, _source(adj, labels, cfg, False), steps(30))
    gaps = np.abs(losses_dedup - losses_naive)
    emit("sampler_pipeline/loss_parity", float(gaps.max()) * 1e6,
         f"max_abs_loss_gap={gaps.max():.3e} early_gap={gaps[:10].max():.3e} "
         f"final_loss={losses_dedup[-1]:.4f}")
    assert gaps[:10].max() < 1e-3, \
        f"dedup trajectory diverged early from naive path: {gaps[:10].max()}"
    assert gaps.max() < 1e-1, \
        f"dedup trajectory diverged from naive path: {gaps.max()}"
