"""Shared benchmark utilities: timing, k-means + NMI (no sklearn offline),
CSV row emission in the required ``name,us_per_call,derived`` format."""

from __future__ import annotations

import time
from typing import Callable, List, Optional, Tuple

import jax
import numpy as np

ROWS: List[Tuple[str, float, str]] = []

# --smoke (benchmarks.run): every benchmark runs ~2 steps so the suite
# exercises each module's full code path in seconds.  Numbers emitted in
# smoke mode are NOT measurements — the mode exists so benchmarks can't
# silently rot between perf runs.
SMOKE = False


def steps(n: int, smoke_n: int = 2) -> int:
    """Loop-count helper: the requested count, or ``smoke_n`` under --smoke."""
    return min(n, smoke_n) if SMOKE else n


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


BENCH_MODES = ("native", "interpret")


def bench_entry(name: str, *, mode: str, dtype: str, **fields) -> dict:
    """Canonical BENCH_*.json record.  Every entry MUST carry its execution
    ``mode`` ("native" = the real backend, "interpret" = pallas interpret /
    CPU semantics check — NOT a perf measurement) and the decode ``dtype``
    ("float32" / "bfloat16" / "int8"), so a number can never be read
    without the context that decides whether it means anything.  Writers
    build entries through this helper; tools/ci.sh --bench asserts the keys
    on the committed artifacts."""
    if mode not in BENCH_MODES:
        raise ValueError(f"bench entry {name!r}: mode must be one of "
                         f"{BENCH_MODES}, got {mode!r}")
    if not dtype or not isinstance(dtype, str):
        raise ValueError(f"bench entry {name!r}: missing dtype")
    return {"name": name, "mode": mode, "dtype": dtype, **fields}


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in microseconds (blocks on outputs)."""
    if SMOKE:
        iters, warmup = 1, 1
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


# ---------------- tiny kmeans + NMI (paper §B.1.4 evaluation) --------------

def kmeans(x: np.ndarray, k: int, iters: int = 30, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = x[rng.choice(x.shape[0], k, replace=False)].copy()
    assign = np.zeros(x.shape[0], np.int64)
    for _ in range(iters):
        d = ((x[:, None, :] - centers[None]) ** 2).sum(-1)
        new_assign = d.argmin(1)
        if (new_assign == assign).all():
            break
        assign = new_assign
        for j in range(k):
            pts = x[assign == j]
            if len(pts):
                centers[j] = pts.mean(0)
    return assign


def nmi(a: np.ndarray, b: np.ndarray) -> float:
    """Normalized mutual information (sqrt normalisation)."""
    a = np.asarray(a)
    b = np.asarray(b)
    n = a.shape[0]
    ua, ub = np.unique(a), np.unique(b)
    cont = np.zeros((len(ua), len(ub)))
    for i, x in enumerate(ua):
        for j, y in enumerate(ub):
            cont[i, j] = np.sum((a == x) & (b == y))
    p = cont / n
    pa = p.sum(1, keepdims=True)
    pb = p.sum(0, keepdims=True)
    with np.errstate(divide="ignore", invalid="ignore"):
        mi = np.nansum(p * np.log(p / (pa @ pb)))
        ha = -np.nansum(pa * np.log(pa))
        hb = -np.nansum(pb * np.log(pb))
    return float(mi / max(np.sqrt(ha * hb), 1e-12))
