"""Paper Table 1 — end-to-end GNN training with NC / Rand / Hash embeddings.

Four GNNs (GraphSAGE minibatched; GCN/SGC/GIN full-graph) on a synthetic
power-law community graph: node classification accuracy, plus GraphSAGE
link prediction hits@50 on an SBM graph.  Claims reproduced: Hash > Rand in
(almost) all cells; Hash close to NC.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, steps
from repro.configs.paper_gnn import paper_gnn_config
from repro.core import lsh
from repro.graph import NeighborSampler, powerlaw_graph
from repro.graph.engine import FullGraphBatch, GNNModel
from repro.graph.generate import holdout_edges, train_val_test_split
from repro.models import gnn
from repro.optim import AdamWConfig, adamw_init, adamw_update

N_NODES = 4000
N_CLASSES = 8
KEY = jax.random.PRNGKey(0)
KINDS = ("dense", "random_full", "hash_full")
LABEL = {"dense": "NC", "random_full": "Rand", "hash_full": "Hash"}


def _cfg(model, kind):
    cfg = paper_gnn_config(model, n_nodes=N_NODES, n_classes=N_CLASSES, kind=kind)
    return dataclasses.replace(
        cfg, embedding=dataclasses.replace(cfg.embedding, c=16, m=8, d_c=64, d_m=64))


def _codes(kind, adj):
    if kind == "hash_full":
        return lsh.encode_lsh(KEY, adj, 16, 8)
    if kind == "random_full":
        return lsh.encode_random(KEY, N_NODES, 16, 8)
    return None


def run():
    adj, labels = powerlaw_graph(0, N_NODES, avg_degree=10, n_classes=N_CLASSES,
                                 homophily=0.9)
    adjn = adj.with_self_loops().normalized("sym")
    tr, va, te = train_val_test_split(0, N_NODES)
    labels_j = jnp.asarray(labels)
    ocfg = AdamWConfig(lr=1e-2, weight_decay=0.0)   # paper §C.1

    # ---- full-graph models (unified GNNModel API, full-graph handle) ----
    fg = FullGraphBatch(adjn)
    for model_name in ("gcn", "sgc", "gin"):
        for kind in KINDS:
            cfg = _cfg(model_name, kind)
            model = GNNModel(cfg)
            p = model.init(KEY, codes=_codes(kind, adj))
            st = adamw_init(p)

            @jax.jit
            def step(p, st):
                def loss_fn(p):
                    h = model.apply(p, fg)
                    return gnn.node_loss(model.logits(p, h)[jnp.asarray(tr)],
                                         labels_j[jnp.asarray(tr)])
                loss, g = jax.value_and_grad(loss_fn, allow_int=True)(p)
                p, st = adamw_update(p, g, st, ocfg)
                return p, st, loss

            t0 = time.time()
            best_va, best_te = 0.0, 0.0
            n_steps = steps(80)
            for i in range(n_steps):
                p, st, loss = step(p, st)
                # paper: report test acc @ best val acc (always eval the
                # final step so --smoke still exercises the eval path)
                if (i + 1) % 20 == 0 or i == n_steps - 1:
                    lg = model.logits(p, model.apply(p, fg))
                    va_acc = gnn.accuracy(lg[jnp.asarray(va)], labels[va])
                    if va_acc >= best_va:
                        best_va = va_acc
                        best_te = gnn.accuracy(lg[jnp.asarray(te)], labels[te])
            emit(f"table1/{model_name}/{LABEL[kind]}", (time.time() - t0) / steps(80) * 1e6,
                 f"acc={best_te:.4f}")

    # ---- GraphSAGE (minibatched, dedup-decode frontiers) ----
    for kind in KINDS:
        cfg = _cfg("sage", kind)
        model = GNNModel(cfg)
        p = model.init(KEY, codes=_codes(kind, adj))
        sampler = NeighborSampler(adj, cfg.fanouts, max_deg=32, seed=0)
        st = adamw_init(p)

        @jax.jit
        def sstep(p, st, fb, y):
            def loss_fn(p):
                h = model.apply(p, fb)
                return gnn.node_loss(model.logits(p, h), y)
            loss, g = jax.value_and_grad(loss_fn, allow_int=True)(p)
            p, st = adamw_update(p, g, st, ocfg)
            return p, st, loss

        t0 = time.time()
        nsteps = 0
        for epoch in range(steps(3, 1)):
            for fb, batch in sampler.frontier_minibatches(tr, 256):
                if nsteps >= steps(10**9):
                    break
                p, st, _ = sstep(p, st, jax.device_put(fb),
                                 labels_j[jnp.asarray(batch)])
                nsteps += 1
        fb, batch = next(sampler.frontier_minibatches(te, 800, shuffle=False))
        h = model.apply(p, jax.device_put(fb))
        acc = gnn.accuracy(model.logits(p, h), labels[batch])
        emit(f"table1/sage/{LABEL[kind]}", (time.time() - t0) / nsteps * 1e6,
             f"acc={acc:.4f}")

    # ---- link prediction (GCN embeddings, hits@50) ----
    train_adj, pos_eval = holdout_edges(0, adj, 0.1)
    adjn_l = train_adj.with_self_loops().normalized("sym")
    rng = np.random.default_rng(0)
    rid = np.asarray(train_adj.row_ids())
    cid = np.asarray(train_adj.indices)
    fg_l = FullGraphBatch(adjn_l)
    for kind in KINDS:
        cfg = dataclasses.replace(_cfg("gcn", kind), task="link")
        model = GNNModel(cfg)
        p = model.init(KEY, codes=_codes(kind, adj))
        st = adamw_init(p)

        @jax.jit
        def lstep(p, st, pos, neg):
            def loss_fn(p):
                h = model.apply(p, fg_l)
                return gnn.link_loss(h, pos, neg)
            loss, g = jax.value_and_grad(loss_fn, allow_int=True)(p)
            p, st = adamw_update(p, g, st, ocfg)
            return p, st, loss

        t0 = time.time()
        for i in range(steps(60)):
            sel = rng.integers(0, rid.shape[0], 512)
            pos = jnp.stack([jnp.asarray(rid[sel]), jnp.asarray(cid[sel])], 1)
            neg = jnp.asarray(rng.integers(0, N_NODES, (512, 2)))
            p, st, _ = lstep(p, st, pos, neg)
        h = model.apply(p, fg_l)
        neg_eval = rng.integers(0, N_NODES, pos_eval.shape)
        hits = gnn.hits_at_k(gnn.link_scores(h, jnp.asarray(pos_eval)),
                             gnn.link_scores(h, jnp.asarray(neg_eval)), 50)
        emit(f"table1/link-gcn/{LABEL[kind]}", (time.time() - t0) / steps(60) * 1e6,
             f"hits@50={hits:.4f}")
