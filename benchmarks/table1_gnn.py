"""Paper Table 1 — end-to-end GNN training with NC / Rand / Hash embeddings.

Four GNNs (GraphSAGE minibatched; GCN/SGC/GIN full-graph) on a synthetic
power-law community graph: node classification accuracy, plus GraphSAGE
link prediction hits@50 on an SBM graph.  Claims reproduced: Hash > Rand in
(almost) all cells; Hash close to NC.

Every node-classification cell runs through ``GraphRuntime`` (ISSUE 4):
one spec per (model, kind), training via ``rt.train`` chunks and accuracy
via ``rt.evaluate("val"/"test")`` — the paper protocol (test acc at best
val acc) with no ad-hoc eval loops.  Link prediction (task="link") keeps
its bespoke loop pending a link-pred runtime path.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, steps
from repro.configs.paper_gnn import paper_gnn_config
from repro.graph.engine import FullGraphBatch, GNNModel
from repro.graph.generate import holdout_edges
from repro.graph.runtime import GraphRuntime, GraphSource, RuntimeSpec
from repro.models import gnn
from repro.optim import AdamWConfig, adamw_init, adamw_update

N_NODES = 4000
N_CLASSES = 8
KEY = jax.random.PRNGKey(0)
KINDS = ("dense", "random_full", "hash_full")
LABEL = {"dense": "NC", "random_full": "Rand", "hash_full": "Hash"}
GRAPH_SRC = GraphSource(kind="powerlaw", seed=0, n_nodes=N_NODES,
                        n_classes=N_CLASSES, avg_degree=10, homophily=0.9)


def _cfg(model, kind):
    cfg = paper_gnn_config(model, n_nodes=N_NODES, n_classes=N_CLASSES, kind=kind)
    return dataclasses.replace(
        cfg, embedding=dataclasses.replace(cfg.embedding, c=16, m=8, d_c=64, d_m=64))


def _codes(kind, adj):
    from repro.core import lsh
    if kind == "hash_full":
        return lsh.encode_lsh(KEY, adj, 16, 8)
    if kind == "random_full":
        return lsh.encode_random(KEY, N_NODES, 16, 8)
    return None


def run():
    graph = GRAPH_SRC.build()
    adj, labels = graph
    ocfg = AdamWConfig(lr=1e-2, weight_decay=0.0)   # paper §C.1

    # ---- node classification: one runtime spec per (model, kind) cell ----
    # paper protocol: train in chunks, model-select on val, report test acc
    for model_name in ("gcn", "sgc", "gin", "sage"):
        for kind in KINDS:
            spec = RuntimeSpec(graph=GRAPH_SRC, model=_cfg(model_name, kind),
                               optimizer=ocfg, batch_size=256,
                               prefetch_depth=0, max_deg=32)
            rt = GraphRuntime.from_spec(spec, graph=graph)
            n_steps = steps(80)
            chunk = max(min(20, n_steps), 1)
            t0 = time.time()
            best_va, best_te = 0.0, 0.0
            done = 0
            while done < n_steps:
                rt.train(min(chunk, n_steps - done))
                done += min(chunk, n_steps - done)
                va_acc = rt.evaluate("val")["accuracy"]
                if va_acc >= best_va:
                    best_va = va_acc
                    best_te = rt.evaluate("test")["accuracy"]
            rt.close()
            emit(f"table1/{model_name}/{LABEL[kind]}",
                 (time.time() - t0) / n_steps * 1e6, f"acc={best_te:.4f}")

    # ---- link prediction (GCN embeddings, hits@50) ----
    train_adj, pos_eval = holdout_edges(0, adj, 0.1)
    adjn_l = train_adj.with_self_loops().normalized("sym")
    rng = np.random.default_rng(0)
    rid = np.asarray(train_adj.row_ids())
    cid = np.asarray(train_adj.indices)
    fg_l = FullGraphBatch(adjn_l)
    for kind in KINDS:
        cfg = dataclasses.replace(_cfg("gcn", kind), task="link")
        model = GNNModel(cfg)
        p = model.init(KEY, codes=_codes(kind, adj))
        st = adamw_init(p)

        @jax.jit
        def lstep(p, st, pos, neg):
            def loss_fn(p):
                h = model.apply(p, fg_l)
                return gnn.link_loss(h, pos, neg)
            loss, g = jax.value_and_grad(loss_fn, allow_int=True)(p)
            p, st = adamw_update(p, g, st, ocfg)
            return p, st, loss

        t0 = time.time()
        for i in range(steps(60)):
            sel = rng.integers(0, rid.shape[0], 512)
            pos = jnp.stack([jnp.asarray(rid[sel]), jnp.asarray(cid[sel])], 1)
            neg = jnp.asarray(rng.integers(0, N_NODES, (512, 2)))
            p, st, _ = lstep(p, st, pos, neg)
        h = model.apply(p, fg_l)
        neg_eval = rng.integers(0, N_NODES, pos_eval.shape)
        hits = gnn.hits_at_k(gnn.link_scores(h, jnp.asarray(pos_eval)),
                             gnn.link_scores(h, jnp.asarray(neg_eval)), 50)
        emit(f"table1/link-gcn/{LABEL[kind]}", (time.time() - t0) / steps(60) * 1e6,
             f"hits@50={hits:.4f}")
