"""Zipfian concurrent-load benchmark for the serving tier (ISSUE 7).

Drives the SAME pre-generated request stream through two serving legs:

  * ``sequential``  the bare ``GraphInferenceEngine``, one request at a
                    time — the PR-4 serving story (per-request dedup +
                    shared hot cache + miss-only decode);
  * ``batched``     N closed-loop client threads submitting concurrently
                    through ``ServingBatcher`` — microbatch coalescing
                    adds the third dedup tier (cross-request union of
                    misses decodes once per microbatch).

Requests are Zipf(``ZIPF_EXPONENT``)-skewed over a seeded permutation of
the node ids — the power-law access pattern the paper's compression
targets — so concurrent requests share hub nodes and cross-request dedup
has something to collapse.  Both legs warm up on a separate stream and
``reset()`` before measuring, so the reported window is steady state (the
compile bill stays visible as ``compile_count``).

Emits the usual CSV rows AND writes ``BENCH_serving.json`` (never under
--smoke): p50/p95/p99 client-observed latency, sustained QPS, and
rows-decoded-per-request per leg, plus ``bitwise_equal_at_staleness0`` —
every batched response is compared bitwise against the sequential leg's
response for the same request (content-keyed frontiers + row-pure decode
make coalescing invisible to clients).  ``tools/ci.sh --bench`` gates the
committed artifact: mode+dtype on every entry, batched strictly fewer
rows per request than sequential, bitwise flag true.
"""

from __future__ import annotations

import json
import threading
import time
from pathlib import Path

import numpy as np

from benchmarks import common
from benchmarks.common import bench_entry, emit, steps
from repro.configs.paper_gnn import paper_gnn_config
from repro.graph.runtime import GraphRuntime, GraphSource, RuntimeSpec
from repro.optim import AdamWConfig
from repro.serving import BatchingSpec, ServingBatcher

N_NODES = 8000
N_CLASSES = 8
SERVE_BATCH = 256
ZIPF_EXPONENT = 1.1
N_CLIENTS = 8
MAX_BATCH = 8
# deliberately smaller than the graph (the engine default would cover all
# 8000 nodes here): the Zipf head lives in the cache and the TAIL keeps
# missing, so the benchmark separates what the hot cache absorbs from what
# cross-request dedup collapses
CACHE_CAPACITY = 2048

OUT_PATH = Path(__file__).resolve().parents[1] / "BENCH_serving.json"


def zipfian_requests(n_req: int, seed: int):
    """``n_req`` request batches of ``SERVE_BATCH`` node ids drawn from a
    Zipf(``ZIPF_EXPONENT``) distribution over a seeded permutation of the
    graph — rank 1 is a random hub, not node 0, so the skew doesn't alias
    the generator's id layout."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, N_NODES + 1, dtype=np.float64)
    p = ranks ** -ZIPF_EXPONENT
    p /= p.sum()
    perm = rng.permutation(N_NODES).astype(np.int32)
    return [perm[rng.choice(N_NODES, size=SERVE_BATCH, p=p)]
            for _ in range(n_req)]


def _warmed(engine, warmup_stream):
    for req in warmup_stream:
        engine.serve(req)
    engine.reset()
    return engine


def _sequential_leg(engine, requests):
    lat, results = [], []
    t0 = time.perf_counter()
    for req in requests:
        t = time.perf_counter()
        results.append(engine.serve(req))
        lat.append(time.perf_counter() - t)
    elapsed = time.perf_counter() - t0
    return np.asarray(lat), elapsed, results


def _batched_leg(batcher, requests, n_clients: int):
    """Closed-loop clients: each thread serves its round-robin share of the
    stream, one outstanding request at a time, all released together."""
    lat = np.zeros(len(requests))
    results = [None] * len(requests)
    barrier = threading.Barrier(n_clients + 1)

    def client(cid: int):
        barrier.wait()
        for i in range(cid, len(requests), n_clients):
            t = time.perf_counter()
            results[i] = batcher.serve(requests[i])
            lat[i] = time.perf_counter() - t

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(n_clients)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    elapsed = time.perf_counter() - t0
    return lat, elapsed, results


def _leg_entry(name: str, lat_s, elapsed: float, stats, dtype: str) -> dict:
    lat_us = np.asarray(lat_s) * 1e6
    return bench_entry(
        name, mode="native", dtype=dtype,
        p50_us=float(np.percentile(lat_us, 50)),
        p95_us=float(np.percentile(lat_us, 95)),
        p99_us=float(np.percentile(lat_us, 99)),
        qps=len(lat_us) / max(elapsed, 1e-9),
        requests=len(lat_us),
        rows_decoded_per_request=stats["rows_decoded_per_request"],
        hit_rate=stats.get("hit_rate", 0.0),
        compile_count=stats["compile_count"])


def run():
    spec = RuntimeSpec(
        graph=GraphSource(kind="powerlaw", seed=0, n_nodes=N_NODES,
                          n_classes=N_CLASSES, avg_degree=10, homophily=0.9),
        model=paper_gnn_config("sage", n_nodes=N_NODES, n_classes=N_CLASSES,
                               kind="hash_full", fanout=10),
        optimizer=AdamWConfig(lr=1e-2, weight_decay=0.0),
        batch_size=256, data_seed=1, prefetch_depth=2,
    ).with_updates(c=16, m=8, d_c=128, d_m=64)
    dtype = spec.model.embedding_config().compute_dtype

    rt = GraphRuntime.from_spec(spec)
    rt.train(steps(30))

    n_req = steps(96, smoke_n=4)
    n_clients = min(N_CLIENTS, n_req)
    requests = zipfian_requests(n_req, seed=23)
    warmup = zipfian_requests(steps(12), seed=24)

    # -- sequential leg ---------------------------------------------------
    seq_engine = _warmed(
        rt.serve(serve_batch=SERVE_BATCH, cache_capacity=CACHE_CAPACITY),
        warmup)
    seq_lat, seq_elapsed, seq_results = _sequential_leg(seq_engine, requests)
    seq_stats = seq_engine.stats()
    seq = _leg_entry("serving_load/sequential", seq_lat, seq_elapsed,
                     seq_stats, dtype)
    emit("serving_load/sequential/p50", seq["p50_us"],
         f"p99={seq['p99_us']:.0f}us qps={seq['qps']:.1f} "
         f"rows/req={seq['rows_decoded_per_request']:.0f} "
         f"hit_rate={seq['hit_rate']:.2f}")

    # -- batched leg (fresh engine, identical construction) ---------------
    bat_engine = _warmed(
        rt.serve(serve_batch=SERVE_BATCH, cache_capacity=CACHE_CAPACITY,
                 max_coalesce=MAX_BATCH), warmup)
    bspec = BatchingSpec(max_batch=min(MAX_BATCH, n_clients),
                         max_delay_ms=2.0, queue_depth=64)
    with ServingBatcher(bat_engine, bspec) as batcher:
        # warm the coalesced request-bucket shapes too (they only exist
        # under concurrency), then reopen the measured window
        _batched_leg(batcher, warmup, n_clients)
        bat_engine.reset()
        bat_lat, bat_elapsed, bat_results = _batched_leg(
            batcher, requests, n_clients)
        bat_stats = bat_engine.stats()
        coalesce = batcher.stats()
    bat = _leg_entry("serving_load/batched", bat_lat, bat_elapsed,
                     bat_stats, dtype)
    bat["mean_coalesced"] = coalesce["mean_coalesced"]
    emit("serving_load/batched/p50", bat["p50_us"],
         f"p99={bat['p99_us']:.0f}us qps={bat['qps']:.1f} "
         f"rows/req={bat['rows_decoded_per_request']:.0f} "
         f"hit_rate={bat['hit_rate']:.2f} "
         f"coalesce={coalesce['mean_coalesced']:.1f}")
    rt.close()

    # -- matched correctness: batched bitwise == sequential ---------------
    for i, (s, b) in enumerate(zip(seq_results, bat_results)):
        if not (np.array_equal(s.embeddings, b.embeddings)
                and np.array_equal(s.logits, b.logits)):
            raise AssertionError(
                f"request {i}: batched response != sequential (staleness-0 "
                f"serving must be bitwise ordering-independent)")
    emit("serving_load/bitwise_equal", 0.0,
         f"all {n_req} batched responses bitwise == sequential")

    if common.SMOKE:
        # 4 requests of coalescing is a code-path check, not a measurement
        # or a dedup guarantee; never overwrite the committed datapoint
        print(f"# smoke: skipping {OUT_PATH.name} write")
        return

    if not (bat["rows_decoded_per_request"]
            < seq["rows_decoded_per_request"]):
        raise AssertionError(
            f"cross-request dedup must decode strictly fewer rows/request: "
            f"batched {bat['rows_decoded_per_request']:.0f} >= sequential "
            f"{seq['rows_decoded_per_request']:.0f}")

    report = {
        "workload": {
            "n_nodes": N_NODES, "serve_batch": SERVE_BATCH,
            "zipf_exponent": ZIPF_EXPONENT, "n_requests": n_req,
            "n_clients": n_clients, "max_batch": bspec.max_batch,
            "max_delay_ms": bspec.max_delay_ms,
            "fanout": list(spec.model.fanouts),
            "cache_capacity": seq_engine.cache_capacity,
        },
        "bitwise_equal_at_staleness0": True,
        "runs": {"sequential": seq, "batched": bat},
    }
    OUT_PATH.write_text(json.dumps(report, indent=2) + "\n")
    print(f"# wrote {OUT_PATH.name}")


if __name__ == "__main__":
    run()
