"""Paper Figure 1 — pre-trained embedding reconstruction proxy.

Compares coding schemes at growing entity counts: random (ALONE), hashing
(the paper, from pre-trained embeddings AND from the graph adjacency), and
learning-based (autoencoder).  Offline stand-in for metapath2vec: Gaussian-
mixture embeddings with planted clusters on a matching synthetic graph;
quality = NMI of k-means on the reconstructed embeddings (paper §B.1.4) —
evaluated on the same fixed 2,000-entity subset across entity counts,
mirroring the paper's fixed top-5k evaluation protocol.

Expected orderings (the paper's claims): hashing ≈ learn >> random at large
n; hashing/graph ≈ hashing/pre-trained.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import steps, emit, kmeans, nmi
from repro.configs.paper_gnn import paper_gnn_config
from repro.core import lsh
from repro.core.autoencoder import AutoencoderConfig, extract_codes, train_autoencoder
from repro.core.decoder import DecoderConfig
from repro.core.embedding import EmbeddingConfig, decode_all, init_embedding
from repro.graph.generate import clustered_embeddings, sbm_graph
from repro.optim import AdamWConfig, adamw_init, adamw_update

C, M = 16, 16        # reduced (c, m) for CPU-scale runs
D_C = D_M = 128
N_CLUSTERS = 8
DIM = 64
EVAL_N = 2000
TRAIN_STEPS = 300


def _train_decoder_on_reconstruction(key, emb_target, codes, n_steps=None):
    n, d_e = emb_target.shape
    cfg = EmbeddingConfig(kind="random_full", n_entities=n, d_e=d_e, c=C, m=M,
                          d_c=D_C, d_m=D_M, compute_dtype="float32")
    params = init_embedding(key, cfg, codes=codes)
    st = adamw_init(params)
    ocfg = AdamWConfig(lr=1e-3, weight_decay=0.01)   # paper §B.2 defaults

    @jax.jit
    def step(p, st, ids, tgt):
        def loss_fn(p):
            from repro.core.embedding import embed_lookup
            return jnp.mean((embed_lookup(p, ids, cfg) - tgt) ** 2)
        loss, g = jax.value_and_grad(loss_fn, allow_int=True)(p)
        p, st = adamw_update(p, g, st, ocfg)
        return p, st, loss

    kb = jax.random.PRNGKey(1)
    for i in range(n_steps if n_steps is not None else steps(TRAIN_STEPS)):
        ids = jax.random.randint(jax.random.fold_in(kb, i), (512,), 0, n)
        params, st, loss = step(params, st, ids, emb_target[ids])
    return params, cfg, float(loss)


def run():
    key = jax.random.PRNGKey(0)
    for n_entities in (2000, 4000, 8000):
        emb, labels = clustered_embeddings(0, n_entities, DIM, N_CLUSTERS, noise=0.35)
        # the adjacency encodes the SAME latent communities as the embeddings
        adj, _ = sbm_graph(1, n_entities, n_classes=N_CLUSTERS,
                           p_in=0.04, p_out=0.002, labels=labels)
        embj = jnp.asarray(emb)

        raw_nmi = nmi(kmeans(emb[:EVAL_N], N_CLUSTERS), labels[:EVAL_N])
        emit(f"fig1/raw/n{n_entities}", 0.0, f"nmi={raw_nmi:.4f}")

        schemes = {
            "random": lsh.encode_random(key, n_entities, C, M),
            "hashing_pretrained": lsh.encode_lsh(key, embj, C, M),
            "hashing_graph": lsh.encode_lsh(key, adj, C, M),
            # beyond-paper: §6.1's higher-order-adjacency suggestion
            "hashing_graph2": lsh.encode_lsh(key, adj, C, M, hops=2),
        }
        for name, codes in schemes.items():
            t0 = time.time()
            params, cfg, loss = _train_decoder_on_reconstruction(key, embj, codes)
            rec = np.asarray(decode_all(params, cfg))
            q = nmi(kmeans(rec[:EVAL_N], N_CLUSTERS), labels[:EVAL_N])
            emit(f"fig1/{name}/n{n_entities}",
                 (time.time() - t0) / steps(TRAIN_STEPS) * 1e6,
                 f"nmi={q:.4f};mse={loss:.5f}")

        # learning-based coding (autoencoder, Shu & Nakayama)
        t0 = time.time()
        acfg = AutoencoderConfig(
            d_in=DIM, c=C, m=M, d_h=D_C,
            decoder=DecoderConfig(c=C, m=M, d_c=D_C, d_m=D_M, d_e=DIM,
                                  compute_dtype="float32"))
        ae_params, ae_loss = train_autoencoder(key, embj, acfg, steps=steps(TRAIN_STEPS))
        codes = extract_codes(ae_params, embj, acfg)
        params, cfg, loss = _train_decoder_on_reconstruction(key, embj, codes)
        rec = np.asarray(decode_all(params, cfg))
        q = nmi(kmeans(rec[:EVAL_N], N_CLUSTERS), labels[:EVAL_N])
        emit(f"fig1/learn/n{n_entities}",
             (time.time() - t0) / (2 * steps(TRAIN_STEPS)) * 1e6,
             f"nmi={q:.4f};mse={loss:.5f}")
