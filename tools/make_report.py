"""Regenerate the EXPERIMENTS.md §Roofline tables from dry-run artifacts.

Usage: PYTHONPATH=src python tools/make_report.py [results/dryrun_v2]
                                                  [results/dryrun_final]
Prints markdown: one row per (arch × shape × mesh) with the three terms,
dominant bottleneck, roofline fraction, usefulness ratio, and per-device
memory — the §Roofline tables are generated from this.
"""

import glob
import json
import os
import sys


def load(d):
    rows = {}
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(p))
        key = (r["arch"], r["shape"], r["mesh"])
        rows[key] = r
    return rows


def table(d, title):
    rows = load(d)
    print(f"\n### {title} ({d})\n")
    print("| arch | shape | mesh | c (s) | m (s) | x (s) | dom | frac | useful | GiB |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for (arch, shape, mesh), r in sorted(rows.items()):
        if r["status"] == "skipped":
            print(f"| {arch} | {shape} | {mesh} | — | — | — | skip (sub-quadratic required) | — | — | — |")
            continue
        if r["status"] != "ok":
            print(f"| {arch} | {shape} | {mesh} | FAILED | | | | | | |")
            continue
        ro = r["roofline"]
        print(f"| {arch} | {shape} | {mesh} | {ro['compute_s']:.4f} | "
              f"{ro['memory_s']:.4f} | {ro['collective_s']:.4f} | "
              f"{ro['dominant']} | {ro['roofline_fraction']:.3f} | "
              f"{ro['useful_ratio']:.2f} | {r['memory']['peak_est_gib']:.1f} |")


if __name__ == "__main__":
    dirs = sys.argv[1:] or ["results/dryrun_v2", "results/dryrun_final"]
    for i, d in enumerate(dirs):
        if os.path.isdir(d):
            table(d, "baseline policy" if i == 0 else "optimized profiles")
