#!/usr/bin/env python
"""Docs drift gate (ISSUE 8 satellite): the docs layer can't silently rot.

The names a user reaches for — every decode backend in the
``core.backend`` registry, every ``RuntimeSpec`` pipeline knob, every
``EmbeddingSpec`` compression field — must each appear somewhere in
``docs/*.md``.  The required set is derived from the LIVE code
(``available_backends()`` + ``dataclasses.fields``), so adding a backend or
a spec field without documenting it fails this gate; conversely a doc
refresh can't claim coverage it doesn't have.

Matching is word-boundary regex over the concatenated docs, so ``c`` the
field must appear as the standalone token ``c`` (it does, in the field
tables), not merely inside other words.

Usage:  python tools/check_docs.py
Exit 0 = every required name documented.  Wired into the tools/ci.sh
import-gate leg; tests/test_docs_gate.py asserts both directions.
"""

from __future__ import annotations

import dataclasses
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DOCS = ROOT / "docs"


def required_names() -> dict:
    """Name -> provenance, derived from the live registry and spec types."""
    sys.path.insert(0, str(ROOT / "src"))
    from repro.configs.base import EmbeddingSpec
    from repro.core.backend import available_backends
    from repro.elastic.manager import ElasticSpec
    from repro.graph.runtime import RuntimeSpec

    req = {}
    for name in available_backends():
        req[name] = "core.backend registry"
    for f in dataclasses.fields(RuntimeSpec):
        req[f.name] = "graph.runtime.RuntimeSpec field"
    for f in dataclasses.fields(EmbeddingSpec):
        req[f.name] = "configs.base.EmbeddingSpec field"
    for f in dataclasses.fields(ElasticSpec):
        req[f.name] = "elastic.manager.ElasticSpec field"
    return req


def docs_text(docs_dir: Path = DOCS) -> str:
    pages = sorted(docs_dir.glob("*.md"))
    if not pages:
        raise SystemExit(f"check_docs: no markdown pages under {docs_dir}")
    return "\n".join(p.read_text() for p in pages)


def missing_names(text: str, required=None) -> dict:
    """Subset of ``required`` absent (word-boundary) from ``text``."""
    required = required_names() if required is None else required
    return {name: src for name, src in required.items()
            if not re.search(rf"\b{re.escape(name)}\b", text)}


def main(docs_dir: Path = DOCS) -> int:
    required = required_names()
    missing = missing_names(docs_text(docs_dir), required)
    if missing:
        print(f"check_docs: {len(missing)} undocumented name(s) — every "
              f"registry backend and spec field must appear in docs/*.md:",
              file=sys.stderr)
        for name, src in sorted(missing.items()):
            print(f"  {name:24s} ({src})", file=sys.stderr)
        return 1
    print(f"check_docs OK ({len(required)} names covered by "
          f"{len(sorted(docs_dir.glob('*.md')))} pages)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
