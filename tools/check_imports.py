#!/usr/bin/env python
"""Import-health gate (ISSUE 1 satellite): fail fast when a module in the
tree cannot even be imported, so a missing *optional* dependency can never
silently break collection of unrelated test modules again.

Two phases:

  1. import every module under ``src/repro``, plus every ``benchmarks/``
     and ``tools/`` module — all must ALWAYS import (optional deps have to
     be lazy/gated; benchmark/tool entry points may only *run* work behind
     ``main()``/``run()`` guards, never at import time);
  2. ``pytest --collect-only`` over ``tests/`` — test modules needing an
     optional dependency must guard it with ``pytest.importorskip`` (skips
     are fine, collection *errors* are not).

Usage:  python tools/check_imports.py [--src-only]
Exit code 0 = healthy.  Run it before the test suite in any verify path.
"""

from __future__ import annotations

import argparse
import importlib
import subprocess
import sys
import traceback
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = ROOT / "src"


def iter_modules() -> list:
    mods = []
    for py in sorted((SRC / "repro").rglob("*.py")):
        rel = py.relative_to(SRC).with_suffix("")
        parts = list(rel.parts)
        if parts[-1] == "__init__":
            parts = parts[:-1]
        mods.append(".".join(parts))
    return mods


def iter_script_modules() -> list:
    """``benchmarks.*``, ``tools.*`` and ``examples.*`` modules (namespace
    packages rooted at the repo) — the CI runs ``python -m benchmarks.run``
    and the ``--examples`` smoke leg, so a script that stops importing is a
    broken CI leg, not someone else's problem.  Entry points may only *run*
    work behind ``main()`` / ``__main__`` guards, never at import time."""
    mods = []
    for pkg in ("benchmarks", "tools", "examples"):
        for py in sorted((ROOT / pkg).glob("*.py")):
            if py.stem != "__init__":
                mods.append(f"{pkg}.{py.stem}")
    return mods


def check_src_imports() -> int:
    sys.path.insert(0, str(SRC))
    sys.path.insert(0, str(ROOT))     # benchmarks/ + tools/ namespace pkgs
    failures = 0
    src_mods, script_mods = iter_modules(), iter_script_modules()
    for mod in src_mods + script_mods:
        try:
            importlib.import_module(mod)
        except Exception:
            failures += 1
            print(f"FAIL import {mod}")
            traceback.print_exc(limit=3)
    print(f"[check_imports] src: {len(src_mods)} modules + "
          f"{len(script_mods)} benchmark/tool/example modules, "
          f"{failures} import failure(s)")
    return failures


def check_test_collection() -> int:
    import os
    env = {**os.environ, "PYTHONPATH": str(SRC) + (
        ":" + os.environ["PYTHONPATH"] if os.environ.get("PYTHONPATH") else "")}
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "--collect-only", "-q",
         "-p", "no:cacheprovider", "tests"],
        cwd=str(ROOT), env=env, capture_output=True, text=True)
    tail = "\n".join((proc.stdout or "").strip().splitlines()[-5:])
    print(f"[check_imports] pytest --collect-only rc={proc.returncode}\n{tail}")
    if proc.returncode not in (0, 5):   # 5 = no tests collected (empty tree)
        print(proc.stdout)
        print(proc.stderr, file=sys.stderr)
        return 1
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--src-only", action="store_true",
                    help="skip the pytest collection phase (fast gate)")
    args = ap.parse_args()
    failures = check_src_imports()
    if not args.src_only:
        failures += check_test_collection()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
