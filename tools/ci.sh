#!/usr/bin/env bash
# Single CI entry point (ISSUE 2 satellite).
#
#   tools/ci.sh           import gate + tier-1 pytest
#   tools/ci.sh --bench   ... plus the benchmark suite in --smoke mode
#                         (2 steps per benchmark: exercises every module's
#                         code path so benchmarks can't silently rot)
#
# Mirrors ROADMAP "Tier-1 verify": import/collection health is a gate that
# runs BEFORE the suite, so a broken optional dep fails loudly here instead
# of erroring collection of unrelated test modules.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== [1/2] import-health gate =="
python tools/check_imports.py

echo "== [2/2] tier-1 pytest =="
python -m pytest -x -q

if [[ "${1:-}" == "--bench" ]]; then
    echo "== [extra] benchmark smoke =="
    python -m benchmarks.run --smoke
fi

echo "CI OK"
