#!/usr/bin/env bash
# Single CI entry point (ISSUE 2 satellite; multidevice leg from ISSUE 3).
#
#   tools/ci.sh                import gate + docs drift gate (check_docs.py:
#                              every registry backend / spec field must be
#                              documented) + tier-1 pytest
#   tools/ci.sh --bench        ... plus the benchmark suite in --smoke mode
#                              (2 steps per benchmark: exercises every
#                              module's code path so benchmarks can't
#                              silently rot — including the fused per-dtype
#                              decode, which raises if int8/bf16 drift
#                              exceeds DRIFT_BOUNDS, and codes_offload,
#                              which raises unless host placement is
#                              bitwise with flat O(frontier) device code
#                              bytes), and a gate asserting the committed
#                              BENCH_*.json artifacts carry mode + dtype on
#                              every entry (BENCH_offload.json additionally:
#                              host bytes flat and < replicated)
#   tools/ci.sh --bench-only   import gate + benchmark smoke, WITHOUT the
#                              tier-1 pytest — the CI matrix runs tier-1 in
#                              its own leg, so the bench leg shouldn't pay
#                              for the suite twice
#   tools/ci.sh --multidevice  import gate + the `multidevice`-marked tests
#                              under XLA_FLAGS=--xla_force_host_platform_
#                              device_count=8, so sharded code paths see 8
#                              devices on a CPU-only container, plus an
#                              owner-decode GraphRuntime smoke
#                              (lookup_impl="owner:gather", 4 shards, 2
#                              steps).  Runs ONLY the marked tests: the
#                              tier-1 suite must keep its single-device view
#                              (tests/conftest.py).
#   tools/ci.sh --examples     import gate + examples smoke, WITHOUT the
#                              tier-1 pytest: runs the GraphRuntime front
#                              door end to end — `train_gnn_hash.py --steps
#                              2` (train + val/test eval + checkpoint) and a
#                              2-request `GraphInferenceEngine` serve via
#                              `serve_gnn.py` — so the examples can't rot.
#   tools/ci.sh --elastic      import gate + a forced-8-host-device elastic
#                              kill/rescale smoke (FailurePlan kills a shard,
#                              peer transfer + exact rescale recover it,
#                              post-recovery curve asserted bitwise) + a
#                              required-keys gate on the committed
#                              BENCH_elastic.json, WITHOUT the tier-1 pytest.
#
# Mirrors ROADMAP "Tier-1 verify": import/collection health is a gate that
# runs BEFORE the suite, so a broken optional dep fails loudly here instead
# of erroring collection of unrelated test modules.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

RUN_BENCH=0
RUN_MULTI=0
RUN_EXAMPLES=0
RUN_ELASTIC=0
RUN_SUITE=1
for arg in "$@"; do
    case "$arg" in
        --bench)       RUN_BENCH=1 ;;
        --bench-only)  RUN_BENCH=1; RUN_SUITE=0 ;;
        --multidevice) RUN_MULTI=1 ;;
        --examples)    RUN_EXAMPLES=1; RUN_SUITE=0 ;;
        --elastic)     RUN_ELASTIC=1; RUN_SUITE=0 ;;
        *) echo "usage: tools/ci.sh [--bench|--bench-only] [--multidevice] [--examples] [--elastic]" >&2
           exit 2 ;;
    esac
done

echo "== [1/2] import-health + docs drift gate =="
python tools/check_imports.py
python tools/check_docs.py

if [[ "$RUN_MULTI" == 1 ]]; then
    echo "== [2/3] multidevice pytest (8 forced host devices) =="
    XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
        python -m pytest -q -m multidevice
    echo "== [3/3] owner-decode runtime smoke (lookup_impl=owner:gather) =="
    XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
        python - <<'PY'
import math

from repro.configs.paper_gnn import paper_gnn_config
from repro.graph.runtime import GraphRuntime, GraphSource, RuntimeSpec

# n_nodes=1000 + fanout 10 puts the workload firmly in the owner regime:
# the frontier cap rounds to 1024, so owner_unique_cap=512 while any owner
# can own at most 1000/4 = 250 distinct ids — the plan can never overflow
spec = RuntimeSpec(
    graph=GraphSource(kind="powerlaw", seed=0, n_nodes=1000, n_classes=8),
    model=paper_gnn_config("sage", n_nodes=1000, n_classes=8, fanout=10),
    batch_size=64, n_shards=4, total_steps=2, log_every=1,
).with_updates(c=16, m=8, d_c=64, d_m=64, lookup_impl="owner:gather")
rt = GraphRuntime.from_spec(spec)
try:
    batch = rt.data_iter.next_batch()
    assert batch["frontier"].plan is not None, "owner plan missing"
    res = rt.train(2)
    assert all(math.isfinite(l) for l in res.losses), \
        f"non-finite loss: {res.losses}"
    print("owner-decode smoke OK:", res.losses)
finally:
    rt.close()
PY
elif [[ "$RUN_SUITE" == 1 ]]; then
    echo "== [2/2] tier-1 pytest =="
    python -m pytest -x -q
fi

if [[ "$RUN_BENCH" == 1 ]]; then
    echo "== [extra] benchmark smoke =="
    python -m benchmarks.run --smoke
    echo "== [extra] fused-decode precision gate (int8 vs f32 + entry keys) =="
    python - <<'PY'
# The kernels_micro smoke above already ran the fused per-dtype decode and
# raised if int8/bf16 drift exceeded core.backend.DRIFT_BOUNDS or the int8
# codebook byte reduction fell under 3.5x.  This gate additionally pins the
# committed artifacts: every BENCH entry must carry mode + dtype keys
# (benchmarks.common.bench_entry is the only sanctioned writer).
import json
from pathlib import Path

root = Path(".")
checked = 0
for name in ("BENCH_kernels.json", "BENCH_decode.json", "BENCH_shard.json",
              "BENCH_serving.json", "BENCH_compression.json",
              "BENCH_elastic.json", "BENCH_offload.json"):
    path = root / name
    if not path.exists():
        continue
    doc = json.loads(path.read_text())
    if name == "BENCH_kernels.json":
        entries = doc["fused_hash_decode"]["entries"]
        dtypes = {e["dtype"] for e in entries}
        assert {"float32", "bfloat16", "int8"} <= dtypes, dtypes
        red = doc["fused_hash_decode"]["int8_codebook_byte_reduction_vs_f32"]
        assert red >= 3.5, f"int8 byte reduction {red} < 3.5x"
        for e in entries:
            assert e["modeled"]["hbm_bytes"] > 0, e
    elif name == "BENCH_decode.json":
        entries = list(doc["backends"].values())
    elif name == "BENCH_serving.json":
        entries = list(doc["runs"].values())
        for e in entries:
            for key in ("p50_us", "p95_us", "p99_us", "qps",
                        "rows_decoded_per_request"):
                assert isinstance(e.get(key), (int, float)), (name, key, e)
        assert (doc["runs"]["batched"]["rows_decoded_per_request"]
                < doc["runs"]["sequential"]["rows_decoded_per_request"]), (
            "cross-request dedup must decode strictly fewer rows/request")
        assert doc["bitwise_equal_at_staleness0"] is True, doc.keys()
    elif name == "BENCH_compression.json":
        budgets = doc["budgets"]
        assert len(budgets) >= 2, f"need >= 2 matched budgets, got {budgets.keys()}"
        entries = []
        for bname, row in budgets.items():
            fams = row["families"]
            assert set(fams) == {"paper", "hashemb", "tt"}, (bname, fams.keys())
            for e in fams.values():
                for key in ("table_bytes", "val_accuracy", "final_train_loss"):
                    assert isinstance(e.get(key), (int, float)), (bname, key, e)
                entries.append(e)
    elif name == "BENCH_elastic.json":
        # one flat record; the full required-keys gate lives in --elastic
        entries = [doc]
        assert doc.get("post_recovery_bitwise") is True, doc.keys()
    elif name == "BENCH_offload.json":
        # ISSUE 10: host placement must be bitwise AND O(frontier) —
        # flat device code bytes across the sweep, strictly below the
        # replicated baseline, which itself must grow with the graph
        assert doc["bitwise_equal_step0"] is True, doc.keys()
        assert doc["bitwise_equal_after_steps"] is True, doc.keys()
        entries = doc["entries"]
        for e in entries:
            for key in ("device_resident_code_bytes",
                        "transferred_code_bytes_per_batch", "n_nodes"):
                assert isinstance(e.get(key), (int, float)), (name, key, e)
            assert e.get("codes_placement") in ("device", "host"), e
            assert e.get("bitwise_equal_vs_replicated") is True, e
        host = sorted((e["n_nodes"], e["device_resident_code_bytes"])
                      for e in entries if e["codes_placement"] == "host")
        dev = sorted((e["n_nodes"], e["device_resident_code_bytes"])
                     for e in entries if e["codes_placement"] == "device")
        assert host and dev, "need both placements in the sweep"
        assert len({b for _, b in host}) == 1, f"host bytes not flat: {host}"
        assert all(h[1] < d[1] for h, d in zip(host, dev)), (host, dev)
        assert all(b2 > b1 for (_, b1), (_, b2) in zip(dev, dev[1:])), dev
    else:
        entries = [r for r in doc.get("runs", {}).values()
                   if isinstance(r, dict)]
    for e in entries:
        assert e.get("mode") in ("native", "interpret"), (name, e)
        assert isinstance(e.get("dtype"), str) and e["dtype"], (name, e)
        checked += 1
print(f"bench artifact gate OK ({checked} entries carry mode+dtype)")
PY
fi

if [[ "$RUN_ELASTIC" == 1 ]]; then
    echo "== [2/3] elastic kill/rescale smoke (8 forced host devices) =="
    XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
        python - <<'PY'
import dataclasses

from repro.configs.paper_gnn import paper_gnn_config
from repro.elastic import (DEGRADED, HEALTHY, RESCALING, ElasticManager,
                           ElasticSpec, FailurePlan)
from repro.graph.runtime import GraphRuntime, GraphSource, RuntimeSpec

# compressed schedule: shard 2 of 4 dies at step 2 (lease grace 1 -> detect
# at step 3), one transfer chunk arrives corrupted, rescale to 3 shards,
# and the continued curve must be bitwise the never-failed rescaled run
N = 1000
spec = RuntimeSpec(
    graph=GraphSource(kind="powerlaw", seed=0, n_nodes=N, n_classes=8),
    model=paper_gnn_config("sage", n_nodes=N, n_classes=8, fanout=5),
    batch_size=48, n_shards=4, prefetch_depth=2,
    elastic=ElasticSpec(lease_steps=1, chunk_bytes=1 << 16),
).with_updates(c=16, m=8, d_c=64, d_m=64, lookup_impl="sharded:gather")
graph = spec.graph.build()

mgr = ElasticManager(GraphRuntime.from_spec(spec, graph=graph),
                     plan=FailurePlan(kill=((2, 2),), corrupt_chunks=(1,)))
res = mgr.run(6)
assert res.history == [HEALTHY, DEGRADED, RESCALING, HEALTHY], res.history
(rep,) = res.reports
assert rep.n_after == 3 and rep.retransmits >= 1, rep
res.runtime.close()

rt4 = GraphRuntime.from_spec(spec, graph=graph)
head = rt4.train(rep.detected_at_step + 1)
rt3 = rt4.rescale(3)
rt4.close()
tail = rt3.train(6 - rep.detected_at_step - 1)
rt3.close()
assert res.losses == head.losses + tail.losses, "post-recovery curve diverged"
print(f"elastic smoke OK: {rep.n_before}->{rep.n_after} shards, "
      f"steps_lost={rep.steps_lost}, "
      f"bytes_transferred={rep.bytes_transferred}, "
      f"retransmits={rep.retransmits}, bitwise continuation")
PY
    echo "== [3/3] BENCH_elastic.json required-keys gate =="
    python - <<'PY'
import json
from pathlib import Path

doc = json.loads(Path("BENCH_elastic.json").read_text())
# headline columns are steps-lost / bytes-moved, never CPU wall-clock
for key in ("steps_lost", "detected_at_step", "payload_bytes",
            "bytes_transferred", "chunks", "retransmits"):
    assert isinstance(doc.get(key), int), (key, doc.get(key))
assert doc.get("mode") in ("native", "interpret"), doc.get("mode")
assert isinstance(doc.get("dtype"), str) and doc["dtype"], doc.get("dtype")
topo = doc.get("topology")
assert isinstance(topo, dict) and {"before", "after"} <= set(topo), topo
assert doc.get("post_recovery_bitwise") is True, doc.get("post_recovery_bitwise")
assert "recovery_wall_s_cpu" in doc  # present, labelled, non-headline
print("BENCH_elastic.json gate OK")
PY
fi

if [[ "$RUN_EXAMPLES" == 1 ]]; then
    echo "== [2/2] examples smoke (GraphRuntime train/eval/serve) =="
    CKPT_DIR="$(mktemp -d)"
    python examples/train_gnn_hash.py --steps 2 --nodes 2000 --classes 8 \
        --ckpt-dir "$CKPT_DIR"
    rm -rf "$CKPT_DIR"
    python examples/serve_gnn.py --nodes 2000 --steps 2 --requests 2 \
        --batch 64
fi

echo "CI OK"
