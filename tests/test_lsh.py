"""Algorithm 1 behaviour + LSH properties (unit + hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import codes, lsh
from repro.graph.csr import CSRMatrix
from repro.graph.generate import clustered_embeddings


def test_shapes_and_determinism():
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (100, 32))
    p1 = lsh.encode_lsh(key, A, 16, 8)
    p2 = lsh.encode_lsh(key, A, 16, 8)
    assert p1.shape == (100, codes.n_words(16, 8))
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    p3 = lsh.encode_lsh(jax.random.PRNGKey(1), A, 16, 8)
    assert (np.asarray(p1) != np.asarray(p3)).any()


def test_median_threshold_is_balanced():
    """Median binarisation puts (almost) exactly half the entities on each
    side of every hyperplane — the paper's collision-reduction mechanism."""
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (256, 16))
    cds = lsh.encode_lsh_codes(key, A, 2, 32)     # 32 single-bit codes
    ones = np.asarray(cds).sum(axis=0)
    assert (np.abs(ones - 128) <= 1).all()


def test_row_block_invariance():
    key = jax.random.PRNGKey(3)
    A = jax.random.normal(key, (96, 24))
    a = lsh.encode_lsh(key, A, 4, 16, row_block=None)
    b = lsh.encode_lsh(key, A, 4, 16, row_block=32)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sparse_equals_dense():
    rng = np.random.default_rng(0)
    dense = (rng.random((64, 64)) < 0.1).astype(np.float32)
    rows, cols = np.nonzero(dense)
    csr = CSRMatrix.from_coo(rows, cols, np.ones_like(rows, np.float32), (64, 64))
    key = jax.random.PRNGKey(5)
    a = lsh.encode_lsh(key, jnp.asarray(dense), 16, 8)
    b = lsh.encode_lsh(key, csr, 16, 8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_permutation_equivariance(seed):
    """LSH(A)[perm] == LSH(A[perm]) — codes depend only on the row content."""
    key = jax.random.PRNGKey(seed)
    A = jax.random.normal(key, (50, 16))
    perm = jax.random.permutation(jax.random.fold_in(key, 1), 50)
    a = lsh.encode_lsh(jax.random.PRNGKey(7), A, 4, 8)
    b = lsh.encode_lsh(jax.random.PRNGKey(7), A[perm], 4, 8)
    np.testing.assert_array_equal(np.asarray(a)[np.asarray(perm)], np.asarray(b))


@settings(max_examples=10, deadline=None)
@given(scale=st.floats(0.1, 10.0), seed=st.integers(0, 1000))
def test_scale_invariance_with_zero_threshold(scale, seed):
    """sign(sA·V) == sign(A·V) for s>0 (zero threshold)."""
    key = jax.random.PRNGKey(seed)
    A = jax.random.normal(key, (40, 12))
    a = lsh.encode_lsh(jax.random.PRNGKey(9), A, 4, 8, threshold="zero")
    b = lsh.encode_lsh(jax.random.PRNGKey(9), A * scale, 4, 8, threshold="zero")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_locality_similar_rows_get_similar_codes():
    """The LSH property the paper exploits: clustered auxiliary rows produce
    codes whose Hamming distance is smaller within clusters."""
    emb, labels = clustered_embeddings(0, 400, 32, n_clusters=4, noise=0.15)
    bits = codes.unpack_bits(
        lsh.encode_lsh(jax.random.PRNGKey(0), jnp.asarray(emb), 2, 32), 32)
    bits = np.asarray(bits)
    intra, inter = [], []
    rng = np.random.default_rng(0)
    for _ in range(500):
        i, j = rng.integers(0, 400, 2)
        d = (bits[i] != bits[j]).sum()
        (intra if labels[i] == labels[j] else inter).append(d)
    assert np.mean(intra) < np.mean(inter) - 2.0


def test_random_coding_uniform():
    packed = lsh.encode_random(jax.random.PRNGKey(0), 1000, 16, 8)
    cds = codes.unpack_codes(packed, 16, 8)
    counts = np.bincount(np.asarray(cds).reshape(-1), minlength=16)
    assert counts.min() > 300  # roughly uniform over 8000 draws / 16 bins


def test_higher_order_adjacency_improves_locality():
    """Beyond-paper (§6.1 future work): 2-hop auxiliary (A²) separates
    planted communities better than 1-hop on an SBM graph — measured as the
    inter-vs-intra-cluster Hamming gap of the codes."""
    from repro.graph.generate import sbm_graph

    adj, labels = sbm_graph(0, 2000, n_classes=4, p_in=0.02, p_out=0.002)
    gaps = {}
    for hops in (1, 2):
        packed = lsh.encode_lsh(jax.random.PRNGKey(0), adj, 16, 8, hops=hops)
        bits = np.asarray(codes.unpack_bits(packed, 32))
        rng = np.random.default_rng(0)
        intra, inter = [], []
        for _ in range(2000):
            i, j = rng.integers(0, 2000, 2)
            d = (bits[i] != bits[j]).sum()
            (intra if labels[i] == labels[j] else inter).append(d)
        gaps[hops] = np.mean(inter) - np.mean(intra)
    assert gaps[2] > gaps[1] + 0.5, gaps
