"""NN substrate tests: attention (decode==prefill), MoE (oracle equality),
Mamba2 SSD (chunked==naive recurrence), RoPE variants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.attention import AttentionConfig, attention, init_attention
from repro.nn.kvcache import KVCache, SSMCache
from repro.nn.moe import MoEConfig, init_moe, moe_ffn, router_probs
from repro.nn.rope import apply_rope, default_positions, rope_cos_sin
from repro.nn.ssm import (SSMConfig, init_ssm, ssd_chunked, ssd_reference,
                          ssm_forward)

KEY = jax.random.PRNGKey(0)


# ---------------- attention ----------------

@pytest.mark.parametrize("kv", [1, 2, 8])
def test_attention_decode_equals_prefill(kv):
    cfg = AttentionConfig(d_model=64, n_heads=8, n_kv_heads=kv, d_head=8)
    p = init_attention(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, 64))
    pos = default_positions(2, 16, "standard")
    cos, sin = rope_cos_sin(pos, 8)
    y_full, _ = attention(p, x, cfg, cos=cos, sin=sin)
    cache = KVCache.zeros(2, 32, kv, 8, jnp.float32)
    ys = []
    for t in range(16):
        ct, stt = rope_cos_sin(pos[:, t:t + 1], 8)
        yt, cache = attention(p, x[:, t:t + 1], cfg, cos=ct, sin=stt, cache=cache)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(jnp.concatenate(ys, 1)),
                               rtol=2e-4, atol=2e-4)


def test_rope_relative_property():
    """RoPE: attention logits depend only on relative positions."""
    q = jax.random.normal(KEY, (1, 1, 1, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, 32))
    def logit(pq, pk):
        cq, sq = rope_cos_sin(jnp.array([[pq]]), 32)
        ck, sk = rope_cos_sin(jnp.array([[pk]]), 32)
        return float(jnp.sum(apply_rope(q, cq, sq) * apply_rope(k, ck, sk)))
    assert abs(logit(3, 1) - logit(10, 8)) < 1e-3
    assert abs(logit(3, 1) - logit(4, 1)) > 1e-4  # sanity: positions matter


def test_mrope_sections():
    pos = default_positions(2, 8, "mrope")
    cos, sin = rope_cos_sin(pos, 32, mrope_sections=(4, 6, 6))
    assert cos.shape == (2, 8, 16)
    with pytest.raises(ValueError):
        rope_cos_sin(pos, 32, mrope_sections=(4, 4, 4))


def test_partial_rope_keeps_tail():
    x = jax.random.normal(KEY, (1, 4, 2, 32))
    pos = default_positions(1, 4, "standard")
    cos, sin = rope_cos_sin(pos, 32, fraction=0.5)
    y = apply_rope(x, cos, sin)
    np.testing.assert_array_equal(np.asarray(y[..., 16:]), np.asarray(x[..., 16:]))


# ---------------- MoE ----------------

def test_moe_matches_dense_oracle():
    cfg = MoEConfig(d_model=32, d_ff=64, n_experts=8, top_k=2)
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (64, 32))
    out = moe_ffn(p, x, cfg)
    w, idx = router_probs(p, x, cfg)
    ref = jnp.zeros_like(x)
    for e in range(8):
        h = jax.nn.silu(x @ p["w_gate"][e]) * (x @ p["w_up"][e])
        ref += (h @ p["w_down"][e]) * (w * (idx == e)).sum(-1)[:, None]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_moe_expert_padding_never_routed():
    cfg = MoEConfig(d_model=16, d_ff=32, n_experts=5, top_k=2, n_experts_padded=8)
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (256, 16))
    _, idx = router_probs(p, x, cfg)
    assert int(jnp.max(idx)) < 5


def test_moe_grads_finite():
    cfg = MoEConfig(d_model=16, d_ff=32, n_experts=4, top_k=2)
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (32, 16))
    g = jax.grad(lambda p: (moe_ffn(p, x, cfg) ** 2).sum())(p)
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))


# ---------------- Mamba2 SSD ----------------

@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_ssd_chunked_equals_reference(chunk):
    B, S, H, P, N = 2, 32, 4, 8, 16
    X = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 3), (H,)))
    Bc = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, N))
    Cc = jax.random.normal(jax.random.fold_in(KEY, 5), (B, S, N))
    Yc, _ = ssd_chunked(X, dt, A, Bc, Cc, chunk)
    Yr = ssd_reference(X, dt, A, Bc, Cc)
    np.testing.assert_allclose(np.asarray(Yc), np.asarray(Yr), rtol=1e-4, atol=1e-4)


def test_ssm_decode_equals_forward():
    cfg = SSMConfig(d_model=32, d_state=16, headdim=8, chunk=8)
    p = init_ssm(KEY, cfg)
    x = jax.random.normal(KEY, (2, 32, 32))
    y_full, _ = ssm_forward(p, x, cfg)
    cache = SSMCache.zeros(2, cfg.n_heads, cfg.d_state, cfg.headdim,
                           cfg.conv_width, cfg.conv_channels)
    outs = []
    for t in range(32):
        yt, cache = ssm_forward(p, x[:, t:t + 1], cfg, cache=cache)
        outs.append(yt)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(outs, 1)),
                               rtol=1e-3, atol=1e-3)


def test_ssd_state_continuation():
    """Chunked prefill in two halves == one full pass (state carry)."""
    B, S, H, P, N = 1, 32, 2, 8, 8
    X = jax.random.normal(KEY, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 2), (H,)))
    Bc = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, N))
    Cc = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, N))
    y_full, _ = ssd_chunked(X, dt, A, Bc, Cc, 8)
    y1, s1 = ssd_chunked(X[:, :16], dt[:, :16], A, Bc[:, :16], Cc[:, :16], 8)
    y2, _ = ssd_chunked(X[:, 16:], dt[:, 16:], A, Bc[:, 16:], Cc[:, 16:], 8,
                        init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
