"""Plan-ahead miss partition for cached training (ISSUE 6 satellite).

``MissPlanningSource`` permutes each frontier miss-first against a host-side
``HostCacheShadow`` before the batch reaches the jitted step, so the cached
train step decodes only (predicted) misses.  The shadow replays the device
cache's value-independent bookkeeping exactly, so:

  * losses are bitwise-identical to the plain cached run (the permutation
    is undone by the remapped index_maps; the decode covers every miss),
  * hit/miss counters match the plain run,
  * the shadow equals the device ``CacheState`` bookkeeping field-for-field
    after any number of steps, and
  * checkpoint resume restores the shadow (or re-anchors it from the
    restored cache) and continues the exact sequence.
"""

import os

import numpy as np
import pytest

from repro.configs.paper_gnn import paper_gnn_config
from repro.graph.engine import MissPlanningSource
from repro.graph.runtime import GraphRuntime, GraphSource, RuntimeSpec
from repro.graph.sampler import FrontierBatch

N = 1200


@pytest.fixture(scope="module")
def graph():
    return GraphSource(kind="powerlaw", seed=0, n_nodes=N, n_classes=8).build()


def _spec(**emb):
    spec = RuntimeSpec(
        graph=GraphSource(kind="powerlaw", seed=0, n_nodes=N, n_classes=8),
        model=paper_gnn_config("sage", n_nodes=N, n_classes=8, fanout=5),
        batch_size=64, pad_to=128, log_every=1, prefetch_depth=2,
    )
    return spec.with_updates(c=16, m=8, d_c=128, d_m=64, **emb)


def _run(spec, steps, graph):
    rt = GraphRuntime.from_spec(spec, graph=graph)
    losses = []
    try:
        rt.train(steps, on_metrics=lambda s, m: losses.append(float(m["loss"])))
        state = rt.state
        src = getattr(rt.data_iter, "source", rt.data_iter)
    finally:
        rt.close()
    return losses, state, src


@pytest.mark.parametrize("staleness", [0, 2])
def test_planned_run_bitwise_matches_plain_cached(graph, staleness):
    base = _spec(cache_capacity=512, cache_staleness=staleness)
    plan = base.with_updates(cache_plan_misses=True)
    l0, s0, _ = _run(base, 6, graph)
    l1, s1, src = _run(plan, 6, graph)
    assert l0 == l1, f"staleness={staleness}: losses diverge"
    c0, c1 = s0["cache"], s1["cache"]
    assert int(c0.hits) == int(c1.hits)
    assert int(c0.misses) == int(c1.misses)
    # host shadow == device cache bookkeeping, field for field
    sh = src.shadow
    np.testing.assert_array_equal(sh.node_ids, np.asarray(c1.node_ids))
    np.testing.assert_array_equal(sh.version, np.asarray(c1.version))
    np.testing.assert_array_equal(sh.last_used, np.asarray(c1.last_used))
    assert sh.version_counter == int(c1.version_counter)
    assert sh.clock == int(c1.clock)


def test_planned_batches_carry_static_miss_count(graph):
    spec = _spec(cache_capacity=512, cache_staleness=2,
                 cache_plan_misses=True)
    rt = GraphRuntime.from_spec(spec, graph=graph)
    try:
        seen = set()
        for _ in range(4):
            fb = rt.data_iter.next_batch()["frontier"]
            assert fb.n_decode is not None
            assert fb.valid is not None
            U = int(fb.unique.shape[0])
            assert 0 <= fb.n_decode <= U
            seen.add(fb.n_decode)
        # n_decode is bucketed (pad_to doubling) so steady-state training
        # reuses a handful of jit shapes rather than one per miss count
        assert all(n == 0 or n % rt.spec.pad_to == 0 or n == U for n in seen)
    finally:
        rt.close()


def test_resume_restores_shadow_and_sequence(graph, tmp_path):
    spec = _spec(cache_capacity=512, cache_staleness=2,
                 cache_plan_misses=True)
    spec = spec.with_updates(ckpt_dir=os.fspath(tmp_path / "ck"),
                             ckpt_every=3)
    _run(spec, 6, graph)

    rt = GraphRuntime.resume(os.fspath(tmp_path / "ck"))
    resumed = []
    try:
        rt.train(9, on_metrics=lambda s, m: resumed.append(float(m["loss"])))
    finally:
        rt.close()

    straight, _, _ = _run(_spec(cache_capacity=512, cache_staleness=2,
                                cache_plan_misses=True), 9, graph)
    assert resumed == straight[6:], (resumed, straight)


class _PlannedStub:
    """Source emitting an owner-planned batch (plan already attached)."""

    def next_batch(self):
        fb = FrontierBatch(unique=np.zeros(4, np.int32),
                           index_maps=(np.zeros(4, np.int32),),
                           n_unique=4, valid=None, plan=object())
        return {"frontier": fb}


def test_missplanning_source_rejects_owner_planned_batches():
    src = MissPlanningSource(_PlannedStub(), capacity=64)
    with pytest.raises(ValueError, match="plan"):
        src.next_batch()


def test_runtime_validates_plan_misses_spec(graph):
    with pytest.raises(ValueError, match="cache_capacity"):
        GraphRuntime.from_spec(_spec(cache_plan_misses=True), graph=graph)
    # the miss-first permutation needs the dedup frontier layout (and is
    # rejected for n_shards > 1 by the same branch)
    with pytest.raises(ValueError, match="single-shard dedup"):
        GraphRuntime.from_spec(
            _spec(cache_capacity=512, cache_plan_misses=True)
            .with_updates(dedup=False),
            graph=graph)
