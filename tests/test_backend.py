"""DecodeBackend layer tests (ISSUE 2): cross-backend parity (values and
grads, aligned + unaligned shapes), backend selection/registration, the
pallas frontier acceptance check, and the hot-node cache (hit/miss
accounting, staleness-0 exactness through the streaming engine, bounded
drift at staleness k, invalidation on version bump)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_gnn import paper_gnn_config
from repro.core import backend as backend_mod
from repro.core import embedding as emb_lib
from repro.core.backend import (CachedDecodeBackend, CacheState,
                                DecodeBackend, available_backends,
                                get_backend, register_backend)
from repro.core.decoder import DecoderConfig, apply_decoder, init_decoder
from repro.graph import NeighborSampler, powerlaw_graph
from repro.graph.engine import GNNModel, SageBatchSource
from repro.train.step import init_gnn_train_state, make_gnn_train_step

KEY = jax.random.PRNGKey(0)


def _decode_setup(B, m=8, c=16, d_c=128, seed=0):
    k = jax.random.PRNGKey(seed)
    codes = jax.random.randint(k, (B, m), 0, c)
    cb = jax.random.normal(jax.random.fold_in(k, 1), (m, c, d_c))
    w0 = jax.random.normal(jax.random.fold_in(k, 2), (d_c,))
    return codes, cb, w0


# ---------------------------------------------------------------------------
# protocol / registry
# ---------------------------------------------------------------------------

def test_registry_and_selection():
    assert {"gather", "onehot", "pallas"} <= set(available_backends())
    assert get_backend("gather").name == "gather"
    # auto: onehot on CPU CI, pallas on TPU
    auto = get_backend("auto")
    expected = "pallas" if jax.default_backend() == "tpu" else "onehot"
    assert auto.name == expected
    with pytest.raises(ValueError, match="unknown decode backend"):
        get_backend("nope")
    # instances pass straight through
    be = get_backend("onehot")
    assert get_backend(be) is be


def test_register_custom_backend():
    class Doubler(DecodeBackend):
        name = "doubler"

        def decode(self, codes, codebooks, w0=None):
            return 2.0 * backend_mod.GatherBackend().decode(codes, codebooks, w0)

    register_backend("doubler", Doubler)
    try:
        codes, cb, w0 = _decode_setup(16)
        a = get_backend("gather").decode(codes, cb, w0)
        b = get_backend("doubler").decode(codes, cb, w0)
        np.testing.assert_allclose(np.asarray(2.0 * a), np.asarray(b))
    finally:
        backend_mod._REGISTRY.pop("doubler", None)


def test_backend_metadata():
    pal = get_backend("pallas", interpret=True)
    assert pal.capabilities.fused and "tpu" in pal.capabilities.accelerator
    assert pal.preferred_pad % 8 == 0
    assert get_backend("gather").capabilities.grad


# ---------------------------------------------------------------------------
# cross-backend parity (satellite: decode + grads, aligned and unaligned)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,d_c", [
    (256, 128),    # aligned to (block, lane)
    (100, 96),     # deliberately unaligned: pallas must pad, not fall back
    (8, 384),
])
@pytest.mark.parametrize("with_w0", [False, True])
def test_backend_parity_values_and_grads(B, d_c, with_w0):
    codes, cb, w0 = _decode_setup(B, d_c=d_c)
    w = w0 if with_w0 else None
    backends = {
        "gather": get_backend("gather"),
        "onehot": get_backend("onehot"),
        "pallas": get_backend("pallas", interpret=True),
    }
    outs, grads = {}, {}
    for name, be in backends.items():
        outs[name] = np.asarray(be.decode(codes, cb, w))

        def loss(cb_, w0_, be=be):
            return (be.decode(codes, cb_, w0_ if with_w0 else None) ** 2).sum()
        grads[name] = jax.grad(loss, argnums=(0, 1))(cb, w0)

    for name in ("onehot", "pallas"):
        np.testing.assert_allclose(outs[name], outs["gather"],
                                   rtol=1e-5, atol=1e-5)
        for ga, gb in zip(grads[name], grads["gather"]):
            np.testing.assert_allclose(np.asarray(ga), np.asarray(gb),
                                       rtol=1e-4, atol=1e-4)


def test_gather_pallas_bitwise():
    """The gather oracle accumulates in the kernel's codebook order, so
    parity with the fused kernel is bitwise, not approximate."""
    codes, cb, w0 = _decode_setup(128, d_c=128)
    a = get_backend("gather").decode(codes, cb, w0)
    b = get_backend("pallas", interpret=True).decode(codes, cb, w0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decoder_drops_inline_branching():
    """apply_decoder routes through the backend layer — unknown impl names
    surface the registry error, and 'auto' is accepted."""
    cfg = DecoderConfig(c=16, m=8, d_c=64, d_m=64, d_e=32, n_layers=2,
                        compute_dtype="float32")
    p = init_decoder(KEY, cfg)
    codes = jax.random.randint(KEY, (16, cfg.m), 0, cfg.c)
    out = apply_decoder(p, codes, dataclasses.replace(cfg, lookup_impl="auto"))
    assert out.shape == (16, cfg.d_e)
    with pytest.raises(ValueError, match="unknown decode backend"):
        apply_decoder(p, codes, dataclasses.replace(cfg, lookup_impl="nope"))


# ---------------------------------------------------------------------------
# GNN frontier acceptance: pallas forward == gather oracle, bit-identical
# ---------------------------------------------------------------------------

N = 1200


@pytest.fixture(scope="module")
def graph():
    return powerlaw_graph(0, N, avg_degree=8, n_classes=8, homophily=0.9)


def _gnn_cfg(**emb_kw):
    base = paper_gnn_config("sage", n_nodes=N, n_classes=8, fanout=5)
    return dataclasses.replace(
        base, embedding=dataclasses.replace(base.embedding, c=16, m=8,
                                            d_c=128, d_m=64, **emb_kw))


def test_frontier_pallas_bit_identical_to_gather(graph):
    adj, _ = graph
    cfg_g = _gnn_cfg(lookup_impl="gather")
    cfg_p = _gnn_cfg(lookup_impl="pallas")
    codes = emb_lib.make_codes(KEY, cfg_g.embedding_config(), aux=adj)
    params = GNNModel(cfg_g).init(KEY, codes=codes)

    sampler = NeighborSampler(adj, cfg_g.fanouts, max_deg=32, seed=0)
    ids = np.random.default_rng(1).choice(N, 64, replace=False).astype(np.int32)
    fb = jax.device_put(sampler.sample_frontier(
        ids, rng=np.random.default_rng(2)))

    h_gather = GNNModel(cfg_g).apply(params, fb)
    h_pallas = GNNModel(cfg_p, interpret=True).apply(params, fb)
    np.testing.assert_array_equal(np.asarray(h_gather), np.asarray(h_pallas))


# ---------------------------------------------------------------------------
# hot-node cache
# ---------------------------------------------------------------------------

def _ramp_decode(d):
    def decode_fn(ids):
        return jnp.broadcast_to(ids.astype(jnp.float32)[:, None], (ids.shape[0], d))
    return decode_fn


def test_cache_hit_miss_accounting():
    cb = CachedDecodeBackend(staleness=1)
    st = cb.init_state(4, 2)
    decode_fn = _ramp_decode(2)
    ids = jnp.array([1, 2, 3], jnp.int32)

    out, st = cb.lookup(st, ids, decode_fn)           # cold: all miss
    assert (int(st.hits), int(st.misses)) == (0, 3)
    np.testing.assert_array_equal(np.asarray(out[:, 0]), [1, 2, 3])

    out, st = cb.lookup(st, ids, decode_fn)           # same version: all hit
    assert (int(st.hits), int(st.misses)) == (3, 3)

    st = cb.bump_version(st)                          # age 1 <= staleness 1
    out, st = cb.lookup(st, ids, decode_fn)
    assert (int(st.hits), int(st.misses)) == (6, 3)

    out, st = cb.lookup(st, jnp.array([9], jnp.int32), decode_fn)  # absent
    assert (int(st.hits), int(st.misses)) == (6, 4)


def test_cache_invalidation_on_version_bump():
    cb = CachedDecodeBackend(staleness=0)
    st = cb.init_state(4, 2)
    decode_fn = _ramp_decode(2)
    ids = jnp.array([5, 6], jnp.int32)
    _, st = cb.lookup(st, ids, decode_fn)
    _, st = cb.lookup(st, ids, decode_fn)
    assert int(st.hits) == 2                          # same version: hits
    st = cb.bump_version(st)                          # codebook update
    _, st = cb.lookup(st, ids, decode_fn)
    assert (int(st.hits), int(st.misses)) == (2, 4)   # all invalidated


def test_cache_lru_eviction():
    cb = CachedDecodeBackend(staleness=5)
    st = cb.init_state(4, 1)
    decode_fn = _ramp_decode(1)
    _, st = cb.lookup(st, jnp.array([1, 2, 3, 4], jnp.int32), decode_fn)
    _, st = cb.lookup(st, jnp.array([1, 2], jnp.int32), decode_fn)  # touch 1,2
    _, st = cb.lookup(st, jnp.array([7, 8], jnp.int32), decode_fn)  # evict 3,4
    held = set(np.asarray(st.node_ids).tolist())
    assert held == {1, 2, 7, 8}


def test_cache_overflow_does_not_corrupt_slots():
    """More absent misses than free slots: the overflow must be dropped, not
    scattered onto a protected slot (which would leave node_ids and values
    disagreeing about which entity a slot holds)."""
    cb = CachedDecodeBackend(staleness=0)
    st = cb.init_state(4, 1)
    dec = _ramp_decode(1)
    _, st = cb.lookup(st, jnp.array([1, 2, 3], jnp.int32), dec)
    st = cb.bump_version(st)                          # 1,2,3 now stale
    _, st = cb.lookup(st, jnp.array([1, 2, 3, 7, 8, 9], jnp.int32), dec)
    held = np.asarray(st.node_ids)
    vals = np.asarray(st.values[:, 0])
    for i, v in zip(held, vals):                      # decode is identity,
        if i >= 0:                                    # so value must == id
            assert float(v) == float(i), (held, vals)
    assert {1, 2, 3} <= set(held.tolist())            # refreshed in place


def test_cache_valid_mask_skips_padding_rows():
    """Frontier padding rows (duplicates of row 0) must not burn LRU slots
    or count in the hit/miss accounting."""
    cb = CachedDecodeBackend(staleness=3)
    st = cb.init_state(8, 1)
    dec = _ramp_decode(1)
    ids = jnp.array([5, 5, 5, 5], jnp.int32)          # row 0 real, rest pad
    valid = jnp.array([True, False, False, False])
    out, st = cb.lookup(st, ids, dec, valid=valid)
    assert (int(st.hits), int(st.misses)) == (0, 1)
    assert int((np.asarray(st.node_ids) == 5).sum()) == 1
    out, st = cb.lookup(st, ids, dec, valid=valid)
    assert (int(st.hits), int(st.misses)) == (1, 1)
    np.testing.assert_array_equal(np.asarray(out[:, 0]), [5, 5, 5, 5])


def test_cache_grad_flows_only_through_misses():
    cb = CachedDecodeBackend(staleness=3)
    st = cb.init_state(4, 1)
    w = jnp.array(2.0)

    def f(w, st):
        out, st = cb.lookup(st, jnp.array([5], jnp.int32),
                            lambda i: w * jnp.ones((1, 1)))
        return out.sum(), st

    (_, st), g_miss = jax.value_and_grad(f, has_aux=True)(w, st)
    (_, _), g_hit = jax.value_and_grad(f, has_aux=True)(w, st)
    assert float(g_miss) == 1.0     # fresh decode: gradient flows
    assert float(g_hit) == 0.0      # cached row is a stale constant


def test_cache_state_is_checkpointable_pytree():
    st = CacheState.create(8, 4)
    leaves, treedef = jax.tree_util.tree_flatten(st)
    st2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert st2.capacity == 8 and st2.values.shape == (8, 4)


# ---------------------------------------------------------------------------
# streaming-engine acceptance: staleness 0 == uncached, staleness k bounded
# ---------------------------------------------------------------------------

def _train(graph, cfg, steps=10, batch=64):
    adj, labels = graph
    codes = emb_lib.make_codes(KEY, cfg.embedding_config(), aux=adj)
    sampler = NeighborSampler(adj, cfg.fanouts, max_deg=32, seed=0)
    src = SageBatchSource(sampler, np.arange(N), labels, batch, seed=7,
                          pad_to=128)
    state = init_gnn_train_state(jax.random.PRNGKey(1), cfg, codes=codes)
    step = jax.jit(make_gnn_train_step(cfg))
    losses, metrics = [], {}
    for _ in range(steps):
        state, metrics = step(state, jax.device_put(src.next_batch()))
        losses.append(float(metrics["loss"]))
    return losses, metrics


def test_cached_staleness0_exact_on_streaming_engine(graph):
    """Acceptance: CachedDecodeBackend at staleness 0 reproduces uncached
    training losses EXACTLY over 10 streaming-engine steps."""
    l_plain, _ = _train(graph, _gnn_cfg())
    l_cached, m = _train(graph, _gnn_cfg(cache_capacity=256,
                                         cache_staleness=0))
    assert l_plain == l_cached      # bit-identical, not approximately equal
    # staleness 0 + per-step version bump: every access re-decodes
    assert int(m["cache_hits"]) == 0
    assert int(m["cache_misses"]) > 0


def test_cached_staleness_k_bounded_drift(graph):
    l_plain, _ = _train(graph, _gnn_cfg())
    l_stale, m = _train(graph, _gnn_cfg(cache_capacity=1024,
                                        cache_staleness=4))
    assert int(m["cache_hits"]) > 0                   # the cache actually hits
    gaps = [abs(a - b) for a, b in zip(l_plain, l_stale)]
    assert gaps[0] == 0.0                             # first step: cold cache
    assert all(np.isfinite(l_stale))
    assert max(gaps) < 0.5, f"stale-cache loss drift unbounded: {max(gaps)}"
