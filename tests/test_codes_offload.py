"""Host-offloaded codes placement (ISSUE 10).

The tentpole contract: ``EmbeddingSpec(codes_placement="host")`` keeps the
packed ``codes_buf`` in host RAM — the prefetch producer gathers each
frontier's rows into the batch's ``codes`` leaf — and the runtime stays
**bitwise** identical to the replicated default on every path:

  (a) the new ``codes`` leaf is a well-behaved pytree citizen: flatten /
      unflatten round-trips, old 4-tuple aux still unflattens (ckpt compat),
      and ``frontier_batch_shardings`` row-shards it with ``unique``;
  (b) prefetch ``state_dict`` resume replays the exact batch+codes stream;
  (c) train / evaluate / embed / serve_many parity host vs device, as a
      hypothesis property across backends (incl. cached staleness-0) and as
      4-shard ``sharded`` / ``owner`` runs under the multidevice marker;
  (d) spec → checkpoint → resume keeps the placement and the bit pattern;
  (e) the memory claim: host params carry no ``codes_buf`` and the producer
      accounts the per-batch code stream instead.
"""

import dataclasses

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.paper_gnn import paper_gnn_config
from repro.core import embedding as emb_lib
from repro.graph import NeighborSampler, powerlaw_graph
from repro.graph.engine import PrefetchIterator, SageBatchSource
from repro.graph.generate import train_val_test_split
from repro.graph.runtime import GraphRuntime, GraphSource, RuntimeSpec
from repro.graph.sampler import FrontierBatch, attach_codes
from repro.optim import AdamWConfig
from repro.parallel.policy import frontier_batch_shardings

KEY = jax.random.PRNGKey(0)
N = 1200
BATCH = 64
OPT = AdamWConfig(lr=1e-2, weight_decay=0.0)
GRAPH_SRC = GraphSource(kind="powerlaw", seed=0, n_nodes=N, n_classes=8,
                        avg_degree=8, homophily=0.9)


def _cfg(**emb_kw):
    base = paper_gnn_config("sage", n_nodes=N, n_classes=8, fanout=5)
    return dataclasses.replace(base, embedding=dataclasses.replace(
        base.embedding, c=16, m=8, d_c=64, d_m=64, lookup_impl="gather",
        **emb_kw))


def _spec(**kw):
    spec = RuntimeSpec(graph=GRAPH_SRC, model=_cfg(), optimizer=OPT,
                       batch_size=BATCH, prefetch_depth=0)
    return spec.with_updates(**kw) if kw else spec


@pytest.fixture(scope="module")
def graph():
    return GRAPH_SRC.build()


@pytest.fixture(scope="module")
def codes(graph):
    adj, _ = graph
    return np.asarray(emb_lib.make_codes(KEY, _cfg().embedding_config(),
                                         aux=adj))


def _frontier(graph, codes=None, seed=0):
    adj, labels = graph
    sampler = NeighborSampler(adj, _cfg().fanouts, max_deg=64, seed=0)
    tr, _va, _te = train_val_test_split(0, N)
    src = SageBatchSource(sampler, tr, labels, BATCH, seed=seed)
    fb = src.next_batch()["frontier"]
    return attach_codes(fb, codes) if codes is not None else fb


def _param_codes_buf_bytes(params) -> int:
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        if any("codes_buf" in str(getattr(p, "key", p)) for p in path):
            total += int(np.asarray(leaf).nbytes)
    return total


# ---------------------------------------------------------------------------
# (a) leaf hygiene: pytree round-trip, aux compat, shardings
# ---------------------------------------------------------------------------

def test_codes_leaf_pytree_roundtrip(graph, codes):
    fb = _frontier(graph, codes)
    assert fb.codes is not None and fb.codes.dtype == np.uint32
    assert fb.codes.shape[0] == fb.unique.shape[0]     # row-aligned
    leaves, treedef = jax.tree_util.tree_flatten(fb)
    assert np.array_equal(np.asarray(leaves[-1]), fb.codes)  # last leaf
    fb2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert np.array_equal(np.asarray(fb2.codes), fb.codes)
    assert np.array_equal(np.asarray(fb2.unique), np.asarray(fb.unique))
    # attach is idempotent: a second attach must not regather
    assert attach_codes(fb, codes) is fb


def test_codes_roundtrip_under_jit(graph, codes):
    fb = _frontier(graph, codes)
    out = jax.jit(lambda b: (b.codes.sum(), b.unique.sum()))(fb)
    assert int(out[0]) == int(np.asarray(fb.codes, np.uint64).sum() % (1 << 32))


def test_old_aux_unflattens_without_codes(graph):
    """Pre-ISSUE-10 treedefs carry a 4-tuple aux — they must still
    unflatten (checkpointed treedefs, pickled batches)."""
    fb = _frontier(graph)          # no codes
    assert fb.codes is None
    leaves, _ = jax.tree_util.tree_flatten(fb)
    old_aux = (len(fb.index_maps), fb.valid is not None,
               fb.plan is not None, fb.n_decode)
    fb2 = FrontierBatch.tree_unflatten(old_aux, leaves)
    assert fb2.codes is None
    assert np.array_equal(np.asarray(fb2.unique), np.asarray(fb.unique))


def test_codes_leaf_rides_frontier_shardings(graph, codes):
    """``frontier_batch_shardings`` must row-shard the codes leaf exactly
    like ``unique`` (that alignment is what makes sharded/owner decode see
    only shard-local rows) and pass ``codes=None`` through untouched."""
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    batch = {"frontier": _frontier(graph, codes), "labels": np.zeros(BATCH)}
    sh = frontier_batch_shardings(batch, mesh)
    fbs = sh["frontier"]
    assert isinstance(fbs.codes, NamedSharding)
    assert fbs.codes.spec == P("data") == fbs.unique.spec
    sh_none = frontier_batch_shardings(
        {"frontier": _frontier(graph), "labels": np.zeros(BATCH)}, mesh)
    assert sh_none["frontier"].codes is None


# ---------------------------------------------------------------------------
# (b) prefetch state_dict resume replays the exact batch+codes stream
# ---------------------------------------------------------------------------

def test_prefetch_resume_replays_codes_stream(graph, codes):
    adj, labels = graph
    sampler = NeighborSampler(adj, _cfg().fanouts, max_deg=64, seed=0)
    tr, _va, _te = train_val_test_split(0, N)

    def gather(batch):
        batch = dict(batch)
        batch["frontier"] = attach_codes(batch["frontier"], codes)
        return batch

    it = PrefetchIterator(SageBatchSource(sampler, tr, labels, BATCH, seed=0),
                          depth=2, code_gather=gather)
    try:
        for _ in range(3):
            it.next_batch()
        sd = it.state_dict()
        want = it.next_batch()["frontier"]
    finally:
        it.close()
    assert want.codes is not None

    it2 = PrefetchIterator(SageBatchSource(sampler, tr, labels, BATCH,
                                           seed=0),
                           depth=2, code_gather=gather)
    try:
        it2.load_state_dict(sd)
        got = it2.next_batch()["frontier"]
    finally:
        it2.close()
    assert np.array_equal(np.asarray(got.unique), np.asarray(want.unique))
    assert np.array_equal(np.asarray(got.codes), np.asarray(want.codes))


def test_prefetch_stats_account_code_stream(graph):
    rt = GraphRuntime.from_spec(
        _spec(codes_placement="host", prefetch_depth=2), graph=graph)
    try:
        rt.train(3)
        st = rt.data_iter.stats()
    finally:
        rt.close()
    assert st["n_produced"] >= 3
    for k in ("sample_us", "code_gather_us", "put_us"):
        assert st[k] > 0.0, k
    assert st["transferred_code_bytes_per_batch"] > 0


# ---------------------------------------------------------------------------
# (c) bitwise parity host vs device: property across backends + serving
# ---------------------------------------------------------------------------

BACKEND_VARIANTS = (
    {"lookup_impl": "gather"},
    {"lookup_impl": "onehot"},
    {"lookup_impl": "pallas"},
    # staleness-0 hot-node cache: the cached lookup decodes only misses but
    # must stay bitwise — with batch codes it slices the miss prefix
    {"lookup_impl": "pallas", "cache_capacity": 2048, "cache_staleness": 0},
)


def test_host_placement_is_bitwise_property(graph):
    """Property: for any backend variant and batch stream, host placement's
    losses AND embeddings are bit-for-bit the replicated run's (the host
    row gather commutes with decode)."""
    pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=6, deadline=None)
    @given(variant=st.integers(0, len(BACKEND_VARIANTS) - 1),
           data_seed=st.integers(0, 3))
    def check(variant, data_seed):
        emb_kw = BACKEND_VARIANTS[variant]
        dev = GraphRuntime.from_spec(_spec(data_seed=data_seed, **emb_kw),
                                     graph=graph)
        host = GraphRuntime.from_spec(
            _spec(data_seed=data_seed, codes_placement="host",
                  prefetch_depth=2, **emb_kw), graph=graph)
        try:
            assert dev.train(2).losses == host.train(2).losses
            ids = np.arange(4, dtype=np.int32)
            np.testing.assert_array_equal(dev.embed(ids), host.embed(ids))
        finally:
            dev.close()
            host.close()

    check()


def test_host_placement_bitwise_each_backend(graph):
    """Deterministic fallback for the property above (runs even without
    hypothesis): every backend variant, fixed stream, 2-step loss parity."""
    for emb_kw in BACKEND_VARIANTS:
        dev = GraphRuntime.from_spec(_spec(**emb_kw), graph=graph)
        host = GraphRuntime.from_spec(
            _spec(codes_placement="host", prefetch_depth=2, **emb_kw),
            graph=graph)
        try:
            assert dev.train(2).losses == host.train(2).losses, emb_kw
        finally:
            dev.close()
            host.close()


def test_eval_and_serve_many_parity(graph):
    """evaluate() and the serving microbatch concat (serve_many) are
    bitwise host == device — codes attach after the miss-first permutation,
    so the concatenated union frontier stays row-aligned."""
    dev = GraphRuntime.from_spec(_spec(), graph=graph)
    host = GraphRuntime.from_spec(
        _spec(codes_placement="host", prefetch_depth=2), graph=graph)
    try:
        assert dev.train(3).losses == host.train(3).losses
        assert dev.evaluate("val") == host.evaluate("val")

        rng = np.random.default_rng(7)
        reqs = [rng.integers(0, N, size=int(rng.integers(4, 32)))
                .astype(np.int32) for _ in range(4)]
        eng_d = dev.serve(serve_batch=64, max_coalesce=4)
        eng_h = host.serve(serve_batch=64, max_coalesce=4)
        for rd, rh in zip(eng_d.serve_many(reqs), eng_h.serve_many(reqs)):
            np.testing.assert_array_equal(np.asarray(rd.embeddings),
                                          np.asarray(rh.embeddings))
            np.testing.assert_array_equal(np.asarray(rd.logits),
                                          np.asarray(rh.logits))
        # single-request path too
        np.testing.assert_array_equal(
            np.asarray(eng_d.serve(reqs[0]).embeddings),
            np.asarray(eng_h.serve(reqs[0]).embeddings))
    finally:
        dev.close()
        host.close()


@pytest.mark.multidevice(n=4)
@pytest.mark.parametrize("impl", ["sharded:gather", "owner:gather"])
def test_4shard_host_placement_bitwise(graph, impl):
    """4-shard sharded/owner runs: the row-sharded codes leaf lands each
    shard's rows on its own device and the losses stay bitwise."""
    spec = _spec(lookup_impl=impl, n_shards=4, prefetch_depth=2)
    dev = GraphRuntime.from_spec(spec, graph=graph)
    try:
        l_dev = dev.train(2).losses
    finally:
        dev.close()
    host = GraphRuntime.from_spec(spec.with_updates(codes_placement="host"),
                                  graph=graph)
    try:
        assert _param_codes_buf_bytes(host.state["params"]) == 0
        assert host.train(2).losses == l_dev
    finally:
        host.close()


# ---------------------------------------------------------------------------
# (d) spec → checkpoint → resume keeps placement and bit pattern
# ---------------------------------------------------------------------------

def test_ckpt_resume_keeps_host_placement_bitwise(graph, tmp_path):
    ref = GraphRuntime.from_spec(_spec(), graph=graph)
    try:
        ref_losses = ref.train(4).losses
    finally:
        ref.close()

    spec = _spec(codes_placement="host", prefetch_depth=2,
                 ckpt_dir=str(tmp_path / "h"), ckpt_every=2)
    rt = GraphRuntime.from_spec(spec, graph=graph)
    try:
        head = rt.train(2).losses
    finally:
        rt.close()

    # resume knows nothing but the directory: placement rides the manifest
    rt2 = GraphRuntime.resume(str(tmp_path / "h"), graph=graph)
    try:
        assert rt2.codes_on_host
        assert _param_codes_buf_bytes(rt2.state["params"]) == 0
        tail = rt2.train(4)
        assert tail.resumed_from == 2
        assert head + tail.losses == ref_losses       # bitwise, end to end
    finally:
        rt2.close()


def test_spec_json_roundtrip_codes_placement():
    spec = _spec(codes_placement="host")
    back = RuntimeSpec.from_json(spec.to_json())
    assert back.model.embedding.codes_placement == "host"
    assert back == spec


# ---------------------------------------------------------------------------
# (e) memory contract + loud failure modes
# ---------------------------------------------------------------------------

def test_host_params_carry_no_codes_buf(graph):
    dev = GraphRuntime.from_spec(_spec(), graph=graph)
    host = GraphRuntime.from_spec(_spec(codes_placement="host"), graph=graph)
    try:
        resident_dev = _param_codes_buf_bytes(dev.state["params"])
        resident_host = _param_codes_buf_bytes(host.state["params"])
        assert resident_dev > 0
        assert resident_host == 0
    finally:
        dev.close()
        host.close()


def test_unknown_placement_fails_at_init():
    ecfg = _cfg(codes_placement="hbm").embedding_config()
    with pytest.raises(ValueError, match="codes_placement"):
        emb_lib.init_embedding(KEY, ecfg)


def test_host_lookup_without_batch_codes_fails_loudly(graph):
    ecfg = _cfg(codes_placement="host").embedding_config()
    params = emb_lib.init_embedding(KEY, ecfg)
    with pytest.raises(ValueError, match="codes"):
        emb_lib.embed_lookup(params, np.arange(4), ecfg)


def test_fullgraph_rejects_host_placement(graph):
    cfg = dataclasses.replace(
        paper_gnn_config("gcn", n_nodes=N, n_classes=8),
        embedding=dataclasses.replace(
            _cfg().embedding, codes_placement="host"))
    with pytest.raises(ValueError, match="full-graph"):
        GraphRuntime.from_spec(_spec(model=cfg), graph=graph)
