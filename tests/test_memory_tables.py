"""EXACT reproduction of the paper's memory arithmetic (Tables 2, 4, 6)."""

import pytest

from repro.core import memory as M


@pytest.mark.parametrize("n,ref", list(M.PAPER_TABLE4_GLOVE.items()))
def test_table4_glove(n, ref):
    assert abs(M.compression_ratio(n, 300, 2, 128) - ref) < 0.011


@pytest.mark.parametrize("n,ref", list(M.PAPER_TABLE4_M2V.items()))
def test_table4_metapath2vec(n, ref):
    assert abs(M.compression_ratio(n, 128, 2, 128) - ref) < 0.011


@pytest.mark.parametrize("cm", list(M.PAPER_TABLE6_GLOVE))
def test_table6_glove(cm):
    c, m = cm
    for n, ref in M.PAPER_TABLE6_GLOVE[cm].items():
        assert abs(M.compression_ratio(n, 300, c, m) - ref) < 0.011, (cm, n)


@pytest.mark.parametrize("cm", list(M.PAPER_TABLE6_M2V))
def test_table6_metapath2vec(cm):
    c, m = cm
    for n, ref in M.PAPER_TABLE6_M2V[cm].items():
        assert abs(M.compression_ratio(n, 128, c, m) - ref) < 0.011, (cm, n)


def test_table2_exact():
    t = M.PAPER_TABLE2
    light = M.memory_breakdown(t["n"], t["d_e"], 256, 16, 512, 512, 3, "light")
    full = M.memory_breakdown(t["n"], t["d_e"], 256, 16, 512, 512, 3, "full")
    assert abs(light.raw_table_bytes / M.MiB - t["raw_gpu_mib"]) < 0.01
    assert abs(light.binary_code_bytes / M.MiB - t["binary_code_mib"]) < 0.01
    assert abs(light.trainable_decoder_bytes / M.MiB - t["light_decoder_gpu_mib"]) < 0.01
    assert abs(light.frozen_decoder_bytes / M.MiB - t["light_codebooks_cpu_mib"]) < 0.01
    assert abs(full.trainable_decoder_bytes / M.MiB - t["full_decoder_gpu_mib"]) < 0.01
    # GPU-only compression ratio 43.75 (raw + GNN) / (full decoder + GNN)
    gnn = t["gnn_mib"] * M.MiB
    ratio = (full.raw_table_bytes + gnn) / (full.trainable_decoder_bytes + gnn)
    assert abs(ratio - t["full_ratio_gpu"]) < 0.02


def test_ratio_grows_with_entities():
    r = [M.compression_ratio(n, 300, 2, 128) for n in (5000, 50000, 500000)]
    assert r[0] < r[1] < r[2]


def test_musicgen_marginality_note():
    """DESIGN.md §4: at n=2048/codebook the gain is marginal (~1.2x, vs the
    paper's ~40x at products scale) — compression not worth the lossiness
    for a 16 MB table, hence musicgen defaults to dense."""
    r = M.compression_ratio(2048, 2048, 256, 16)
    assert 1.0 < r < 2.0, r
