"""Tests for the §Perf-driven features: chunked CE, dense-dispatch MoE,
one-hot cache writes, strategy resolver, profiles, HLO analyzer."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import init_lm, lm_loss
from repro.nn.kvcache import KVCache
from repro.nn.moe import MoEConfig, init_moe, moe_dense_ffn, moe_ffn

KEY = jax.random.PRNGKey(0)


def test_chunked_ce_equals_full_loss():
    cfg = reduced(get_config("yi-9b"))
    p = init_lm(KEY, cfg)
    tokens = jax.random.randint(jax.random.fold_in(KEY, 1), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    l_full = lm_loss(p, batch, cfg)
    cfg_c = dataclasses.replace(cfg, loss_vocab_chunk=64)
    l_chunk = lm_loss(p, batch, cfg_c)
    np.testing.assert_allclose(float(l_full), float(l_chunk), rtol=1e-5)
    g1 = jax.grad(lambda p: lm_loss(p, batch, cfg), allow_int=True)(p)
    g2 = jax.grad(lambda p: lm_loss(p, batch, cfg_c), allow_int=True)(p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        if a.dtype.kind == "f":
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=1e-5)


def test_moe_dense_equals_sorted():
    cfg = MoEConfig(d_model=32, d_ff=64, n_experts=8, top_k=2)
    p = init_moe(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (64, 32))
    np.testing.assert_allclose(np.asarray(moe_dense_ffn(p, x, cfg)),
                               np.asarray(moe_ffn(p, x, cfg)),
                               rtol=2e-4, atol=2e-4)


def test_moe_dense_respects_padding():
    cfg = MoEConfig(d_model=16, d_ff=32, n_experts=5, top_k=2, n_experts_padded=8)
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (32, 16))
    out = moe_dense_ffn(p, x, cfg)
    assert bool(jnp.isfinite(out).all())


def test_kvcache_onehot_decode_write_equals_dus():
    cache = KVCache.zeros(2, 8, 2, 4, jnp.float32)
    k1 = jax.random.normal(KEY, (2, 3, 2, 4))          # chunked prefill: DUS
    cache = cache.update(k1, k1)
    k2 = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 1, 2, 4))  # decode: onehot
    cache = cache.update(k2, k2)
    np.testing.assert_allclose(np.asarray(cache.k[:, :3]), np.asarray(k1), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(cache.k[:, 3]), np.asarray(k2[:, 0]), rtol=1e-6)
    assert int(cache.pos) == 4
    assert np.asarray(cache.k[:, 4:]).sum() == 0


def test_kvcache_full_replace_prefill():
    cache = KVCache.zeros(1, 4, 1, 2, jnp.float32)
    k = jax.random.normal(KEY, (1, 4, 1, 2))
    cache = cache.update(k, k)
    np.testing.assert_allclose(np.asarray(cache.k), np.asarray(k), rtol=1e-6)


def test_profiles_chunks_divide_vocab():
    from repro.launch.profiles import OPTIMIZED_TRAIN
    for arch, opt in OPTIMIZED_TRAIN.items():
        chunk = (opt.get("overrides") or {}).get("loss_vocab_chunk")
        if chunk:
            vpad = get_config(arch).vocab_padded
            assert vpad % chunk == 0, (arch, vpad, chunk)


def test_strategy_rules():
    from repro.launch.mesh import make_host_mesh
    from repro.parallel.policy import Strategy, rules_for
    # needs only mesh *shape* metadata; single-device mesh objects are fine
    from repro.parallel.sharding import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    r_tp = rules_for(Strategy(), mesh)
    assert r_tp.rules["d_ff"] == "model" and r_tp.rules["batch"] == ("data",)
    r_dp = rules_for(Strategy(dp_over_model=True), mesh)
    assert r_dp.rules["d_ff"] is None
    assert r_dp.rules["batch"] == ("data", "model")


def test_hlo_analyzer_weights_while_loops():
    from repro.launch.hloanalysis import HLOAnalyzer
    text = """
HloModule test

%body.1 (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%d), replica_groups=[2,4]<=[8], to_apply=%add.2
  ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar)
}

%cond.1 (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  ROOT %lt = pred[] constant(false)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8] parameter(0)
  %init = (s32[], f32[8,8]) tuple(%a, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""
    t = HLOAnalyzer(text).totals()
    assert t.flops == 5 * 2 * 8 * 8 * 8                  # 5 weighted dots
    # all-reduce of 256 B over groups of 4: 2*256*(3/4) per iteration
    np.testing.assert_allclose(t.coll["all-reduce"], 5 * 2 * 256 * 0.75)


def test_hbm_model_scales():
    from repro.launch.hbm_model import analytic_hbm_bytes
    from repro.launch.mesh import make_host_mesh
    from repro.launch.shapes import SHAPES
    from repro.parallel.sharding import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = reduced(get_config("qwen1.5-0.5b"))
    train = analytic_hbm_bytes(cfg, SHAPES["train_4k"], mesh, microbatches=1)
    dec = analytic_hbm_bytes(cfg, SHAPES["decode_32k"], mesh)
    assert train["total"] > dec["total"] > 0
    mb2 = analytic_hbm_bytes(cfg, SHAPES["train_4k"], mesh, microbatches=2)
    assert mb2["weights"] == 2 * train["weights"]        # weights re-read per mb
