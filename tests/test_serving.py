"""Continuous-batching serving tier (ISSUE 7).

Pins the contracts the batcher + cross-request dedup rest on:
  (a) Engine-protocol conformance: ``DecodeEngine``, ``GraphInferenceEngine``
      and a batcher-wrapped engine all pass one shared harness (serve
      signature, result shapes, unknown-kwarg tolerance);
  (b) stats accounting: cumulative counters, explicit ``reset()`` that
      survives ``compile_count``, and the shape-bucketing compile bound —
      a 100-request mixed-size stream compiles at most
      ``len(decode_buckets())`` forwards;
  (c) ordering independence: concurrent ``serve()`` through the batcher at
      staleness 0 is BITWISE the same requests served sequentially, in any
      arrival order (content-keyed frontiers + row-pure decode);
  (d) cross-request dedup does strictly less decode work than sequential
      serving on overlapping requests;
  (e) backpressure: a full queue sheds loudly (``Overloaded`` with
      retry-after) and accepted requests always complete;
  (f) ``BatchingSpec`` rides ``RuntimeSpec`` through JSON and selects the
      batcher in ``GraphRuntime.serve()``.
"""

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.paper_gnn import paper_gnn_config
from repro.graph.runtime import GraphRuntime, GraphSource, RuntimeSpec
from repro.models import init_lm
from repro.optim import AdamWConfig
from repro.serving import (BatchingSpec, DecodeEngine, Engine,
                           GenerationResult, GraphInferenceEngine,
                           GraphServeResult, Overloaded, ServingBatcher)

N = 1200
GRAPH_SRC = GraphSource(kind="powerlaw", seed=0, n_nodes=N, n_classes=8,
                        avg_degree=8, homophily=0.9)


def _cfg(**emb_kw):
    base = paper_gnn_config("sage", n_nodes=N, n_classes=8, fanout=5)
    return dataclasses.replace(base, embedding=dataclasses.replace(
        base.embedding, c=16, m=8, d_c=64, d_m=64, lookup_impl="gather",
        **emb_kw))


def _spec(**kw):
    spec = RuntimeSpec(graph=GRAPH_SRC, model=_cfg(),
                       optimizer=AdamWConfig(lr=1e-2, weight_decay=0.0),
                       batch_size=64, prefetch_depth=0, serve_batch=64)
    return spec.with_updates(**kw) if kw else spec


@pytest.fixture(scope="module")
def graph():
    return GRAPH_SRC.build()


@pytest.fixture(scope="module")
def rt(graph):
    runtime = GraphRuntime.from_spec(_spec(), graph=graph)
    runtime.train(3)
    yield runtime
    runtime.close()


def _requests(rng, n, max_b=64, overlap=None):
    reqs = [rng.integers(0, N, size=int(rng.integers(4, max_b))
                         ).astype(np.int32) for _ in range(n)]
    if overlap:
        for r in reqs[1:]:
            r[:overlap] = reqs[0][:overlap]
    return reqs


# ---------------------------------------------------------------------------
# (a) Engine protocol conformance — one harness, every engine
# ---------------------------------------------------------------------------

def _lm_engine():
    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(cfg, params, s_max=64)
    req = np.zeros((2, 4), np.int32)
    def check(res):
        assert isinstance(res, GenerationResult)
        assert res.tokens.shape == (2, 4 + 2)
    return eng, req, check


def _gnn_engine(rt):
    eng = rt.serve(serve_batch=64)
    req = np.arange(12, dtype=np.int32)
    def check(res):
        assert isinstance(res, GraphServeResult)
        assert res.embeddings.shape == (12, rt.cfg.hidden)
        assert res.logits.shape == (12, rt.cfg.n_classes)
        assert res.predictions.shape == (12,)
    return eng, req, check


def _batched_gnn_engine(rt):
    eng, req, check = _gnn_engine(rt)
    return ServingBatcher(eng, BatchingSpec(max_batch=4)), req, check


@pytest.mark.parametrize("which", ["lm", "gnn", "batched_gnn"])
def test_engine_protocol_conformance(rt, which):
    """Every serving surface passes the same harness: isinstance of the
    runtime-checkable protocol, ``serve(request)`` returns the right result
    shape, and unknown kwargs are tolerated (the batcher / shared callers
    pass engine-agnostic options)."""
    makers = {"lm": _lm_engine,
              "gnn": lambda: _gnn_engine(rt),
              "batched_gnn": lambda: _batched_gnn_engine(rt)}
    eng, req, check = makers[which]()
    kwargs = {"lm": {"max_new_tokens": 2}}.get(which, {})
    assert isinstance(eng, Engine)
    check(eng.serve(req, **kwargs))
    check(eng.serve(req, definitely_not_a_real_option=1, **kwargs))
    if hasattr(eng, "close"):
        eng.close()


# ---------------------------------------------------------------------------
# (b) stats accounting + the compile bound
# ---------------------------------------------------------------------------

def test_stats_cumulative_reset_and_compile_count(rt):
    eng = rt.serve(serve_batch=64)
    ids = np.arange(20, dtype=np.int32)
    eng.serve(ids)
    eng.serve(ids)
    st = eng.stats()
    assert st["requests"] == 2 and st["microbatches"] == 2
    assert st["rows_decoded"] > 0 and st["compile_count"] >= 1
    compiles = st["compile_count"]

    eng.reset()
    st = eng.stats()
    # counters zero, but the compile bill and the cache contents survive
    assert st["requests"] == 0 and st["rows_decoded"] == 0
    assert st["hits"] == 0 and st["misses"] == 0
    assert st["compile_count"] == compiles
    eng.serve(ids)
    st = eng.stats()
    assert st["requests"] == 1
    assert st["compile_count"] == compiles, \
        "warm shapes after reset must not recompile"
    assert st["hits"] > 0, "reset must keep the cache contents"


def test_mixed_size_stream_compiles_at_most_bucket_count(rt):
    """Shape-bucketing regression: 100 requests of mixed sizes trigger at
    most one compile per static decode bucket."""
    eng = rt.serve(serve_batch=64)
    rng = np.random.default_rng(3)
    for _ in range(100):
        eng.serve(rng.integers(0, N, size=int(rng.integers(1, 65))
                               ).astype(np.int32))
    st = eng.stats()
    assert st["requests"] == 100
    assert st["compile_count"] <= len(eng.decode_buckets()), (
        f"{st['compile_count']} compiles > "
        f"{len(eng.decode_buckets())} buckets {eng.decode_buckets()}")


# ---------------------------------------------------------------------------
# (c) ordering independence: concurrent batched == sequential, bitwise
# ---------------------------------------------------------------------------

def test_concurrent_batched_bitwise_equals_sequential(rt):
    rng = np.random.default_rng(7)
    reqs = _requests(rng, 12, overlap=3)

    seq_engine = rt.serve(serve_batch=64)
    seq = [seq_engine.serve(r) for r in reqs]

    with ServingBatcher(rt.serve(serve_batch=64, max_coalesce=4),
                        BatchingSpec(max_batch=4, max_delay_ms=20.0)) as sb:
        order = rng.permutation(len(reqs))
        with ThreadPoolExecutor(8) as ex:
            futs = {int(i): ex.submit(sb.serve, reqs[i]) for i in order}
        for i, s in enumerate(seq):
            b = futs[i].result()
            np.testing.assert_array_equal(b.embeddings, s.embeddings)
            np.testing.assert_array_equal(b.logits, s.logits)
            np.testing.assert_array_equal(b.predictions, s.predictions)
        st = sb.stats()
        assert st["completed"] == len(reqs) and st["shed"] == 0
        assert st["max_coalesced"] > 1, \
            "concurrent submits should actually coalesce"


# ---------------------------------------------------------------------------
# (d) cross-request dedup does strictly less decode work
# ---------------------------------------------------------------------------

def test_serve_many_dedups_across_requests(rt):
    rng = np.random.default_rng(11)
    reqs = _requests(rng, 8, overlap=4)

    seq_engine = rt.serve(serve_batch=64)
    for r in reqs:
        seq_engine.serve(r)
    seq_rows = seq_engine.stats()["rows_decoded"]

    bat_engine = rt.serve(serve_batch=64, max_coalesce=4)
    results = bat_engine.serve_many(reqs[:4]) + bat_engine.serve_many(reqs[4:])
    st = bat_engine.stats()
    assert st["rows_decoded"] < seq_rows, (
        f"cross-request dedup must decode strictly fewer rows "
        f"({st['rows_decoded']} vs sequential {seq_rows})")
    assert all(r.batch_requests == 4 for r in results)
    # rows_total accounting is per true request, not per padded bucket
    assert st["rows_total"] == len(reqs) * bat_engine.frontier_cap


def test_serve_many_rejects_oversized_microbatch(rt):
    eng = rt.serve(serve_batch=64, max_coalesce=2)
    reqs = [np.arange(4, dtype=np.int32)] * 3
    with pytest.raises(ValueError, match="max_coalesce"):
        eng.serve_many(reqs)


# ---------------------------------------------------------------------------
# (e) backpressure: loud shed, accepted requests always complete
# ---------------------------------------------------------------------------

class _SlowEngine:
    """Engine stub whose first serve blocks until released — makes queue
    occupancy deterministic for the shed assertions."""

    def __init__(self):
        self.started = threading.Event()
        self.release = threading.Event()
        self.served = []

    def serve(self, request, **_ignored):
        self.started.set()
        self.release.wait(timeout=10)
        self.served.append(np.asarray(request))
        return len(self.served)


def test_backpressure_sheds_loudly():
    eng = _SlowEngine()
    sb = ServingBatcher(eng, BatchingSpec(max_batch=1, max_delay_ms=0.0,
                                          queue_depth=2))
    try:
        first = sb.submit(0)            # worker picks this up and blocks
        assert eng.started.wait(timeout=10)
        admitted = [sb.submit(1), sb.submit(2)]   # fills queue_depth=2
        with pytest.raises(Overloaded) as ei:
            sb.submit(3)
        assert ei.value.queued == 2
        assert ei.value.retry_after_s > 0
        eng.release.set()
        assert first.result(timeout=10) == 1
        assert [f.result(timeout=10) for f in admitted] == [2, 3]
        st = sb.stats()
        assert st["shed"] == 1 and st["completed"] == 3
    finally:
        eng.release.set()
        sb.close()


def test_close_drains_admitted_requests():
    eng = _SlowEngine()
    eng.release.set()                    # never block
    sb = ServingBatcher(eng, BatchingSpec(max_batch=4, max_delay_ms=1.0))
    futs = [sb.submit(i) for i in range(10)]
    sb.close()
    assert sorted(f.result(timeout=0) for f in futs) == list(range(1, 11))
    with pytest.raises(RuntimeError, match="closed"):
        sb.submit(99)


def test_engine_error_propagates_to_futures():
    class _Boom:
        def serve(self, request, **_ignored):
            raise RuntimeError("boom")
    with ServingBatcher(_Boom(), BatchingSpec(max_batch=2)) as sb:
        with pytest.raises(RuntimeError, match="boom"):
            sb.serve(0)


def test_batcher_validates_max_batch_against_engine(rt):
    eng = rt.serve(serve_batch=64, max_coalesce=2)
    with pytest.raises(ValueError, match="max_coalesce"):
        ServingBatcher(eng, BatchingSpec(max_batch=4))


# ---------------------------------------------------------------------------
# (f) BatchingSpec on RuntimeSpec: JSON round-trip + serve() wiring
# ---------------------------------------------------------------------------

def test_batching_spec_json_roundtrip():
    spec = _spec().with_updates(
        batching=BatchingSpec(max_batch=4, max_delay_ms=5.0, queue_depth=32))
    back = RuntimeSpec.from_json(spec.to_json())
    assert back == spec
    assert back.batching == BatchingSpec(4, 5.0, 32)
    # None stays None through the round trip
    plain = _spec()
    assert RuntimeSpec.from_json(plain.to_json()).batching is None


def test_runtime_serve_returns_batcher_when_spec_asks(graph):
    runtime = GraphRuntime.from_spec(
        _spec().with_updates(batching=BatchingSpec(max_batch=4)), graph=graph)
    try:
        with runtime.serve(serve_batch=64) as tier:
            assert isinstance(tier, ServingBatcher)
            # the engine's request buckets were sized from the spec
            assert tier.engine.max_coalesce == 4
            res = tier.serve(np.arange(8, dtype=np.int32))
            assert res.embeddings.shape == (8, runtime.cfg.hidden)
        bare = runtime.serve(serve_batch=64, batching=False)
        assert isinstance(bare, GraphInferenceEngine)
    finally:
        runtime.close()


def test_batching_spec_validates():
    with pytest.raises(ValueError):
        BatchingSpec(max_batch=0)
    with pytest.raises(ValueError):
        BatchingSpec(queue_depth=0)
    with pytest.raises(ValueError):
        BatchingSpec(max_delay_ms=-1.0)
