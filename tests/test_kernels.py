"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lsh as core_lsh
from repro.kernels.flash_attention import flash_attention, mha_ref
from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.hash_decode import hash_decode, hash_decode_ref
from repro.kernels.hash_decode import ops as hd_ops
from repro.kernels.lsh_encode.kernel import lsh_encode_word
from repro.kernels.lsh_encode.ops import lsh_encode_packed
from repro.kernels.lsh_encode.ref import lsh_encode_word_ref


# ---------------- hash_decode ----------------

@pytest.mark.parametrize("B,m,c,d_c", [
    (256, 16, 256, 512),   # paper §5.3 hyper-params
    (128, 128, 2, 512),    # paper §B.2 (c=2, m=128)
    (512, 8, 64, 256),
    (256, 32, 16, 384),
    (128, 4, 4, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_hash_decode_sweep(B, m, c, d_c, dtype):
    key = jax.random.PRNGKey(0)
    codes = jax.random.randint(key, (B, m), 0, c)
    cb = jax.random.normal(jax.random.fold_in(key, 1), (m, c, d_c), dtype)
    w0 = jax.random.normal(jax.random.fold_in(key, 2), (d_c,), dtype)
    # f32: m-term sums accumulate in different orders kernel-vs-ref
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    for w in (None, w0):
        out = hash_decode(codes, cb, w, interpret=True, block_b=128, block_d=128)
        ref = hash_decode_ref(codes, cb, w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=tol, atol=tol)


def test_hash_decode_grads_match_ref():
    key = jax.random.PRNGKey(3)
    codes = jax.random.randint(key, (128, 8), 0, 16)
    cb = jax.random.normal(key, (8, 16, 128))
    w0 = jax.random.normal(jax.random.fold_in(key, 1), (128,))
    gk = jax.grad(lambda cb, w0: (hash_decode(codes, cb, w0, interpret=True) ** 2).sum(),
                  argnums=(0, 1))(cb, w0)
    gr = jax.grad(lambda cb, w0: (hash_decode_ref(codes, cb, w0) ** 2).sum(),
                  argnums=(0, 1))(cb, w0)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_hash_decode_unaligned_falls_back():
    codes = jax.random.randint(jax.random.PRNGKey(0), (100, 8), 0, 16)  # 100 % 128 != 0
    cb = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 96))
    out = hash_decode(codes, cb, None, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(hash_decode_ref(codes, cb, None)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("quantize", ["none", "int8"])
def test_hash_decode_unaligned_backward(quantize):
    """The fallback path must keep the custom VJP: unaligned shapes
    (B=100, d_c=96 — neither sublane- nor lane-tileable) take the jnp
    reference forward, and gradients must still match grad-of-ref."""
    key = jax.random.PRNGKey(5)
    codes = jax.random.randint(key, (100, 8), 0, 16)
    cb = jax.random.normal(jax.random.fold_in(key, 1), (8, 16, 96))
    w0 = jax.random.normal(jax.random.fold_in(key, 2), (96,))

    def ref_loss(cb, w0):
        if quantize == "int8":
            cb = hd_ops.quantize_dequantize(cb)
        return (hash_decode_ref(codes, cb, w0) ** 2).sum()

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        gk = jax.grad(lambda cb, w0: (hash_decode(
            codes, cb, w0, interpret=True, quantize=quantize) ** 2).sum(),
            argnums=(0, 1))(cb, w0)
    gr = jax.grad(ref_loss, argnums=(0, 1))(cb, w0)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_hash_decode_fallback_warns_once_per_shape_and_reason():
    hd_ops.reset_fallback_warnings()
    codes = jax.random.randint(jax.random.PRNGKey(0), (100, 8), 0, 16)
    cb = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 96))
    with pytest.warns(UserWarning, match="falling back"):
        hash_decode(codes, cb, None, interpret=True)
    # same (shape, reason): silent on repeat
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        hash_decode(codes, cb, None, interpret=True)
    # a NEW reason on the same shape must not be silenced by the earlier
    # one: int8 adds the scales-tile requirement (m=8 ok, c=16 < 128 lane)
    with pytest.warns(UserWarning, match="scales-tile"):
        hash_decode(codes, cb, None, interpret=True, quantize="int8")
    # the reset hook restores a clean slate
    hd_ops.reset_fallback_warnings()
    with pytest.warns(UserWarning, match="falling back"):
        hash_decode(codes, cb, None, interpret=True)


@pytest.mark.parametrize("B,m,c,d_c", [
    (256, 16, 256, 512),   # paper §5.3 shape, scales (m, c) tileable
    (128, 8, 128, 128),
])
def test_hash_decode_int8_kernel_matches_ref(B, m, c, d_c):
    """Fused int8 dequant in the kernel == quantize-dequantize-then-decode:
    the scaled-one-hot contraction performs the same f32 products, so the
    match is exact, not approximate."""
    key = jax.random.PRNGKey(11)
    codes = jax.random.randint(key, (B, m), 0, c)
    cb = jax.random.normal(jax.random.fold_in(key, 1), (m, c, d_c))
    w0 = jax.random.normal(jax.random.fold_in(key, 2), (d_c,))
    for w in (None, w0):
        out = hash_decode(codes, cb, w, interpret=True,
                          block_b=128, block_d=128, quantize="int8")
        ref = hash_decode_ref(codes, hd_ops.quantize_dequantize(cb), w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


def test_quantize_codebooks_roundtrip_bound():
    """Absmax int8: dequant error per element <= scale/2, scale = absmax/127,
    and all-zero code vectors reconstruct exactly (scale forced to 1)."""
    cb = jax.random.normal(jax.random.PRNGKey(4), (4, 8, 64))
    cb = cb.at[0, 0].set(0.0)
    q, scales = hd_ops.quantize_codebooks(cb)
    assert q.dtype == jnp.int8 and scales.shape == (4, 8)
    deq = hd_ops.dequantize_codebooks(q, scales)
    err = np.abs(np.asarray(deq - cb))
    bound = np.asarray(scales)[:, :, None] / 2 + 1e-7
    assert (err <= bound).all()
    np.testing.assert_array_equal(np.asarray(deq[0, 0]), np.zeros(64))
    # straight-through backward: identity to the float masters
    g = jax.grad(lambda cb: hd_ops.quantize_dequantize(cb).sum())(cb)
    np.testing.assert_array_equal(np.asarray(g), np.ones_like(np.asarray(cb)))


# ---------------- lsh_encode ----------------

@pytest.mark.parametrize("n,d,w", [(2048, 512, 32), (1024, 256, 16), (512, 128, 32)])
def test_lsh_encode_word_sweep(n, d, w):
    key = jax.random.PRNGKey(1)
    A = jax.random.normal(key, (n, d))
    V = jax.random.normal(jax.random.fold_in(key, 1), (d, w))
    t = jnp.median(A @ V, axis=0)
    out = lsh_encode_word(A, V, t, block_n=256, block_d=128, interpret=True)[:, 0]
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(lsh_encode_word_ref(A, V, t)))


def test_lsh_encode_packed_equals_core():
    A = jax.random.normal(jax.random.PRNGKey(2), (1024, 256))
    a = lsh_encode_packed(jax.random.PRNGKey(7), A, 16, 16,
                          block_n=256, block_d=128, interpret=True)
    b = core_lsh.encode_lsh(jax.random.PRNGKey(7), A, 16, 16)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------- flash_attention ----------------

@pytest.mark.parametrize("B,H,K,S,D,causal", [
    (2, 4, 2, 256, 64, True),
    (1, 8, 8, 128, 64, False),
    (2, 4, 1, 256, 128, True),
    (1, 2, 2, 512, 64, True),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_sweep(B, H, K, S, D, causal, dtype):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, S, D), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, K, S, D), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, K, S, D), dtype)
    out = flash_attention_bhsd(q, k, v, causal=causal, block_q=64, block_k=64,
                               interpret=True)
    ref = mha_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_flash_wrapper_grads():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 128, 4, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 128, 2, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 128, 2, 64))

    def ref_bshd(q, k, v):
        sw = lambda x: jnp.swapaxes(x, 1, 2)
        return sw(mha_ref(sw(q), sw(k), sw(v)))

    gk = jax.grad(lambda *a: (flash_attention(*a, block_q=64, block_k=64,
                                              interpret=True) ** 2).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lambda *a: (ref_bshd(*a) ** 2).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)
