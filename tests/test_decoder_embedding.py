"""Decoder model + embedding layer tests (paper §3.2 semantics)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import embedding as emb
from repro.core.decoder import DecoderConfig, apply_decoder, init_decoder
from repro.core.memory import decoder_param_counts
from repro.nn.module import param_count, trainable_mask


def _cfg(**kw):
    base = dict(c=16, m=8, d_c=64, d_m=64, d_e=32, n_layers=3,
                variant="full", compute_dtype="float32")
    base.update(kw)
    return DecoderConfig(**base)


@pytest.mark.parametrize("variant", ["full", "light"])
@pytest.mark.parametrize("l", [1, 2, 3, 4])
def test_param_count_matches_paper_formula(variant, l):
    cfg = _cfg(variant=variant, n_layers=l)
    p = init_decoder(jax.random.PRNGKey(0), cfg)
    # paper §3.2 counts weights only (biases excluded)
    n_weights = sum(
        leaf.size for path, leaf in jax.tree_util.tree_leaves_with_path(p)
        if not any(str(getattr(k, "key", "")).startswith("b") for k in path)
        and not any(str(getattr(k, "key", "")).endswith("_buf") for k in path)
    )
    trainable, frozen = decoder_param_counts(
        cfg.c, cfg.m, cfg.d_c, cfg.d_m, cfg.d_e, l, variant)
    assert n_weights == trainable == cfg.trainable_params()
    assert cfg.frozen_params() == frozen


@pytest.mark.parametrize("variant", ["full", "light"])
def test_gather_equals_onehot(variant):
    cfg = _cfg(variant=variant)
    p = init_decoder(jax.random.PRNGKey(1), cfg)
    codes = jax.random.randint(jax.random.PRNGKey(2), (64, cfg.m), 0, cfg.c)
    a = apply_decoder(p, codes, dataclasses.replace(cfg, lookup_impl="gather"))
    b = apply_decoder(p, codes, dataclasses.replace(cfg, lookup_impl="onehot"))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_pallas_lookup_impl():
    cfg = _cfg(variant="light", c=16, m=8, d_c=128)
    p = init_decoder(jax.random.PRNGKey(1), cfg)
    codes = jax.random.randint(jax.random.PRNGKey(2), (128, cfg.m), 0, cfg.c)
    a = apply_decoder(p, codes, dataclasses.replace(cfg, lookup_impl="gather"))
    b = apply_decoder(p, codes, dataclasses.replace(cfg, lookup_impl="pallas"),
                      interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999))
def test_decoder_deterministic_per_code(seed):
    """Same code vector -> same embedding (the compression contract)."""
    cfg = _cfg()
    p = init_decoder(jax.random.PRNGKey(0), cfg)
    codes = jax.random.randint(jax.random.PRNGKey(seed), (8, cfg.m), 0, cfg.c)
    dup = jnp.concatenate([codes, codes])
    out = apply_decoder(p, dup, cfg)
    np.testing.assert_allclose(np.asarray(out[:8]), np.asarray(out[8:]),
                               rtol=1e-6, atol=1e-6)


KINDS = ["dense", "hash_full", "hash_light", "random_full", "random_light"]


@pytest.mark.parametrize("kind", KINDS)
def test_embedding_kinds(kind):
    n, d_e = 300, 32
    cfg = emb.EmbeddingConfig(kind=kind, n_entities=n, d_e=d_e, c=16, m=8,
                              d_c=64, d_m=64, compute_dtype="float32")
    aux = jax.random.normal(jax.random.PRNGKey(0), (n, 24))
    p = emb.init_embedding(jax.random.PRNGKey(1), cfg, aux=aux)
    ids = jnp.array([0, 5, 299, 5])
    out = emb.embed_lookup(p, ids, cfg)
    assert out.shape == (4, d_e)
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(out[3]), rtol=1e-6)


def test_trainable_state_independent_of_n():
    """The paper's headline property: trainable params don't grow with n."""
    def n_trainable(n):
        cfg = emb.EmbeddingConfig(kind="random_full", n_entities=n, d_e=32,
                                  c=16, m=8, d_c=64, d_m=64)
        p = emb.init_embedding(jax.random.PRNGKey(0), cfg)
        mask = trainable_mask(p)
        return sum(l.size for l, m in zip(jax.tree.leaves(p), jax.tree.leaves(mask)) if m)
    assert n_trainable(100) == n_trainable(10_000)


def test_hash_requires_aux():
    cfg = emb.EmbeddingConfig(kind="hash_full", n_entities=10, d_e=8)
    with pytest.raises(ValueError):
        emb.make_codes(jax.random.PRNGKey(0), cfg, None)


def test_decode_all_blocked():
    cfg = emb.EmbeddingConfig(kind="random_full", n_entities=100, d_e=16,
                              c=4, m=4, d_c=32, d_m=32, compute_dtype="float32")
    p = emb.init_embedding(jax.random.PRNGKey(0), cfg)
    full = emb.decode_all(p, cfg, block=32)
    assert full.shape == (100, 16)
    one = emb.embed_lookup(p, jnp.array([37]), cfg)
    np.testing.assert_allclose(np.asarray(full[37]), np.asarray(one[0]),
                               rtol=1e-5, atol=1e-5)
