"""Sharded streaming engine (ISSUE 3): the (seed, shard, step) sampling
contract, the stacked multi-shard frontier, the "sharded" decode backend,
and their end-to-end agreement with the single-shard path.

Single-device tests always run (the backend degrades to its base with no
mesh / a 1-sized data axis); tests needing a real multi-device mesh carry
the ``multidevice`` marker and skip — never error — below 2 devices (the
``tools/ci.sh --multidevice`` leg forces 8 host devices and runs them).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_gnn import paper_gnn_config
from repro.core import backend as backend_mod
from repro.core import embedding as emb_lib
from repro.graph import FrontierBatch, NeighborSampler, powerlaw_graph
from repro.graph.engine import (GNNModel, PrefetchIterator, SageBatchSource,
                                ShardedSageBatchSource, default_frontier_cap)
from repro.parallel.policy import make_frontier_placement
from repro.parallel.sharding import use_sharding
from repro.train import (LoopConfig, init_gnn_train_state, make_gnn_train_step,
                         run_training)

KEY = jax.random.PRNGKey(0)
N = 1200
N_SHARDS = 4
BATCH = 64          # global batch; per-shard = BATCH // N_SHARDS


@pytest.fixture(scope="module")
def graph():
    return powerlaw_graph(0, N, avg_degree=8, n_classes=8, homophily=0.9)


def _cfg(lookup_impl="sharded:gather", **emb_kw):
    base = paper_gnn_config("sage", n_nodes=N, n_classes=8, fanout=5)
    return dataclasses.replace(base, embedding=dataclasses.replace(
        base.embedding, c=16, m=8, d_c=64, d_m=64, lookup_impl=lookup_impl,
        **emb_kw))


@pytest.fixture(scope="module")
def codes(graph):
    adj, _ = graph
    # numpy, not a device array: the train state is donated per step, so a
    # shared device buffer would be deleted out from under the next init
    return np.asarray(emb_lib.make_codes(KEY, _cfg().embedding_config(),
                                         aux=adj))


def _mesh(n):
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:n]), ("data",))


# ---------------------------------------------------------------------------
# sharded sampling contract (single device)
# ---------------------------------------------------------------------------

def test_shard_union_bit_identical_to_single(graph):
    """The N per-shard batches concatenated == the 1-shard batch, per level,
    for several steps — the (seed, shard, step) slicing contract."""
    adj, labels = graph
    sampler = NeighborSampler(adj, (5, 5), max_deg=32, seed=0)
    single = SageBatchSource(sampler, np.arange(N), labels, BATCH, seed=7)
    shards = [SageBatchSource(sampler, np.arange(N), labels,
                              BATCH // N_SHARDS, seed=7, shard=s,
                              n_shards=N_SHARDS) for s in range(N_SHARDS)]
    for _ in range(3):
        g = single.next_batch()
        parts = [s.next_batch() for s in shards]
        for i, lvl in enumerate(g["frontier"].levels()):
            cat = np.concatenate(
                [np.asarray(p["frontier"].levels()[i]) for p in parts], axis=0)
            np.testing.assert_array_equal(np.asarray(lvl), cat)
        np.testing.assert_array_equal(
            g["labels"], np.concatenate([p["labels"] for p in parts]))


def test_shard_state_dict_roundtrip(graph):
    adj, labels = graph
    sampler = NeighborSampler(adj, (5, 5), max_deg=32, seed=0)
    src = SageBatchSource(sampler, np.arange(N), labels, 16, seed=3,
                          shard=2, n_shards=N_SHARDS)
    src.next_batch()
    snap = src.state_dict()
    assert snap == {"step": 1, "seed": 3, "shard": 2, "n_shards": N_SHARDS}
    want = src.next_batch()
    src.load_state_dict(snap)
    got = src.next_batch()
    np.testing.assert_array_equal(np.asarray(want["frontier"].unique),
                                  np.asarray(got["frontier"].unique))
    # a different shard layout must refuse the state
    other = SageBatchSource(sampler, np.arange(N), labels, 16, seed=3,
                            shard=1, n_shards=N_SHARDS)
    with pytest.raises(AssertionError):
        other.load_state_dict(snap)


def test_sharded_source_stacked_layout_and_resume(graph):
    """The stacked batch groups rows per shard block, offsets index maps
    into the owning block, masks each block's padding, and resumes through
    PrefetchIterator exactly."""
    adj, labels = graph
    sampler = NeighborSampler(adj, (5, 5), max_deg=32, seed=0)
    src = ShardedSageBatchSource(sampler, np.arange(N), labels,
                                 BATCH // N_SHARDS, n_shards=N_SHARDS,
                                 seed=7, pad_to=64)
    cap = src.frontier_cap
    batch = src.next_batch()
    fb = batch["frontier"]
    assert fb.unique.shape[0] == N_SHARDS * cap
    assert fb.valid is not None and fb.valid.shape == fb.unique.shape
    # each level-0 block points into its own shard's rows
    tgt = np.asarray(fb.index_maps[0])
    per = BATCH // N_SHARDS
    for s in range(N_SHARDS):
        blk = tgt[s * per:(s + 1) * per]
        assert (blk >= s * cap).all() and (blk < (s + 1) * cap).all()
    # stacked maps reconstruct the exact global levels of the 1-shard source
    single = SageBatchSource(sampler, np.arange(N), labels, BATCH, seed=7)
    g = single.next_batch()
    for lvl, got in zip(g["frontier"].levels(), fb.levels()):
        np.testing.assert_array_equal(np.asarray(lvl), np.asarray(got))

    pf = PrefetchIterator(src, depth=2)
    try:
        pf.next_batch()
        snap = pf.state_dict()
        want = np.asarray(pf.next_batch()["labels"])
        pf.load_state_dict(snap)
        got = np.asarray(pf.next_batch()["labels"])
    finally:
        pf.close()
    np.testing.assert_array_equal(want, got)


def test_no_cross_level_draw_correlation_past_path_stride():
    """Path counters repeat across levels once the global batch exceeds the
    path stride (1024): target gpos 1024 shares its counter range with child
    k=0 of gpos 0.  The per-level subkey must decorrelate those draws —
    without it, the two streams are bit-identical whenever the node ids
    coincide (regression for the sample_hashed keying scheme)."""
    from repro.graph import CSRMatrix
    # node 0's only neighbour is node 1; node 1 has many distinct neighbours
    src = [0] + [1] * 40
    dst = [1] + list(range(2, 42))
    adj = CSRMatrix.from_edges(np.array(src), np.array(dst), n_nodes=42)
    sampler = NeighborSampler(adj, (4, 4), max_deg=64, seed=0)
    ids = np.zeros(1025, np.int32)
    ids[1024] = 1                       # same node as gpos 0's forced child
    from repro.graph.sampler import stream_key
    levels = sampler.sample_hashed(ids, np.arange(1025, dtype=np.uint64),
                                   stream_key(0, 0))
    assert levels[1][0, 0] == 1         # child k=0 of gpos 0 is node 1
    # child-of-child draws (level 2, key_1) vs target-1024 level-1 draws
    # (key_0) share the counter range but must not share the stream
    assert not np.array_equal(levels[2][0, 0, :], levels[1][1024, :])


def test_frontier_cap_exact_padding_and_overflow():
    levels = [np.arange(8), np.arange(8).repeat(3).reshape(8, 3)]
    fb = FrontierBatch.from_levels(levels, cap=16)
    assert fb.unique.shape == (16,) and int(fb.n_unique) == 8
    with pytest.raises(ValueError, match="cap"):
        FrontierBatch.from_levels(levels, cap=4)
    # default cap: worst case bounded by the graph size, pad_to-aligned
    assert default_frontier_cap(16, (5, 5), 64, n_nodes=N) == \
        -(-min(16 * 31, N) // 64) * 64


# ---------------------------------------------------------------------------
# sharded backend (single device: degrades to base)
# ---------------------------------------------------------------------------

def test_sharded_backend_registry_and_fallback():
    assert "sharded" in backend_mod.available_backends()
    be = backend_mod.get_backend("sharded:gather")
    assert be.base.name == "gather"
    with pytest.raises(ValueError, match="unknown decode backend"):
        backend_mod.get_backend("nope")
    with pytest.raises(ValueError, match="no ':"):
        backend_mod.get_backend("gather:onehot")
    with pytest.raises(ValueError, match="wrap itself"):
        backend_mod.get_backend("sharded:sharded")

    # no mesh -> bitwise the base backend
    key = jax.random.PRNGKey(1)
    codes = jax.random.randint(key, (32, 8), 0, 16)
    cb = jax.random.normal(jax.random.fold_in(key, 1), (8, 16, 64))
    w0 = jax.random.normal(jax.random.fold_in(key, 2), (64,))
    ref = backend_mod.get_backend("gather").decode(codes, cb, w0)
    np.testing.assert_array_equal(np.asarray(be.decode(codes, cb, w0)),
                                  np.asarray(ref))


def test_sharded_selectable_through_model_and_serving(graph, codes):
    """lookup_impl="sharded" resolves everywhere the registry is routed —
    the GNN frontier path and the serving engine — and on one device the
    hidden states are bitwise the gather path's."""
    adj, labels = graph
    cfg_sh = _cfg("sharded:gather")
    cfg_ref = _cfg("gather")
    params = GNNModel(cfg_ref).init(KEY, codes=codes)
    sampler = NeighborSampler(adj, (5, 5), max_deg=32, seed=0)
    fb = SageBatchSource(sampler, np.arange(N), labels, 32,
                         seed=1).next_batch()["frontier"]
    h_ref = GNNModel(cfg_ref).apply(params, jax.device_put(fb))
    h_sh = GNNModel(cfg_sh).apply(params, jax.device_put(fb))
    np.testing.assert_array_equal(np.asarray(h_ref), np.asarray(h_sh))

    from repro.configs import get_config, reduced
    from repro.models import init_lm
    from repro.serving import DecodeEngine
    lm_cfg = reduced(get_config("qwen1.5-0.5b"))
    lm_params = init_lm(jax.random.PRNGKey(0), lm_cfg)
    eng = DecodeEngine(lm_cfg, lm_params, s_max=32,
                       decode_backend="sharded:gather")
    assert eng.decode_backend == "sharded:gather"
    with pytest.raises(ValueError, match="unknown decode backend"):
        DecodeEngine(lm_cfg, lm_params, s_max=32, decode_backend="bogus")


# ---------------------------------------------------------------------------
# multi-device: backend parity, end-to-end bit-identity, sharded cache
# ---------------------------------------------------------------------------

@pytest.mark.multidevice(n=4)
def test_sharded_decode_matches_gather_oracle():
    """Forward is bitwise the gather oracle (rows accumulate identically on
    whichever shard holds them); grads match within f32 tolerance (the psum
    reduces partial codebook grads in a different order)."""
    mesh = _mesh(4)
    key = jax.random.PRNGKey(0)
    B, m, c, d_c = 64, 8, 16, 128
    codes = jax.random.randint(key, (B, m), 0, c)
    cb = jax.random.normal(jax.random.fold_in(key, 1), (m, c, d_c))
    w0 = jax.random.normal(jax.random.fold_in(key, 2), (d_c,))
    oracle = backend_mod.get_backend("gather")
    sb = backend_mod.get_backend("sharded:gather")

    for scale in (w0, None):
        ref = oracle.decode(codes, cb, scale)
        with use_sharding(mesh):
            out = jax.jit(lambda c, b, s: sb.decode(c, b, s))(codes, cb, scale)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def loss(fn):
        return lambda cb_, w0_: (fn(codes, cb_, w0_) ** 2).sum()
    with use_sharding(mesh):
        assert backend_mod.resolve_auto() == "sharded"
        g_sh = jax.jit(jax.grad(loss(sb.decode), argnums=(0, 1)))(cb, w0)
    g_ref = jax.grad(loss(oracle.decode), argnums=(0, 1))(cb, w0)
    for a, b in zip(g_sh, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.multidevice(n=4)
def test_sharded_decode_pads_unaligned_batch():
    mesh = _mesh(4)
    key = jax.random.PRNGKey(3)
    codes = jax.random.randint(key, (30, 8), 0, 16)   # 30 % 4 != 0
    cb = jax.random.normal(jax.random.fold_in(key, 1), (8, 16, 64))
    ref = backend_mod.get_backend("gather").decode(codes, cb, None)
    with use_sharding(mesh):
        out = backend_mod.get_backend("sharded:gather").decode(codes, cb, None)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def _run_stream(graph, codes, cfg, n_shards, mesh, steps=3, seed=0,
                owner=False):
    adj, labels = graph
    sampler = NeighborSampler(adj, cfg.fanouts, max_deg=32, seed=0)
    src = ShardedSageBatchSource(sampler, np.arange(N), labels,
                                 BATCH // n_shards, n_shards=n_shards,
                                 seed=seed, pad_to=64, owner_plan=owner)
    place = make_frontier_placement(mesh) if mesh is not None else None
    state = init_gnn_train_state(KEY, cfg, codes=codes)
    it = PrefetchIterator(src, depth=2, device=place)
    try:
        res = run_training(make_gnn_train_step(cfg, mesh=mesh), state, it,
                           LoopConfig(total_steps=steps))
    finally:
        it.close()
    return res.losses


@pytest.mark.multidevice(n=4)
def test_4shard_run_loss_bit_identical_to_1shard(graph, codes):
    """Acceptance (ISSUE 3): with a 4-way data mesh, the 4-shard streaming
    GNN run's forward loss is bit-identical to the 1-shard run on step 0 —
    same global batch (sampling contract), same decoded rows (sharded
    backend over the gather base), same combine (full-batch, post-gather).
    """
    cfg = _cfg("sharded:gather")
    l1 = _run_stream(graph, codes, cfg, 1, None)
    l4 = _run_stream(graph, codes, cfg, N_SHARDS, _mesh(N_SHARDS))
    assert l1[0] == l4[0], f"step-0 loss diverged: {l1[0]} vs {l4[0]}"
    # later steps may only drift by f32 accumulation (grad psum order)
    assert max(abs(a - b) for a, b in zip(l1, l4)) < 1e-3


# ---------------------------------------------------------------------------
# owner-computes decode (ISSUE 5): plan, backend, end-to-end, property
# ---------------------------------------------------------------------------

def _owner_source(graph, n_shards=N_SHARDS, seed=7, owner_plan=True, **kw):
    adj, labels = graph
    sampler = NeighborSampler(adj, (5, 5), max_deg=32, seed=0)
    return ShardedSageBatchSource(sampler, np.arange(N), labels,
                                  BATCH // n_shards, n_shards=n_shards,
                                  seed=seed, pad_to=64, owner_plan=owner_plan,
                                  **kw)


def test_owner_backend_registry_and_fallback():
    assert "owner" in backend_mod.available_backends()
    be = backend_mod.get_backend("owner:gather")
    assert be.base.name == "gather"
    with pytest.raises(ValueError, match="wrap itself"):
        backend_mod.get_backend("owner:owner")
    with pytest.raises(ValueError, match="wrap itself"):
        backend_mod.get_backend("owner:sharded")
    with pytest.raises(ValueError, match="wrap itself"):
        backend_mod.get_backend("sharded:owner")

    # no mesh -> bitwise the base backend, with or without a plan
    key = jax.random.PRNGKey(1)
    codes = jax.random.randint(key, (32, 8), 0, 16)
    cb = jax.random.normal(jax.random.fold_in(key, 1), (8, 16, 64))
    w0 = jax.random.normal(jax.random.fold_in(key, 2), (64,))
    ref = backend_mod.get_backend("gather").decode(codes, cb, w0)
    np.testing.assert_array_equal(np.asarray(be.decode(codes, cb, w0)),
                                  np.asarray(ref))
    np.testing.assert_array_equal(
        np.asarray(be.decode_frontier(codes, cb, w0, plan=None)),
        np.asarray(ref))


def test_owner_plan_routes_every_valid_row_once(graph):
    """Host-side contract of ``build_owner_plan``: simulating the exchange
    in numpy with ids as payloads, every valid frontier row receives the id
    it asked for, each owner's decode list is distinct ids ≡ owner (mod n),
    and the total decoded rows equal the stacked frontier's global unique
    count (the cross-shard dedup)."""
    src = _owner_source(graph)
    batch = src.next_batch()
    fb = batch["frontier"]
    plan = fb.plan
    assert plan is not None
    n, cap = src.n_shards, src.frontier_cap
    unique = np.asarray(fb.unique).reshape(n, cap)
    valid = np.asarray(fb.valid).reshape(n, cap)
    n_uniques = [int(valid[s].sum()) for s in range(n)]

    # owner o's decode list: distinct, owned by o
    global_unique = np.unique(np.concatenate(
        [unique[s, :n_uniques[s]] for s in range(n)]))
    for o in range(n):
        k = int(plan.n_owned[o])
        recv = np.stack([unique[s][np.clip(plan.req_rows[s, o], 0, cap - 1)]
                         for s in range(n)]).reshape(-1)
        owned = recv[plan.owned_src[o, :k]]
        assert len(np.unique(owned)) == k and (owned % n == o).all()
    assert int(plan.n_owned.sum()) == global_unique.shape[0]
    assert int(plan.n_owned.sum()) < sum(n_uniques)   # real cross-shard dedup

    # full exchange simulation: payload = the id itself
    out = np.full((n, cap), -1, np.int64)
    for o in range(n):
        recv = np.stack([unique[s][np.clip(plan.req_rows[s, o], 0, cap - 1)]
                         for s in range(n)]).reshape(-1)
        dec = recv[plan.owned_src[o]]                 # "decode" = identity
        for s in range(n):
            back = dec[plan.ret_idx[o, s]]            # (oc,)
            rows = plan.req_rows[s, o]
            ok = rows < cap
            out[s, rows[ok]] = back[ok]
    for s in range(n):
        np.testing.assert_array_equal(out[s, :n_uniques[s]],
                                      unique[s, :n_uniques[s]])


def test_owner_plan_overflow_falls_back_loudly(graph):
    """Caps too small for the workload: the source must warn and emit the
    batch WITHOUT a plan (decode falls back), never truncate rows."""
    src = _owner_source(graph, owner_cap=2, owner_unique_cap=8)
    with pytest.warns(UserWarning, match="owner plan overflow"):
        batch = src.next_batch()
    fb = batch["frontier"]
    assert fb.plan is None
    # the batch itself is intact — the 1-shard reconstruction still holds
    adj, labels = graph
    sampler = NeighborSampler(adj, (5, 5), max_deg=32, seed=0)
    single = SageBatchSource(sampler, np.arange(N), labels, BATCH, seed=7)
    g = single.next_batch()
    for lvl, got in zip(g["frontier"].levels(), fb.levels()):
        np.testing.assert_array_equal(np.asarray(lvl), np.asarray(got))


def test_owner_spec_field_roundtrip():
    """An owner-decode run is one RuntimeSpec field change, and the owner
    knobs ride through JSON (checkpoint-resume safe)."""
    import json

    from repro.graph.runtime import GraphSource, RuntimeSpec
    spec = RuntimeSpec(
        graph=GraphSource(n_nodes=N, n_classes=8),
        model=paper_gnn_config("sage", n_nodes=N, n_classes=8, fanout=5),
    ).with_updates(lookup_impl="owner:gather", n_shards=4,
                   owner_cap=128, owner_unique_cap=256)
    assert spec.model.embedding.lookup_impl == "owner:gather"
    assert (spec.owner_cap, spec.owner_unique_cap) == (128, 256)
    restored = RuntimeSpec.from_dict(json.loads(spec.to_json()))
    assert restored == spec


def test_owner_caps_default_sizing():
    from repro.graph.sampler import default_owner_caps
    # the BENCH_shard.json workload: cap·1.25/n request slots, cap/2 decode
    # rows (the duplication-threshold inequality, both sublane-rounded)
    assert default_owner_caps(7168, 4) == (2240, 3584)
    # never exceed the trivially safe bounds (cap, n_shards·owner_cap)
    oc, ou = default_owner_caps(16, 16)
    assert oc <= 16 and ou <= 16 * oc


def test_owner_hashed_frontiers_never_overflow_default_caps(graph):
    """Property (ISSUE 5 satellite): frontiers drawn by the splitmix64
    counter-based sampler never overflow the default capacities — every
    (requester, owner) bucket fits the ``cap/n_shards`` expectation with the
    default safety factor, every owner's unique set fits ``cap/2``, and the
    plan therefore always builds (the loud fallback never fires in
    practice)."""
    hypothesis = pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    from repro.graph.sampler import default_owner_caps
    adj, labels = graph
    sampler = NeighborSampler(adj, (5, 5), max_deg=32, seed=0)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), step=st.integers(0, 1000))
    def check(seed, step):
        src = ShardedSageBatchSource(sampler, np.arange(N), labels,
                                     BATCH // N_SHARDS, n_shards=N_SHARDS,
                                     seed=seed, pad_to=64, owner_plan=True)
        for sh in src.shards:
            sh.step = step
        batch = src.next_batch()
        fb = batch["frontier"]
        # no bucket or owned-unique overflow: the plan built (no fallback)
        assert fb.plan is not None, (seed, step)
        cap = src.frontier_cap
        oc, _ = default_owner_caps(cap, N_SHARDS)
        unique = np.asarray(fb.unique).reshape(N_SHARDS, cap)
        valid = np.asarray(fb.valid).reshape(N_SHARDS, cap)
        for s in range(N_SHARDS):
            ids = unique[s][valid[s]]
            counts = np.bincount(ids % N_SHARDS, minlength=N_SHARDS)
            assert counts.max() <= oc, (seed, step, counts.max(), oc)

    check()


@pytest.mark.multidevice(n=4)
def test_owner_decode_matches_gather_oracle(graph):
    """Tentpole acceptance: forward through the owner exchange is bitwise
    the gather oracle on every valid row (a row's decode is computed once,
    on its owner, from the same code row); codebook/W0 grads match the
    oracle within f32 tolerance (cotangents are scatter-added per owner and
    the disjoint owner partials psummed in a different order)."""
    mesh = _mesh(N_SHARDS)
    src = _owner_source(graph)
    fb = src.next_batch()["frontier"]
    assert fb.plan is not None
    key = jax.random.PRNGKey(0)
    m, c, d_c = 8, 16, 128
    ctable = jax.random.randint(key, (N, m), 0, c)
    codes = jnp.asarray(np.asarray(ctable)[np.asarray(fb.unique)])
    cb = jax.random.normal(jax.random.fold_in(key, 1), (m, c, d_c))
    w0 = jax.random.normal(jax.random.fold_in(key, 2), (d_c,))
    valid = np.asarray(fb.valid)
    vm = jnp.asarray(valid)[:, None]

    oracle = backend_mod.get_backend("gather")
    ob = backend_mod.get_backend("owner:gather")
    for scale in (w0, None):
        ref = oracle.decode(codes, cb, scale)
        with use_sharding(mesh):
            out = jax.jit(lambda co, b, s: ob.decode_frontier(
                co, b, s, plan=fb.plan))(codes, cb, scale)
        np.testing.assert_array_equal(np.asarray(out)[valid],
                                      np.asarray(ref)[valid])

    def loss(fn):
        return lambda cb_, w0_: ((fn(cb_, w0_) * vm) ** 2).sum()
    with use_sharding(mesh):
        g_own = jax.jit(jax.grad(
            loss(lambda b, s: ob.decode_frontier(codes, b, s, plan=fb.plan)),
            argnums=(0, 1)))(cb, w0)
    g_ref = jax.grad(loss(lambda b, s: oracle.decode(codes, b, s)),
                     argnums=(0, 1))(cb, w0)
    for a, b in zip(g_own, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.multidevice(n=2)
def test_auto_prefers_owner_past_duplication_threshold():
    with use_sharding(_mesh(2)):
        assert backend_mod.resolve_auto(duplication=3.0) == "owner"
        assert backend_mod.resolve_auto(duplication=1.2) == "sharded"
        assert backend_mod.resolve_auto() == "sharded"
    assert backend_mod.resolve_auto(duplication=3.0) in ("onehot", "pallas")


@pytest.mark.multidevice(n=4)
def test_4shard_owner_run_loss_bit_identical_to_1shard(graph, codes):
    """Acceptance (ISSUE 5): the owner-computes 4-shard streaming run's
    step-0 forward loss is bit-identical to the 1-shard run — hub rows
    decode once on their owner, from the same codes, through the same
    gather-order accumulation."""
    cfg_own = _cfg("owner:gather")
    l1 = _run_stream(graph, codes, _cfg("sharded:gather"), 1, None)
    l4 = _run_stream(graph, codes, cfg_own, N_SHARDS, _mesh(N_SHARDS),
                     owner=True)
    assert l1[0] == l4[0], f"step-0 loss diverged: {l1[0]} vs {l4[0]}"
    assert max(abs(a - b) for a, b in zip(l1, l4)) < 1e-3


@pytest.mark.multidevice(n=4)
def test_owner_cached_staleness0_bit_exact(graph, codes):
    """Satellite (ISSUE 5): CachedDecodeBackend over the owner exchange at
    staleness 0 reproduces the uncached owner run exactly (the cache wraps
    the whole exchange; every access re-decodes at staleness 0)."""
    mesh = _mesh(N_SHARDS)
    l_plain = _run_stream(graph, codes, _cfg("owner:gather"),
                          N_SHARDS, mesh, steps=6, seed=7, owner=True)
    l_cached = _run_stream(graph, codes,
                           _cfg("owner:gather", cache_capacity=256,
                                cache_staleness=0),
                           N_SHARDS, mesh, steps=6, seed=7, owner=True)
    assert l_plain == l_cached


@pytest.mark.multidevice(n=4)
def test_cached_decode_staleness0_bit_exact_under_sharding(graph, codes):
    """Satellite (ISSUE 3): CachedDecodeBackend at staleness 0 over a
    shard-partitioned frontier reproduces the uncached sharded run exactly
    (the stacked batch's per-block `valid` mask keeps padding rows out of
    the cache, and every access re-decodes at staleness 0)."""
    mesh = _mesh(N_SHARDS)
    l_plain = _run_stream(graph, codes, _cfg("sharded:gather"),
                          N_SHARDS, mesh, steps=6, seed=7)
    l_cached = _run_stream(graph, codes,
                           _cfg("sharded:gather", cache_capacity=256,
                                cache_staleness=0),
                           N_SHARDS, mesh, steps=6, seed=7)
    assert l_plain == l_cached
