"""Streaming graph-engine tests (ISSUE 1): dedup-decode equivalence,
prefetch determinism + resume, isolated-node self-sampling, config plumbing
for the Algorithm-1 encoding knobs, and the import-health gate."""

import dataclasses
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_gnn import paper_gnn_config
from repro.core import embedding as emb_lib
from repro.core import lsh
from repro.graph import CSRMatrix, FrontierBatch, NeighborSampler, powerlaw_graph
from repro.graph.engine import (FullGraphBatch, GNNModel, PrefetchIterator,
                                SageBatchSource)
from repro.models import gnn
from repro.train import LoopConfig, init_gnn_train_state, make_gnn_train_step, run_training

KEY = jax.random.PRNGKey(0)
N = 1200


@pytest.fixture(scope="module")
def graph():
    return powerlaw_graph(0, N, avg_degree=8, n_classes=8, homophily=0.9)


@pytest.fixture(scope="module")
def cfg():
    base = paper_gnn_config("sage", n_nodes=N, n_classes=8, fanout=5)
    return dataclasses.replace(
        base, embedding=dataclasses.replace(base.embedding, c=16, m=8, d_c=64, d_m=64))


@pytest.fixture(scope="module")
def params(graph, cfg):
    adj, _ = graph
    codes = emb_lib.make_codes(KEY, cfg.embedding_config(), aux=adj)
    return GNNModel(cfg).init(KEY, codes=codes)


# ---------------------------------------------------------------------------
# dedup decode
# ---------------------------------------------------------------------------

def test_frontier_reconstructs_levels(graph, cfg):
    adj, _ = graph
    sampler = NeighborSampler(adj, cfg.fanouts, max_deg=32, seed=0)
    ids = np.random.default_rng(1).choice(N, 64, replace=False).astype(np.int32)
    levels = sampler.sample(ids, rng=np.random.default_rng(2))
    fb = FrontierBatch.from_levels(levels, pad_to=128)
    assert fb.unique.shape[0] % 128 == 0
    assert int(fb.n_unique) <= fb.unique.shape[0]
    # the frontier must be lossless: unique[index_maps[i]] == levels[i]
    for lvl, rebuilt in zip(levels, fb.levels()):
        np.testing.assert_array_equal(rebuilt, lvl)
    np.testing.assert_array_equal(fb.targets, ids)
    # and genuinely deduplicated
    assert int(fb.n_unique) == np.unique(np.concatenate(
        [l.ravel() for l in levels])).shape[0]


def test_dedup_decode_bit_identical(graph, cfg, params):
    """Dedup decode (one lookup over the frontier + gathers) must reproduce
    the naive per-position decode exactly on a seeded batch."""
    adj, _ = graph
    sampler = NeighborSampler(adj, cfg.fanouts, max_deg=32, seed=0)
    ids = np.random.default_rng(3).choice(N, 64, replace=False).astype(np.int32)
    levels = sampler.sample(ids, rng=np.random.default_rng(4))
    fb = FrontierBatch.from_levels(levels)

    model = GNNModel(cfg)
    h_naive = model.apply(params, [jnp.asarray(l) for l in levels])
    h_dedup = model.apply(params, jax.device_put(fb))
    np.testing.assert_array_equal(np.asarray(h_naive), np.asarray(h_dedup))


def test_dedup_decode_dense_kind(graph):
    """The frontier path is embedding-kind agnostic (dense table too)."""
    adj, _ = graph
    cfg = dataclasses.replace(
        paper_gnn_config("sage", n_nodes=N, n_classes=8, fanout=5, kind="dense"))
    sampler = NeighborSampler(adj, cfg.fanouts, max_deg=32, seed=0)
    params = GNNModel(cfg).init(KEY)
    ids = np.arange(32, dtype=np.int32)
    levels = sampler.sample(ids, rng=np.random.default_rng(5))
    fb = FrontierBatch.from_levels(levels)
    h_naive = GNNModel(cfg).apply(params, [jnp.asarray(l) for l in levels])
    h_dedup = GNNModel(cfg).apply(params, jax.device_put(fb))
    np.testing.assert_array_equal(np.asarray(h_naive), np.asarray(h_dedup))


def test_isolated_node_self_sampling():
    """Isolated nodes still self-sample through the frontier path."""
    # node 4 has no edges
    adj = CSRMatrix.from_edges([0, 1, 2], [1, 2, 3], n_nodes=5)
    sampler = NeighborSampler(adj, (3, 3), max_deg=4, seed=0)
    ids = np.array([4, 0], dtype=np.int32)
    fb = sampler.sample_frontier(ids, pad_to=8, rng=np.random.default_rng(0))
    levels = fb.levels()
    # every neighbour drawn for isolated node 4 is node 4 itself
    np.testing.assert_array_equal(levels[1][0], np.full(3, 4))
    np.testing.assert_array_equal(levels[2][0], np.full((3, 3), 4))
    assert 4 in np.asarray(fb.unique[:int(fb.n_unique)])


# ---------------------------------------------------------------------------
# prefetch pipeline
# ---------------------------------------------------------------------------

def _sources(graph, cfg, batch_size=32, seed=7):
    adj, labels = graph
    def make():
        sampler = NeighborSampler(adj, cfg.fanouts, max_deg=32, seed=0)
        return SageBatchSource(sampler, np.arange(N), labels, batch_size, seed=seed)
    return make


def test_prefetch_matches_sync_sequence(graph, cfg):
    make = _sources(graph, cfg)
    sync = make()
    expect = [sync.next_batch() for _ in range(8)]
    pf = PrefetchIterator(make(), depth=3)
    try:
        got = [pf.next_batch() for _ in range(8)]
    finally:
        pf.close()
    for a, b in zip(expect, got):
        np.testing.assert_array_equal(a["labels"], np.asarray(b["labels"]))
        np.testing.assert_array_equal(a["frontier"].unique,
                                      np.asarray(b["frontier"].unique))
        for ma, mb in zip(a["frontier"].index_maps, b["frontier"].index_maps):
            np.testing.assert_array_equal(ma, np.asarray(mb))


def test_prefetch_state_resume(graph, cfg):
    """state_dict reflects *consumed* batches (not produced-ahead ones), so
    restoring it replays exactly the un-consumed suffix."""
    make = _sources(graph, cfg)
    pf = PrefetchIterator(make(), depth=3)
    try:
        for _ in range(3):
            pf.next_batch()
        snap = pf.state_dict()
        expect = [np.asarray(pf.next_batch()["labels"]) for _ in range(3)]
    finally:
        pf.close()
    assert snap == {"step": 3, "seed": 7, "shard": 0, "n_shards": 1}

    pf2 = PrefetchIterator(make(), depth=3)
    try:
        pf2.next_batch()          # run ahead, then rewind via load_state_dict
        pf2.load_state_dict(snap)
        got = [np.asarray(pf2.next_batch()["labels"]) for _ in range(3)]
    finally:
        pf2.close()
    np.testing.assert_array_equal(np.stack(expect), np.stack(got))


def test_prefetch_reusable_after_close(graph, cfg):
    """close() pauses (rewinds to last consumed batch); next_batch resumes
    the exact sequence — so run_training may close a caller-owned iterator
    and the caller can keep using it (e.g. staged training)."""
    make = _sources(graph, cfg)
    sync = make()
    expect = [np.asarray(sync.next_batch()["labels"]) for _ in range(6)]
    pf = PrefetchIterator(make(), depth=3)
    try:
        got = [np.asarray(pf.next_batch()["labels"]) for _ in range(3)]
        pf.close()                       # drops produced-ahead batches
        got += [np.asarray(pf.next_batch()["labels"]) for _ in range(3)]
    finally:
        pf.close()
    np.testing.assert_array_equal(np.stack(expect), np.stack(got))


def test_prefetch_propagates_source_errors(graph, cfg):
    class Boom:
        def next_batch(self):
            raise RuntimeError("boom")
    pf = PrefetchIterator(Boom(), depth=1)
    try:
        with pytest.raises(RuntimeError, match="boom"):
            pf.next_batch()
    finally:
        pf.close()


# ---------------------------------------------------------------------------
# unified model API + engine training
# ---------------------------------------------------------------------------

def test_unified_api_dispatch(graph, cfg, params):
    adj, _ = graph
    model = GNNModel(cfg)
    with pytest.raises(TypeError):
        model.apply(params, object())
    gcfg = dataclasses.replace(cfg, model="gcn")
    gparams = GNNModel(gcfg).init(
        KEY, codes=emb_lib.make_codes(KEY, gcfg.embedding_config(), aux=adj))
    h = GNNModel(gcfg).apply(gparams, FullGraphBatch(
        adj.with_self_loops().normalized("sym")))
    assert h.shape == (N, cfg.hidden)


def test_engine_trains_through_generic_loop(graph, cfg):
    """make_gnn_train_step + PrefetchIterator + run_training: loss drops."""
    adj, labels = graph
    codes = emb_lib.make_codes(KEY, cfg.embedding_config(), aux=adj)
    state = init_gnn_train_state(KEY, cfg, codes=codes)
    sampler = NeighborSampler(adj, cfg.fanouts, max_deg=32, seed=0)
    source = SageBatchSource(sampler, np.arange(N), labels, 128, seed=0)
    data_iter = PrefetchIterator(source, depth=2)
    res = run_training(make_gnn_train_step(cfg), state, data_iter,
                       LoopConfig(total_steps=30))
    assert np.mean(res.losses[-5:]) < np.mean(res.losses[:5]) - 0.1


# ---------------------------------------------------------------------------
# encoding-knob plumbing (threshold / hops)
# ---------------------------------------------------------------------------

def test_threshold_and_hops_plumbed(graph):
    adj, _ = graph
    base = emb_lib.EmbeddingConfig(kind="hash_full", n_entities=N, d_e=32,
                                   c=16, m=8, d_c=64, d_m=64)
    for threshold, hops in (("zero", 1), ("median", 2)):
        cfg = dataclasses.replace(base, threshold=threshold, hops=hops)
        got = emb_lib.make_codes(KEY, cfg, aux=adj)
        want = lsh.encode_lsh(KEY, adj, 16, 8, threshold=threshold, hops=hops)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # and the knob actually changes the encoding
        default = emb_lib.make_codes(KEY, base, aux=adj)
        assert not np.array_equal(np.asarray(got), np.asarray(default))


def test_spec_plumbs_encoding_knobs():
    cfg = paper_gnn_config("sage", n_nodes=100, n_classes=4)
    spec = dataclasses.replace(cfg.embedding, threshold="zero", hops=2)
    ecfg = dataclasses.replace(cfg, embedding=spec).embedding_config()
    assert ecfg.threshold == "zero" and ecfg.hops == 2


# ---------------------------------------------------------------------------
# tooling: import-health gate
# ---------------------------------------------------------------------------

def test_check_imports_tool():
    """The collect gate passes on the current tree (missing optional deps
    must skip, never break collection)."""
    root = Path(__file__).resolve().parents[1]
    proc = subprocess.run(
        [sys.executable, str(root / "tools" / "check_imports.py"), "--src-only"],
        capture_output=True, text=True, cwd=str(root), timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
