"""GraphRuntime (ISSUE 4): the declarative spec front door.

Asserts the redesign's contracts:
  (a) spec-built training is bit-identical to the hand-wired PR-1 pipeline
      (graph → codes → state → sampler → source → step) for 5 steps;
  (b) ``GraphInferenceEngine.embed`` matches ``GNNModel.apply`` on the same
      frontier (miss-only cached decode is bitwise-invisible at serving);
  (c) spec → checkpoint → resume round-trips exactly (spec rides in the
      manifest; ``GraphRuntime.resume`` rebuilds the pipeline from it);
  (d) a sharded spec is a pure field change (``multidevice``-marked);
  plus: cached-pallas decode is a pure field change, the miss-only cache
  lookup is bitwise-equal to the select-based one, and specs survive JSON.
"""

import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs.paper_gnn import paper_gnn_config
from repro.core import embedding as emb_lib
from repro.core.backend import CachedDecodeBackend, CacheState
from repro.graph import NeighborSampler, powerlaw_graph
from repro.graph.engine import (GNNModel, SageBatchSource,
                                ShardedSageBatchSource)
from repro.graph.generate import train_val_test_split
from repro.graph.runtime import (FullGraphSource, GraphRuntime, GraphSource,
                                 RuntimeSpec)
from repro.optim import AdamWConfig
from repro.train import init_gnn_train_state, make_gnn_train_step

KEY = jax.random.PRNGKey(0)
N = 1200
BATCH = 64
OPT = AdamWConfig(lr=1e-2, weight_decay=0.0)
GRAPH_SRC = GraphSource(kind="powerlaw", seed=0, n_nodes=N, n_classes=8,
                        avg_degree=8, homophily=0.9)


def _cfg(**emb_kw):
    base = paper_gnn_config("sage", n_nodes=N, n_classes=8, fanout=5)
    return dataclasses.replace(base, embedding=dataclasses.replace(
        base.embedding, c=16, m=8, d_c=64, d_m=64, lookup_impl="gather",
        **emb_kw))


def _spec(**kw):
    spec = RuntimeSpec(graph=GRAPH_SRC, model=_cfg(), optimizer=OPT,
                       batch_size=BATCH, prefetch_depth=0)
    return spec.with_updates(**kw) if kw else spec


@pytest.fixture(scope="module")
def graph():
    return GRAPH_SRC.build()


# ---------------------------------------------------------------------------
# (a) spec-built training == hand-wired PR-1 pipeline, bitwise
# ---------------------------------------------------------------------------

def _handwired_losses(graph, cfg, n_steps):
    """The exact pre-runtime wiring from examples/train_gnn_hash.py (PR 1)."""
    adj, labels = graph
    codes = np.asarray(emb_lib.make_codes(KEY, cfg.embedding_config(),
                                          aux=adj))
    state = init_gnn_train_state(KEY, cfg, codes=codes)
    step = jax.jit(make_gnn_train_step(cfg, OPT))
    sampler = NeighborSampler(adj, cfg.fanouts, max_deg=64, seed=0)
    tr, _va, _te = train_val_test_split(0, N)
    src = SageBatchSource(sampler, tr, labels, BATCH, seed=0)
    losses = []
    for _ in range(n_steps):
        state, m = step(state, jax.device_put(src.next_batch()))
        losses.append(float(m["loss"]))
    return losses, state


def test_spec_training_bit_identical_to_handwired(graph):
    handwired, _ = _handwired_losses(graph, _cfg(), 5)
    rt = GraphRuntime.from_spec(_spec(), graph=graph)
    res = rt.train(5)
    rt.close()
    assert res.losses == handwired          # bitwise, not approx


def test_prefetch_is_a_knob_not_a_code_path(graph):
    """prefetch_depth must not change the batch stream (exact resume
    semantics carry over from the engine)."""
    sync = GraphRuntime.from_spec(_spec(prefetch_depth=0), graph=graph)
    pf = GraphRuntime.from_spec(_spec(prefetch_depth=2), graph=graph)
    try:
        assert sync.train(4).losses == pf.train(4).losses
    finally:
        sync.close()
        pf.close()


def test_cached_pallas_is_a_spec_field_change(graph):
    """1-shard default → cached-pallas decode is a ``with_updates`` call;
    pallas forward is bitwise the gather oracle (PR 2) and staleness-0
    caching is bit-exact, so the 5-step trajectory must not move."""
    base = GraphRuntime.from_spec(_spec(), graph=graph)
    cached = GraphRuntime.from_spec(
        _spec(lookup_impl="pallas", cache_capacity=2048, cache_staleness=0),
        graph=graph)
    try:
        assert base.train(5).losses == cached.train(5).losses
    finally:
        base.close()
        cached.close()


# ---------------------------------------------------------------------------
# (b) serving engine == direct model forward on the same frontier
# ---------------------------------------------------------------------------

def test_engine_embed_matches_model_apply(graph):
    rt = GraphRuntime.from_spec(_spec(), graph=graph)
    rt.train(3)
    engine = rt.serve(serve_batch=32)
    model = GNNModel(rt.cfg, interpret=rt.interpret)
    ids = np.arange(24, dtype=np.int32)

    # request 0: cold cache (everything misses), request 1+: hot (the
    # frontier is content-keyed, so repeat requests resample identically)
    for request in range(3):
        fb = engine.frontier_for(ids)
        h_direct = np.asarray(model.apply(rt.params, jax.device_put(fb)))
        h_engine = engine.embed(ids)
        np.testing.assert_array_equal(h_engine, h_direct[:len(ids)])
    stats = engine.stats()
    assert stats["hits"] > 0, "hot requests must actually hit the cache"
    assert stats["rows_decoded"] < stats["rows_total"], \
        "miss-only decode must pay fewer rows than the full frontier"
    rt.close()


def test_engine_is_serving_protocol():
    from repro.serving import Engine
    from repro.serving.gnn import GraphInferenceEngine
    assert issubclass(GraphInferenceEngine, Engine)  # runtime_checkable


def test_missonly_lookup_bitwise_equals_select_lookup():
    """The miss-only cache path (host partition + padded miss-prefix) must
    return exactly what the select-based ``lookup`` returns, for any mix of
    hits / stale entries / absent ids / invalid padding rows."""
    rng = np.random.default_rng(0)
    d, C, U = 8, 16, 24
    cache = CachedDecodeBackend(staleness=0)
    state = CacheState.create(C, d)
    table = jax.numpy.asarray(rng.standard_normal((64, d)).astype(np.float32))
    decode = lambda ids: table[ids]

    # warm the cache with ids 0..15
    warm = np.arange(16, dtype=np.int32)
    _, state = cache.lookup(state, jax.numpy.asarray(warm), decode)

    ids = np.concatenate([warm[:12], np.arange(40, 48, dtype=np.int32),
                          np.full(4, 0, np.int32)]).astype(np.int32)
    valid = np.concatenate([np.ones(20, bool), np.zeros(4, bool)])

    out_ref, state_ref = cache.lookup(
        state, jax.numpy.asarray(ids), decode,
        valid=jax.numpy.asarray(valid))

    perm, n_miss = CachedDecodeBackend.plan_missonly(
        np.asarray(state.node_ids), ids, valid)
    assert n_miss == 8                       # exactly the absent ids
    assert set(ids[perm[:n_miss]]) == set(range(40, 48))
    n_dec = 8
    out_mo, state_mo = cache.lookup_missonly(
        state, jax.numpy.asarray(ids[perm]), decode, n_dec,
        valid=jax.numpy.asarray(valid[perm]))

    inv = np.empty_like(perm)
    inv[perm] = np.arange(U)
    np.testing.assert_array_equal(np.asarray(out_mo)[inv][valid],
                                  np.asarray(out_ref)[valid])
    # identical accounting and identical cached contents (as id→value sets)
    assert int(state_mo.hits) == int(state_ref.hits)
    assert int(state_mo.misses) == int(state_ref.misses)
    ref_map = {int(i): np.asarray(state_ref.values)[k]
               for k, i in enumerate(np.asarray(state_ref.node_ids)) if i >= 0}
    mo_map = {int(i): np.asarray(state_mo.values)[k]
              for k, i in enumerate(np.asarray(state_mo.node_ids)) if i >= 0}
    assert ref_map.keys() == mo_map.keys()
    for k in ref_map:
        np.testing.assert_array_equal(ref_map[k], mo_map[k])


# ---------------------------------------------------------------------------
# (c) spec → checkpoint → resume round-trip
# ---------------------------------------------------------------------------

def test_spec_checkpoint_resume_roundtrip(graph, tmp_path):
    full_spec = _spec(ckpt_dir=str(tmp_path / "full"), ckpt_every=4)
    rt_full = GraphRuntime.from_spec(full_spec, graph=graph)
    res_full = rt_full.train(8)
    rt_full.close()

    part_spec = _spec(ckpt_dir=str(tmp_path / "part"), ckpt_every=4)
    rt_part = GraphRuntime.from_spec(part_spec, graph=graph)
    rt_part.train(4)
    rt_part.close()

    # resume knows NOTHING but the directory: the spec comes from the
    # checkpoint manifest and must round-trip exactly, and the trained
    # params must be live IMMEDIATELY (evaluate/serve before any train)
    rt_res = GraphRuntime.resume(str(tmp_path / "part"), graph=graph)
    assert rt_res.spec == part_spec
    for a, b in zip(jax.tree.leaves(rt_part.state["params"]),
                    jax.tree.leaves(rt_res.state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    res_tail = rt_res.train(8)
    assert res_tail.resumed_from == 4
    assert res_tail.losses == res_full.losses[4:]
    for a, b in zip(jax.tree.leaves(rt_full.state["params"]),
                    jax.tree.leaves(rt_res.state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    rt_res.close()


def test_spec_json_roundtrip():
    spec = _spec(lookup_impl="pallas", cache_capacity=512, n_shards=2,
                 total_steps=77)
    restored = RuntimeSpec.from_json(spec.to_json())
    assert restored == spec
    # and through a plain-dict (manifest) cycle too
    assert RuntimeSpec.from_dict(json.loads(json.dumps(spec.to_dict()))) == spec


def test_with_updates_routes_fields():
    spec = _spec()
    s = spec.with_updates(n_shards=4, lookup_impl="sharded:gather", hidden=64)
    assert s.n_shards == 4
    assert s.model.embedding.lookup_impl == "sharded:gather"
    assert s.model.hidden == 64
    with pytest.raises(TypeError):
        spec.with_updates(not_a_field=1)


# ---------------------------------------------------------------------------
# full-graph model family through the same front door
# ---------------------------------------------------------------------------

def test_fullgraph_runtime_train_and_evaluate(graph):
    cfg = dataclasses.replace(
        paper_gnn_config("gcn", n_nodes=N, n_classes=8),
        embedding=dataclasses.replace(_cfg().embedding))
    rt = GraphRuntime.from_spec(_spec(model=cfg), graph=graph)
    assert isinstance(rt.source, FullGraphSource)
    res = rt.train(12)
    assert res.losses[-1] < res.losses[0]
    ev = rt.evaluate("val")
    assert ev["n"] == len(rt.splits["val"])
    assert 0.0 <= ev["accuracy"] <= 1.0
    # evaluate is deterministic
    assert rt.evaluate("test") == rt.evaluate("test")
    rt.close()


def test_evaluate_counts_every_split_node_once(graph):
    rt = GraphRuntime.from_spec(_spec(), graph=graph)
    ev = rt.evaluate("val", batch_size=48)   # forces a wrapped final batch
    assert ev["n"] == len(rt.splits["val"])
    assert rt.evaluate("val", batch_size=48) == ev   # deterministic
    rt.close()


# ---------------------------------------------------------------------------
# (d) sharded spec: a field change, under the multidevice CI leg
# ---------------------------------------------------------------------------

@pytest.mark.multidevice(4)
def test_sharded_spec_is_a_field_change(graph):
    spec = _spec(lookup_impl="sharded:gather")
    rt1 = GraphRuntime.from_spec(spec, graph=graph)
    res1 = rt1.train(3)
    rt1.close()

    rt4 = GraphRuntime.from_spec(spec.with_updates(n_shards=4), graph=graph)
    assert isinstance(rt4.source, ShardedSageBatchSource)
    assert rt4.mesh is not None and rt4.mesh.shape["data"] == 4
    res4 = rt4.train(3)
    rt4.close()
    # the (seed, shard, step) contract: step-0 forward loss is bitwise equal
    assert res1.losses[0] == res4.losses[0]


def test_sharded_spec_fails_loudly_without_devices(graph):
    if jax.device_count() >= 4:
        pytest.skip("only meaningful on a single-device run")
    with pytest.raises(ValueError, match="n_shards"):
        GraphRuntime.from_spec(_spec(n_shards=4), graph=graph)
