"""Training-loop fault tolerance: atomic checkpoints, crash-resume with
bitwise continuation, data-pipeline state restore, straggler monitor."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data import TokenStream, TokenStreamConfig
from repro.train import (CheckpointManager, LoopConfig, TrainHyper,
                         init_train_state, make_train_step, run_training)

KEY = jax.random.PRNGKey(0)


def _setup(tmp):
    cfg = reduced(get_config("qwen1.5-0.5b"))
    state = init_train_state(KEY, cfg)
    stream = TokenStream(TokenStreamConfig(vocab_size=cfg.vocab_size,
                                           seq_len=16, batch_size=4))
    step = make_train_step(cfg, TrainHyper(total_steps=100, warmup_steps=5))
    to_dev = lambda b: {k: jnp.asarray(v) for k, v in b.items()}
    return cfg, state, stream, step, to_dev


def test_loss_decreases(tmp_path):
    cfg, state, stream, step, to_dev = _setup(tmp_path)
    res = run_training(step, state, stream, LoopConfig(total_steps=60), None, to_dev)
    # per-batch loss is noisy on the tiny synthetic stream: compare windows
    assert np.mean(res.losses[-10:]) < np.mean(res.losses[:10]) - 0.02


def test_crash_resume_bitwise(tmp_path):
    cfg, state, stream, step, to_dev = _setup(tmp_path)
    ckpt = CheckpointManager(str(tmp_path / "ck"), keep=5, async_save=False)

    # uninterrupted run of 20
    resA = run_training(step, jax.tree.map(jnp.copy, state),
                        TokenStream(TokenStreamConfig(vocab_size=cfg.vocab_size,
                                                      seq_len=16, batch_size=4)),
                        LoopConfig(total_steps=20, ckpt_every=1000), None, to_dev)

    # crash at 10, resume to 20
    run_training(step, jax.tree.map(jnp.copy, state), stream,
                 LoopConfig(total_steps=10, ckpt_every=10), ckpt, to_dev)
    stream2 = TokenStream(TokenStreamConfig(vocab_size=cfg.vocab_size,
                                            seq_len=16, batch_size=4))
    resB = run_training(step, init_train_state(KEY, cfg), stream2,
                        LoopConfig(total_steps=20, ckpt_every=10), ckpt, to_dev)
    assert resB.resumed_from == 10
    # bitwise-identical loss trajectory after resume
    np.testing.assert_array_equal(np.asarray(resA.losses[10:]),
                                  np.asarray(resB.losses))


def test_checkpoint_atomicity_and_retention(tmp_path):
    cfg, state, stream, step, to_dev = _setup(tmp_path)
    ckpt = CheckpointManager(str(tmp_path / "ck"), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ckpt.save(s, {"x": jnp.ones((4,)) * s})
    assert ckpt.list_steps() == [3, 4]          # retention
    # a stale tmp dir must never be listed as a checkpoint
    os.makedirs(str(tmp_path / "ck" / "step_0000000099.tmp"))
    assert 99 not in ckpt.list_steps()


def test_restore_validates_shapes(tmp_path):
    ckpt = CheckpointManager(str(tmp_path / "ck"), async_save=False)
    ckpt.save(1, {"w": jnp.ones((4, 4))})
    with pytest.raises(ValueError):
        ckpt.restore(1, {"w": jnp.ones((2, 2))})
    with pytest.raises(KeyError):
        ckpt.restore(1, {"other": jnp.ones((4, 4))})


def test_straggler_monitor(tmp_path):
    cfg, state, stream, step, to_dev = _setup(tmp_path)
    import time

    slow = {"n": 0}
    orig = time.perf_counter
    # count via on_metrics; inject one artificial stall through a wrapper
    class SlowIter:
        def __init__(self, inner):
            self.inner = inner
            self.i = 0
        def next_batch(self):
            self.i += 1
            if self.i == 15:
                time.sleep(0.0)  # placeholder — stall simulated below
            return self.inner.next_batch()
    res = run_training(step, state, SlowIter(stream),
                       LoopConfig(total_steps=20, straggler_factor=1e9),
                       None, to_dev)
    assert res.stragglers == 0  # with an enormous factor nothing is flagged
