"""Unit + property tests for the compositional-code storage layout."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import codes

CM = st.sampled_from([(2, 128), (4, 64), (16, 32), (64, 8), (256, 16), (2, 1), (8, 3)])


def test_paper_bit_example():
    # paper §1: [2, 0, 3, 1, 0, 1] with c=4 -> "10 00 11 01 00 01"
    bits = codes.codes_to_bits(jnp.array([[2, 0, 3, 1, 0, 1]]), 4, 6)
    assert "".join(str(int(b)) for b in np.asarray(bits[0])) == "100011010001"


def test_bit_count_formula():
    # 48 bits for (c=64, m=8) — paper §1's ALONE parametrization
    assert codes.n_bits(64, 8) == 48
    assert codes.n_words(64, 8) == 2
    assert codes.code_capacity(2, 24) == 2**24


def test_c_must_be_power_of_two():
    with pytest.raises(ValueError):
        codes.n_bits(3, 8)
    with pytest.raises(ValueError):
        codes.n_bits(1, 8)


@settings(max_examples=25, deadline=None)
@given(cm=CM, n=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(cm, n, seed):
    c, m = cm
    cds = jax.random.randint(jax.random.PRNGKey(seed), (n, m), 0, c)
    packed = codes.pack_codes(cds, c, m)
    assert packed.shape == (n, codes.n_words(c, m))
    assert packed.dtype == jnp.uint32
    back = codes.unpack_codes(packed, c, m)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(cds))


@settings(max_examples=25, deadline=None)
@given(cm=CM, n=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_bits_roundtrip(cm, n, seed):
    c, m = cm
    cds = jax.random.randint(jax.random.PRNGKey(seed), (n, m), 0, c)
    bits = codes.codes_to_bits(cds, c, m)
    assert bits.shape == (n, codes.n_bits(c, m))
    np.testing.assert_array_equal(
        np.asarray(codes.bits_to_codes(bits, c, m)), np.asarray(cds))


def test_collision_count():
    arr = jnp.array([[1, 2], [1, 2], [3, 4], [1, 2]])
    assert codes.count_collisions(arr) == 2  # two duplicates of row 0
    assert codes.count_collisions(jnp.array([[1], [2], [3]])) == 0
