"""Mixed-precision + quantized decode (ISSUE 6): policy validation, the
per-backend dtype contract, fused-int8 parity between backends, and the
end-to-end acceptance bar — bf16 / int8 step-0 loss within the documented
``core.backend.DRIFT_BOUNDS`` on EVERY decode backend."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_gnn import paper_gnn_config
from repro.core.backend import (
    CachedDecodeBackend,
    DEFAULT_POLICY,
    DRIFT_BOUNDS,
    MixedPrecisionPolicy,
    get_backend,
)
from repro.graph.runtime import GraphRuntime, GraphSource, RuntimeSpec

# ---------------- policy object ----------------


def test_policy_rejects_unknown_quantize():
    with pytest.raises(ValueError, match="quantize"):
        MixedPrecisionPolicy(quantize="int4")


def test_policy_rejects_non_f32_reduce():
    with pytest.raises(ValueError, match="reduce_dtype"):
        MixedPrecisionPolicy(reduce_dtype="bfloat16")


def test_default_policy_is_noop():
    assert DEFAULT_POLICY.param_dtype is None
    assert DEFAULT_POLICY.quantize == "none"
    assert DEFAULT_POLICY.reduce_dtype == "float32"


# ---------------- dtype contract, every backend ----------------

@pytest.mark.parametrize("name", [
    "gather", "onehot", "pallas", "sharded:gather", "owner:gather"])
def test_dtype_contract_every_backend(name):
    pol = MixedPrecisionPolicy(param_dtype="bfloat16",
                               compute_dtype="bfloat16", quantize="int8")
    be = get_backend(name, interpret=True, policy=pol)
    c = be.dtype_contract()
    assert c["storage"] == "int8 values + float32 scales"
    assert c["accumulate"] == "float32"
    assert c["output"] == "float32"
    # the f32-storage contract states the param dtype verbatim
    c32 = get_backend(name, interpret=True, policy=MixedPrecisionPolicy(
        param_dtype="float32", compute_dtype="float32")).dtype_contract()
    assert c32["storage"] == "float32"
    assert c32["accumulate"] == "float32"


def test_cached_backend_contract_names_base():
    pol = MixedPrecisionPolicy(param_dtype="bfloat16", quantize="int8")
    base = get_backend("gather", policy=pol)
    c = CachedDecodeBackend.dtype_contract(base)
    assert c["base"] == "gather"
    assert c["accumulate"].startswith("float32")
    assert c["output"] == "float32"


# ---------------- int8 parity between backends ----------------


def test_int8_decode_parity_across_backends():
    """All three backends decode the SAME dequantized values (the shared
    straight-through ``quantize_dequantize`` / the kernel's fused scales) —
    only the m-term summation order differs (sequential gather vs matmul
    contraction), so outputs agree to f32 accumulation-order tolerance."""
    key = jax.random.PRNGKey(0)
    codes = jax.random.randint(key, (256, 8), 0, 128)
    cb = jax.random.normal(jax.random.fold_in(key, 1), (8, 128, 128))
    pol = MixedPrecisionPolicy(quantize="int8")
    out = {n: np.asarray(get_backend(n, interpret=True, policy=pol)
                         .decode(codes, cb))
           for n in ("gather", "onehot", "pallas")}
    np.testing.assert_allclose(out["gather"], out["onehot"],
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out["pallas"], out["gather"],
                               rtol=1e-5, atol=1e-5)


def test_bf16_param_dtype_casts_storage():
    """param_dtype=bfloat16 must decode exactly what a pre-cast bf16
    codebook would, and stay within the documented bf16 drift bound."""
    key = jax.random.PRNGKey(1)
    codes = jax.random.randint(key, (128, 8), 0, 16)
    cb = jax.random.normal(jax.random.fold_in(key, 1), (8, 16, 128))
    be = get_backend("gather", policy=MixedPrecisionPolicy(
        param_dtype="bfloat16"))
    out = np.asarray(be.decode(codes, cb))
    pre = np.asarray(get_backend("gather").decode(
        codes, cb.astype(jnp.bfloat16)))
    np.testing.assert_array_equal(out, pre)
    f32 = np.asarray(get_backend("gather").decode(codes, cb))
    drift = np.abs(out - f32).max() / max(np.abs(f32).max(), 1e-12)
    assert drift <= DRIFT_BOUNDS["bfloat16"], drift


# ---------------- end-to-end drift: every backend ----------------

N_NODES, N_CLASSES = 600, 8


@pytest.fixture(scope="module")
def graph():
    return GraphSource(kind="powerlaw", seed=0, n_nodes=N_NODES,
                       n_classes=N_CLASSES).build()


def _spec(lookup_impl, n_shards=1, **emb):
    spec = RuntimeSpec(
        graph=GraphSource(kind="powerlaw", seed=0, n_nodes=N_NODES,
                          n_classes=N_CLASSES),
        model=paper_gnn_config("sage", n_nodes=N_NODES, n_classes=N_CLASSES,
                               fanout=3),
        batch_size=32, pad_to=128, n_shards=n_shards, log_every=1,
        data_seed=1, prefetch_depth=0,
    )
    return spec.with_updates(c=16, m=8, d_c=128, d_m=32,
                             lookup_impl=lookup_impl, **emb)


def _step0_loss(graph, lookup_impl, n_shards=1, **emb):
    rt = GraphRuntime.from_spec(_spec(lookup_impl, n_shards, **emb),
                                graph=graph)
    losses = []
    try:
        rt.train(1, on_metrics=lambda s, m: losses.append(float(m["loss"])))
    finally:
        rt.close()
    assert losses and np.isfinite(losses[0])
    return losses[0]


def _assert_drift(graph, lookup_impl, n_shards=1, **emb):
    base = _step0_loss(graph, lookup_impl, n_shards, **emb)
    for variant, bound_key in ((dict(param_dtype="bfloat16"), "bfloat16"),
                               (dict(quantize="int8"), "int8")):
        loss = _step0_loss(graph, lookup_impl, n_shards, **emb, **variant)
        drift = abs(loss - base) / max(abs(base), 1e-12)
        assert drift <= DRIFT_BOUNDS[bound_key], (
            f"{lookup_impl} {variant}: step-0 loss drift {drift:.4g} "
            f"exceeds DRIFT_BOUNDS[{bound_key!r}]={DRIFT_BOUNDS[bound_key]}")


@pytest.mark.parametrize("impl", ["gather", "onehot", "pallas"])
def test_step0_loss_drift_within_bounds(graph, impl):
    _assert_drift(graph, impl)


def test_step0_loss_drift_within_bounds_cached(graph):
    _assert_drift(graph, "gather", cache_capacity=256, cache_staleness=2)


@pytest.mark.multidevice(n=4)
def test_step0_loss_drift_within_bounds_sharded(graph):
    _assert_drift(graph, "sharded:gather", n_shards=4)


@pytest.mark.multidevice(n=4)
def test_step0_loss_drift_within_bounds_owner(graph):
    _assert_drift(graph, "owner:gather", n_shards=4)
