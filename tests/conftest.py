# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see ONE
# device; multi-device parallelism tests run in subprocesses (test_parallel).
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
