# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see ONE
# device; multi-device tests either run in subprocesses (test_parallel) or
# carry the `multidevice` marker and only execute under the forced-host-
# device CI leg (`tools/ci.sh --multidevice`).
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multidevice(n=2): needs >= n jax devices in THIS process; skips "
        "(never errors) on fewer — run via tools/ci.sh --multidevice, which "
        "forces 8 host devices and selects only these tests")


def pytest_runtest_setup(item):
    for mark in item.iter_markers(name="multidevice"):
        require_devices(int(mark.kwargs.get("n", mark.args[0] if mark.args else 2)))


def require_devices(n: int = 2):
    """Device-count twin of ``pytest.importorskip``: skip — never error —
    when the runtime exposes fewer than ``n`` jax devices.  Returns the
    device list so callers can build meshes from a prefix of it."""
    import jax
    if jax.device_count() < n:
        pytest.skip(f"needs >= {n} jax devices, have {jax.device_count()} "
                    f"(run under XLA_FLAGS=--xla_force_host_platform_"
                    f"device_count=8, see tools/ci.sh --multidevice)")
    return jax.devices()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
