"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward + one train step on CPU, asserting output shapes + finiteness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced
from repro.configs.archs import ASSIGNED
from repro.models import init_cache, init_lm, lm_forward
from repro.nn.rope import default_positions
from repro.train.step import TrainHyper, init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    shape = (B, S, cfg.n_codebooks) if cfg.input_mode == "audio_tokens" else (B, S)
    tokens = jax.random.randint(KEY, shape, 0, cfg.vocab_size)
    b = {"tokens": tokens, "labels": tokens}
    if cfg.input_mode == "tokens_mrope":
        b["positions"] = default_positions(B, S, "mrope")
    return b


def test_all_assigned_archs_registered():
    assert set(ASSIGNED) <= set(list_archs())
    assert len(ASSIGNED) == 10


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    spec = {
        "zamba2-7b": dict(n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
                          d_ff=14336, vocab_size=32000, ssm_state=64, family="hybrid"),
        "qwen1.5-0.5b": dict(n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
                             d_ff=2816, vocab_size=151936, qkv_bias=True, family="dense"),
        "internlm2-20b": dict(n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
                              d_ff=16384, vocab_size=92544, family="dense"),
        "chatglm3-6b": dict(n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2,
                            d_ff=13696, vocab_size=65024, family="dense"),
        "yi-9b": dict(n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
                      d_ff=11008, vocab_size=64000, family="dense"),
        "musicgen-large": dict(n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
                               d_ff=8192, vocab_size=2048, family="audio"),
        "mamba2-2.7b": dict(n_layers=64, d_model=2560, vocab_size=50280,
                            ssm_state=128, family="ssm"),
        "dbrx-132b": dict(n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
                          d_ff=10752, vocab_size=100352, n_experts=16,
                          moe_top_k=4, family="moe"),
        "granite-moe-3b-a800m": dict(n_layers=32, d_model=1536, n_heads=24,
                                     n_kv_heads=8, d_ff=512, vocab_size=49155,
                                     n_experts=40, moe_top_k=8, family="moe"),
        "qwen2-vl-7b": dict(n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
                            d_ff=18944, vocab_size=152064, family="vlm"),
    }[arch]
    for k, v in spec.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    batch = _batch(cfg)
    state = init_train_state(KEY, cfg)
    B, S = batch["tokens"].shape[:2]

    logits, _ = lm_forward(state["params"], batch["tokens"], cfg,
                           positions=batch.get("positions"))
    exp = ((B, S, cfg.n_codebooks, cfg.vocab_padded)
           if cfg.input_mode == "audio_tokens" else (B, S, cfg.vocab_padded))
    assert logits.shape == exp
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    step = make_train_step(cfg, TrainHyper(total_steps=10))
    new_state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # params actually changed
    before = jax.tree.leaves(state["params"])
    after = jax.tree.leaves(new_state["params"])
    changed = any(
        a.dtype.kind == "f" and not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(after, before))
    assert changed, f"{arch}: no parameter changed after a train step"


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-2.7b", "zamba2-7b",
                                  "dbrx-132b", "musicgen-large", "qwen2-vl-7b"])
def test_reduced_decode_consistency(arch):
    cfg = reduced(get_config(arch))
    p = init_lm(KEY, cfg)
    B, S = 2, 8
    shape = (B, S, cfg.n_codebooks) if cfg.input_mode == "audio_tokens" else (B, S)
    tokens = jax.random.randint(KEY, shape, 0, cfg.vocab_size)
    full, _ = lm_forward(p, tokens, cfg)
    cache = init_cache(cfg, B, 16, jnp.float32)
    outs = []
    for t in range(S):
        lt, cache = lm_forward(p, tokens[:, t:t + 1], cfg, cache=cache)
        outs.append(lt)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate(outs, 1)),
                               rtol=5e-3, atol=5e-3)


def test_microbatch_equivalence():
    """k-microbatch accumulation == single-batch gradients (same update)."""
    cfg = reduced(get_config("qwen1.5-0.5b"))
    batch = _batch(cfg, B=4, S=16)
    s1 = init_train_state(KEY, cfg)
    s2 = jax.tree.map(lambda x: x, s1)
    st1, m1 = make_train_step(cfg, TrainHyper(total_steps=10, microbatches=1))(s1, batch)
    st2, m2 = make_train_step(cfg, TrainHyper(total_steps=10, microbatches=2))(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(st1["params"]), jax.tree.leaves(st2["params"])):
        if a.dtype.kind == "f":
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)
