"""Docs drift gate (ISSUE 8 satellite): tools/check_docs.py both passes on
the real docs AND fails loudly when a documented name disappears — the gate
must cut in both directions or it gates nothing."""

import re
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))

import check_docs  # noqa: E402


def test_required_names_come_from_live_code():
    req = check_docs.required_names()
    # registry backends, incl. both PR-8 compression families
    for name in ("gather", "onehot", "pallas", "sharded", "owner",
                 "hashemb", "tt"):
        assert name in req, name
    # spec fields across both dataclasses
    for name in ("lookup_impl", "tt_rank", "quantize", "batching",
                 "owner_cap", "owner_unique_cap", "cache_plan_misses",
                 "codes_placement"):
        assert name in req, name


def test_real_docs_pass():
    assert check_docs.main() == 0


def test_missing_name_fails_loudly(tmp_path, capsys):
    # redact one required backend name from a copy of the docs
    for page in (ROOT / "docs").glob("*.md"):
        text = page.read_text()
        text = re.sub(r"\bhashemb\b", "REDACTED", text)
        (tmp_path / page.name).write_text(text)
    assert check_docs.main(docs_dir=tmp_path) == 1
    err = capsys.readouterr().err
    assert "hashemb" in err and "undocumented" in err


def test_missing_spec_field_fails(tmp_path):
    for page in (ROOT / "docs").glob("*.md"):
        (tmp_path / page.name).write_text(
            re.sub(r"\btt_rank\b", "REDACTED", page.read_text()))
    missing = check_docs.missing_names(check_docs.docs_text(tmp_path))
    assert set(missing) == {"tt_rank"}
    assert missing["tt_rank"] == "configs.base.EmbeddingSpec field"


def test_missing_codes_placement_fails(tmp_path):
    # ISSUE 10: the new EmbeddingSpec field must be picked up automatically
    # — redacting it from a docs copy has to fail the gate
    for page in (ROOT / "docs").glob("*.md"):
        (tmp_path / page.name).write_text(
            re.sub(r"\bcodes_placement\b", "REDACTED", page.read_text()))
    missing = check_docs.missing_names(check_docs.docs_text(tmp_path))
    assert set(missing) == {"codes_placement"}
    assert missing["codes_placement"] == "configs.base.EmbeddingSpec field"
    assert check_docs.main(docs_dir=tmp_path) == 1


def test_empty_docs_dir_is_loud(tmp_path):
    with pytest.raises(SystemExit):
        check_docs.docs_text(tmp_path)


def test_word_boundary_matching_not_substring():
    # "tt" must not be satisfied by e.g. "attention"; "c" not by "cache"
    missing = check_docs.missing_names(
        "attention cache owner_capacity", required={
            "tt": "x", "c": "x", "owner_cap": "x"})
    assert set(missing) == {"tt", "c", "owner_cap"}
    assert check_docs.missing_names(
        "the `tt` family, field c, and owner_cap", required={
            "tt": "x", "c": "x", "owner_cap": "x"}) == {}
