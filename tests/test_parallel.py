"""Distribution tests — run in SUBPROCESSES with a forced 8-device host
platform so the main pytest process keeps its single-device view."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_moe_ep_equals_single_shard():
    run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.sharding import make_mesh, use_sharding
        from repro.nn.moe import MoEConfig, init_moe, moe_ffn, moe_ffn_ep
        key = jax.random.PRNGKey(0)
        cfg = MoEConfig(d_model=32, d_ff=64, n_experts=8, top_k=2, capacity_factor=4.0)
        p = init_moe(key, cfg)
        x = jax.random.normal(key, (64, 32))
        mesh = make_mesh((2, 4), ("data", "model"))
        with use_sharding(mesh):
            out_ep = jax.jit(lambda p, x: moe_ffn_ep(p, x, cfg))(p, x)
        np.testing.assert_allclose(np.asarray(out_ep), np.asarray(moe_ffn(p, x, cfg)),
                                   rtol=2e-4, atol=2e-4)
    """)


def test_sharded_train_step_matches_single_device():
    run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.parallel.sharding import make_mesh, use_sharding
        from repro.parallel.policy import state_shardings, batch_shardings
        from repro.train.step import TrainHyper, init_train_state, make_train_step
        cfg = reduced(get_config('qwen1.5-0.5b'))
        key = jax.random.PRNGKey(0)
        state = init_train_state(key, cfg)
        tokens = jax.random.randint(key, (8, 16), 0, cfg.vocab_size)
        batch = {'tokens': tokens, 'labels': tokens}
        step = make_train_step(cfg, TrainHyper(total_steps=10))
        s1, m1 = jax.jit(step)(jax.tree.map(lambda x: x, state), batch)

        mesh = make_mesh((4, 2), ('data', 'model'))
        with use_sharding(mesh):
            st_sh = state_shardings(cfg, jax.eval_shape(lambda: init_train_state(key, cfg)), mesh)
            b_sh = batch_shardings(jax.eval_shape(lambda: batch), mesh)
            s2, m2 = jax.jit(step, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, None))(state, batch)
        np.testing.assert_allclose(float(m1['loss']), float(m2['loss']), rtol=1e-4)
        for a, b in zip(jax.tree.leaves(s1['params']), jax.tree.leaves(s2['params'])):
            if a.dtype.kind == 'f':
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=5e-3, atol=5e-4)
        print('sharded == single-device OK')
    """)


def test_dp_over_model_strategy_matches():
    run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.parallel.sharding import make_mesh, use_sharding
        from repro.parallel.policy import (Strategy, rules_for, state_shardings,
                                           batch_shardings)
        from repro.train.step import TrainHyper, init_train_state, make_train_step
        cfg = reduced(get_config('qwen1.5-0.5b'))
        key = jax.random.PRNGKey(0)
        state = init_train_state(key, cfg)
        tokens = jax.random.randint(key, (8, 16), 0, cfg.vocab_size)
        batch = {'tokens': tokens, 'labels': tokens}
        step = make_train_step(cfg, TrainHyper(total_steps=10))
        s1, m1 = jax.jit(step)(jax.tree.map(lambda x: x, state), batch)
        strat = Strategy(dp_over_model=True)
        mesh = make_mesh((4, 2), ('data', 'model'))
        with use_sharding(mesh, rules_for(strat, mesh)):
            st_sh = state_shardings(cfg, jax.eval_shape(lambda: init_train_state(key, cfg)), mesh, strat)
            b_sh = batch_shardings(jax.eval_shape(lambda: batch), mesh, strat)
            s2, m2 = jax.jit(step, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, None))(state, batch)
        np.testing.assert_allclose(float(m1['loss']), float(m2['loss']), rtol=1e-4)
    """)


def test_elastic_restart_different_mesh():
    """Checkpoint on mesh (4,2), restore + continue on mesh (2,2) with 4
    devices — elastic-scaling restart (DESIGN.md §6)."""
    run_devices("""
        import os, tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.parallel.sharding import make_mesh, use_sharding
        from repro.parallel.policy import state_shardings, batch_shardings
        from repro.train.checkpoint import CheckpointManager
        from repro.train.step import TrainHyper, init_train_state, make_train_step
        cfg = reduced(get_config('qwen1.5-0.5b'))
        key = jax.random.PRNGKey(0)
        state = init_train_state(key, cfg)
        tokens = jax.random.randint(key, (8, 16), 0, cfg.vocab_size)
        batch = {'tokens': tokens, 'labels': tokens}
        step = make_train_step(cfg, TrainHyper(total_steps=10))

        d = tempfile.mkdtemp()
        ck = CheckpointManager(d, async_save=False)
        mesh8 = make_mesh((4, 2), ('data', 'model'))
        with use_sharding(mesh8):
            st_sh = state_shardings(cfg, jax.eval_shape(lambda: init_train_state(key, cfg)), mesh8)
            b_sh = batch_shardings(jax.eval_shape(lambda: batch), mesh8)
            s1, _ = jax.jit(step, in_shardings=(st_sh, b_sh), out_shardings=(st_sh, None))(state, batch)
        ck.save(1, s1)

        # "restart" on a smaller mesh: a real restart runs in a fresh process,
        # so re-create the step closure (also keeps jax<0.5 from reusing the
        # mesh8-traced jaxpr — its trace cache ignores the mesh context)
        step = make_train_step(cfg, TrainHyper(total_steps=10))
        mesh4 = make_mesh((2, 2), ('data', 'model'))
        restored, _ = ck.restore(1, jax.eval_shape(lambda: init_train_state(key, cfg)))
        with use_sharding(mesh4):
            st_sh4 = state_shardings(cfg, jax.eval_shape(lambda: init_train_state(key, cfg)), mesh4)
            restored = jax.tree.map(lambda arr, sh: jax.device_put(arr, sh), restored, st_sh4)
            b_sh4 = batch_shardings(jax.eval_shape(lambda: batch), mesh4)
            s2, m2 = jax.jit(step, in_shardings=(st_sh4, b_sh4), out_shardings=(st_sh4, None))(restored, batch)
        assert np.isfinite(float(m2['loss']))
        assert int(s2['step']) == 2
        print('elastic restart OK')
    """)


def test_gradient_compression_int8():
    run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.parallel.sharding import make_mesh, shard_map
        from repro.optim.compress import psum_compressed, compress_gradients_int8, decompress_gradients_int8

        g = jax.random.normal(jax.random.PRNGKey(0), (1024,))
        q, s = compress_gradients_int8(g)
        back = decompress_gradients_int8(q, s, g.shape)
        rel = float(jnp.linalg.norm(back - g) / jnp.linalg.norm(g))
        assert rel < 0.01, rel   # int8 block quant ~0.4% error

        mesh = make_mesh((8,), ('data',))
        gs = jax.random.normal(jax.random.PRNGKey(1), (8, 256))
        def worker(g, r):
            return psum_compressed(g, 'data', r)
        out, res = jax.jit(shard_map(worker, mesh=mesh,
            in_specs=(P('data', None), P('data', None)),
            out_specs=(P('data', None), P('data', None)), check_vma=False))(
            gs[:, None, :].reshape(8, 256) * 0 + gs, jnp.zeros((8, 256)))
        ref = jnp.mean(gs, axis=0)
        got = out[0]
        rel = float(jnp.linalg.norm(got - ref) / jnp.linalg.norm(ref))
        assert rel < 0.05, rel
        print('psum_compressed OK', rel)
    """, n_devices=8)


def test_gpipe_matches_sequential():
    run_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.sharding import make_mesh
        from repro.parallel.pipeline import gpipe, pipeline_reference
        S, M, mb, T, D = 4, 8, 2, 8, 16
        key = jax.random.PRNGKey(0)
        stage_params = {
            'w': jax.random.normal(key, (S, 2, D, D)) * 0.1,   # 2 layers/stage
            'b': jax.random.normal(jax.random.fold_in(key, 1), (S, 2, D)) * 0.1,
        }
        def stage_fn(p, x):
            for i in range(2):
                x = jnp.tanh(x @ p['w'][i] + p['b'][i])
            return x
        xs = jax.random.normal(jax.random.fold_in(key, 2), (M, mb, T, D))
        mesh = make_mesh((2, 4), ('data', 'model'))
        out = gpipe(stage_fn, stage_params, xs, mesh, axis='model')
        ref = pipeline_reference(stage_fn, stage_params, xs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)

        # differentiable: grads match the sequential reference
        def loss_pp(p):
            return (gpipe(stage_fn, p, xs, mesh, axis='model') ** 2).sum()
        def loss_ref(p):
            return (pipeline_reference(stage_fn, p, xs) ** 2).sum()
        g1 = jax.grad(loss_pp)(stage_params)
        g2 = jax.grad(loss_ref)(stage_params)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)
        print('gpipe fwd+bwd == sequential OK')
    """)
