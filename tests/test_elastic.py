"""Elastic sharded training (repro.elastic, docs/elastic.md).

Single-device tests cover the wire format, fault-injection semantics, the
manager state machine (against a stub runtime), crash-safe checkpoint
writes, and topology validation.  The ``multidevice``-marked tests run the
real thing under forced host devices (tools/ci.sh --elastic):

  * kill shard 2 of 4 at step 10 via FailurePlan → peer-transfer recovery
    (checkpoint dir never read) → rescale to 3 shards → the continued loss
    curve is BITWISE the never-failed 3-shard continuation from the same
    transferred state;
  * rescale a 4-shard checkpoint to 8 (and down to 2) shards → step-0
    loss bitwise identical to a native run at the new count.
"""

import dataclasses
import json
import os
import types

import numpy as np
import pytest

from repro.elastic import (DEGRADED, HEALTHY, RESCALING, Chunk,
                           ChunkCorruption, ElasticError, ElasticManager,
                           ElasticSpec, FailurePlan, chunk_payload,
                           pack_state, rescale_spec, transfer_state,
                           unpack_state)
from repro.graph.sampler import remap_shard_state
from repro.train import CheckpointManager, FenceInterrupt, TopologyMismatch
from repro.train.loop import LoopConfig, LoopResult

N = 600
BATCH = 48          # divisible by 4 (before) and 3 (after the rescale)


# ---------------------------------------------------------------------------
# transfer wire format
# ---------------------------------------------------------------------------

def _tree():
    return {"params": {"w": np.arange(12, dtype=np.float32).reshape(3, 4)},
            "opt": {"m": np.full((5,), 0.25)},
            "step": np.asarray(7, np.int32)}


def test_pack_unpack_roundtrip_bitwise():
    state = _tree()
    payload = pack_state(state, {"source": {"step": 9, "seed": 3}})
    out, extra = unpack_state(payload, _tree())
    assert extra == {"source": {"step": 9, "seed": 3}}
    for a, b in zip(np.asarray(out["params"]["w"]).ravel(),
                    state["params"]["w"].ravel()):
        assert a == b
    assert np.asarray(out["opt"]["m"]).dtype == state["opt"]["m"].dtype


def test_unpack_rejects_wrong_template():
    payload = pack_state(_tree())
    bad = _tree()
    bad["params"]["w"] = np.zeros((2, 2), np.float32)   # wrong shape
    with pytest.raises(ValueError, match="shape mismatch"):
        unpack_state(payload, bad)
    with pytest.raises(KeyError, match="missing leaf"):
        unpack_state(payload, {"params": {"extra_leaf": np.zeros(3)}})


def test_chunking_covers_payload_exactly():
    data = bytes(range(256)) * 10
    chunks = chunk_payload(data, 100)
    assert [c.seq for c in chunks] == list(range(len(chunks)))
    assert all(c.total == len(chunks) for c in chunks)
    assert b"".join(c.payload for c in chunks) == data
    assert all(c.verify() for c in chunks)
    # tampered payload keeps the sender CRC -> verify() must fail
    tampered = dataclasses.replace(chunks[0],
                                   payload=b"X" + chunks[0].payload[1:])
    assert not tampered.verify()
    assert chunk_payload(b"", 64)[0].payload == b""   # empty still framed


def test_transfer_detects_and_retransmits_corruption():
    data = os.urandom(5000)
    plan = FailurePlan(corrupt_chunks=(1, 3))
    out, stats = transfer_state(data, chunk_bytes=1000,
                                tamper=plan.tamper, max_retries=2)
    assert out == data                       # reassembly is bitwise
    assert stats.chunks == 5
    assert stats.retransmits == 2            # one clean re-send per tamper
    assert stats.bytes_transferred == len(data) + 2 * 1000
    assert stats.payload_bytes == len(data)


def test_transfer_raises_when_retries_exhausted():
    always = lambda seq, attempt: seq == 0   # every attempt corrupted
    with pytest.raises(ChunkCorruption, match="chunk 0"):
        transfer_state(b"abcdef", chunk_bytes=2, tamper=always, max_retries=1)
    # zero-retry budget: a single first-attempt corruption is fatal
    plan = FailurePlan(corrupt_chunks=(0,))
    with pytest.raises(ChunkCorruption):
        transfer_state(b"abcdef", chunk_bytes=2, tamper=plan.tamper,
                       max_retries=0)


# ---------------------------------------------------------------------------
# failure plan semantics
# ---------------------------------------------------------------------------

def test_failure_plan_predicates():
    plan = FailurePlan(kill=((2, 10),), heartbeat_delay=((1, 4, 2),),
                       corrupt_chunks=(3,))
    assert plan.alive(2, 9) and not plan.alive(2, 10) and not plan.alive(2, 99)
    assert plan.alive(0, 99)                      # other shards unaffected
    assert not plan.delayed(1, 3) and plan.delayed(1, 4)
    assert plan.delayed(1, 5) and not plan.delayed(1, 6)
    assert plan.tamper(3, 0) and not plan.tamper(3, 1)   # first attempt only
    assert not plan.tamper(2, 0)


# ---------------------------------------------------------------------------
# ElasticSpec
# ---------------------------------------------------------------------------

def test_elastic_spec_roundtrip_and_validation():
    spec = ElasticSpec(lease_steps=3, min_shards=2, chunk_bytes=4096,
                       max_transfer_retries=1, heartbeat_timeout_s=5.0)
    assert ElasticSpec.from_dict(json.loads(json.dumps(spec.to_dict()))) == spec
    for bad in (dict(lease_steps=0), dict(min_shards=0),
                dict(chunk_bytes=0), dict(max_transfer_retries=-1)):
        with pytest.raises(ValueError):
            ElasticSpec(**bad)


def test_runtime_spec_carries_elastic():
    from repro.configs.paper_gnn import paper_gnn_config
    from repro.graph.runtime import GraphSource, RuntimeSpec
    spec = RuntimeSpec(graph=GraphSource(n_nodes=N, n_classes=8),
                       model=paper_gnn_config("sage", n_nodes=N, n_classes=8),
                       elastic=ElasticSpec(lease_steps=1))
    back = RuntimeSpec.from_json(spec.to_json())
    assert back.elastic == ElasticSpec(lease_steps=1)
    assert RuntimeSpec.from_json(
        dataclasses.replace(spec, elastic=None).to_json()).elastic is None


# ---------------------------------------------------------------------------
# manager state machine (stub runtime: no jax work, just the protocol)
# ---------------------------------------------------------------------------

class _StubRuntime:
    """Duck-typed GraphRuntime: train() walks steps and honours the fence;
    state is a tiny pytree so pack/transfer/unpack run for real."""

    def __init__(self, n_shards=4, elastic=None):
        self.spec = types.SimpleNamespace(n_shards=n_shards, ckpt_dir=None,
                                          elastic=elastic, batch_size=BATCH)
        self.state = {"w": np.zeros(3, np.float32)}
        self.data_iter = types.SimpleNamespace(
            state_dict=lambda: {"step": 0, "seed": 0, "n_shards": n_shards})
        self.closed = False

    def train(self, steps, on_metrics=None, fence=None):
        interrupted = None
        losses = []
        for step in range(int(steps)):
            losses.append(0.0)
            if fence is not None:
                try:
                    fence(step)
                except FenceInterrupt:
                    interrupted = step + 1
                    break
        return LoopResult(state=self.state, losses=losses, step_times=[],
                          stragglers=0, resumed_from=None,
                          interrupted_at=interrupted)

    def close(self):
        self.closed = True


def _stub_manager(plan, n_shards=4, **spec_kw):
    rt = _StubRuntime(n_shards=n_shards)
    mgr = ElasticManager(rt, plan=plan,
                         spec=ElasticSpec(lease_steps=1, **spec_kw))
    # recovery builds a real GraphRuntime; swap it for a stub rebuild
    def _recover_stub():
        dead, detected = mgr._pending
        mgr._pending = None
        mgr._consumed.update((s, at) for s, at in mgr.plan.kill
                             if at <= detected)
        n_after = mgr.n_shards - len(dead)
        if n_after < mgr.spec.min_shards:
            raise ElasticError("survivors < min_shards")
        payload = pack_state(mgr.rt.state,
                             {"source": mgr.rt.data_iter.state_dict()})
        wire, _stats = transfer_state(payload,
                                      chunk_bytes=mgr.spec.chunk_bytes,
                                      tamper=mgr.plan.tamper,
                                      max_retries=mgr.spec.max_transfer_retries)
        mgr.state = RESCALING
        mgr.history.append(RESCALING)
        new_rt = _StubRuntime(n_shards=n_after)
        new_rt.state, _ = unpack_state(wire, new_rt.state)
        mgr.rt.close()
        mgr.rt, mgr.n_shards = new_rt, n_after
        mgr._leases = {s: mgr._done - 1 for s in range(n_after)}
        mgr.state = HEALTHY
        mgr.history.append(HEALTHY)
    mgr._recover = _recover_stub
    return mgr


def test_manager_detects_kill_and_rescales():
    mgr = _stub_manager(FailurePlan(kill=((2, 10),)))
    res = mgr.run(20)
    assert res.steps == 20 and len(res.losses) == 20
    # lease_steps=1, last renewal at 9 -> fence 11 trips, 12 steps done
    assert mgr.n_shards == 3
    assert mgr.state == HEALTHY
    assert res.history[:2] == [HEALTHY, DEGRADED]
    assert res.history[-1] == HEALTHY


def test_manager_healthy_run_never_transitions():
    mgr = _stub_manager(None)
    res = mgr.run(5)
    assert res.history == [HEALTHY] and res.steps == 5


def test_manager_tolerates_short_heartbeat_delay():
    # a 1-fence delay within the lease grace must NOT trigger recovery
    mgr = _stub_manager(FailurePlan(heartbeat_delay=((1, 4, 1),)))
    res = mgr.run(10)
    assert res.history == [HEALTHY] and mgr.n_shards == 4
    # ... but a delay longer than the grace does
    mgr2 = _stub_manager(FailurePlan(heartbeat_delay=((1, 4, 3),)))
    res2 = mgr2.run(10)
    assert DEGRADED in res2.history


def test_manager_min_shards_floor():
    mgr = _stub_manager(FailurePlan(kill=((0, 2), (1, 2), (2, 2),)),
                        min_shards=2)
    with pytest.raises(ElasticError):
        mgr.run(10)


def test_manager_refuses_checkpointed_runtime():
    rt = _StubRuntime()
    rt.spec.ckpt_dir = "/tmp/somewhere"
    with pytest.raises(ValueError, match="rescale_checkpoint"):
        ElasticManager(rt)


# ---------------------------------------------------------------------------
# sampler-state remap + spec rescale (single device, pure host logic)
# ---------------------------------------------------------------------------

def test_remap_shard_state_drops_layout_keeps_stream_anchor():
    state = {"step": 12, "seed": 5, "n_shards": 4, "miss_shadow": {"x": 1}}
    out = remap_shard_state(state, 3)
    assert out == {"step": 12, "seed": 5, "shard": 0, "n_shards": 3}


def test_remapped_union_stream_is_exact():
    # the global batch at (seed, step) must not depend on the shard count:
    # the 4-shard union of per-shard batches == the 3-shard union == global
    from repro.graph.engine import SageBatchSource
    from repro.graph.generate import powerlaw_graph
    from repro.graph.sampler import NeighborSampler
    adj, labels = powerlaw_graph(0, N, avg_degree=8, n_classes=8)
    sampler = NeighborSampler(adj, (5, 5), max_deg=32, seed=0)
    nodes = np.arange(N, dtype=np.int32)

    def union(n_shards, step):
        per = BATCH // n_shards
        got = []
        for shard in range(n_shards):
            src = SageBatchSource(sampler, nodes, labels, per, seed=0,
                                  shard=shard, n_shards=n_shards, dedup=False)
            src.load_state_dict(remap_shard_state(
                {"step": step, "seed": 0}, n_shards, shard=shard))
            got.append(src.next_batch()["levels"][0])
        return np.concatenate(got)

    np.testing.assert_array_equal(union(4, 7), union(3, 7))
    np.testing.assert_array_equal(union(4, 12), union(1, 12))


def test_rescale_spec_validates_and_rederives():
    from repro.configs.paper_gnn import paper_gnn_config
    from repro.graph.runtime import GraphSource, RuntimeSpec
    spec = RuntimeSpec(graph=GraphSource(n_nodes=N, n_classes=8),
                       model=paper_gnn_config("sage", n_nodes=N, n_classes=8),
                       batch_size=BATCH, n_shards=4, ckpt_dir="/tmp/old")
    out = rescale_spec(spec, 3)
    assert out.n_shards == 3 and out.batch_size == BATCH
    assert out.ckpt_dir is None            # old-topology dir never carries over
    assert out.owner_cap is None and out.owner_unique_cap is None  # stay derived
    with pytest.raises(ValueError, match="not divisible"):
        rescale_spec(spec, 5)
    # pinned caps are re-derived at the new count
    pinned = dataclasses.replace(spec, frontier_cap=512, owner_cap=256,
                                 owner_unique_cap=256)
    out2 = rescale_spec(pinned, 2)
    from repro.graph.sampler import default_owner_caps
    assert (out2.owner_cap, out2.owner_unique_cap) == default_owner_caps(512, 2)


# ---------------------------------------------------------------------------
# crash-safe checkpoints + topology validation (single device)
# ---------------------------------------------------------------------------

def test_interrupted_checkpoint_write_never_resumed(tmp_path):
    d = str(tmp_path / "ck")
    ck = CheckpointManager(d, async_save=False)
    state = {"w": np.arange(4, dtype=np.float32)}
    ck.save(1, state, {"data": {"step": 1}})
    # simulate a crash mid-write of step 2: tmp dir exists, no manifest
    half = os.path.join(d, "step_0000000002.tmp")
    os.makedirs(half)
    with open(os.path.join(half, "arrays.npz"), "wb") as f:
        f.write(b"partial garbage")
    assert CheckpointManager(d).list_steps() == [1]   # sweep + manifest gate
    assert not os.path.exists(half)                   # stale tmp swept on open
    # a fully-written-but-unpublished tmp (manifest present, no rename)
    # is equally invisible and swept
    ck2 = CheckpointManager(d, async_save=False)
    restored = ck2.restore_latest({"w": np.zeros(4, np.float32)})
    assert restored is not None and restored[0] == 1


def test_topology_mismatch_raises_before_arrays(tmp_path):
    ck = CheckpointManager(str(tmp_path), async_save=False)
    state = {"w": np.ones(3, np.float32)}
    ck.save(2, state, {}, topology={"n_shards": 4, "batch_size": 64})
    with pytest.raises(TopologyMismatch, match="GraphRuntime.rescale"):
        ck.restore(2, state, expect_topology={"n_shards": 8, "batch_size": 64})
    # matching + unasserted + legacy (no stamp) all pass
    ck.restore(2, state, expect_topology={"n_shards": 4, "batch_size": 64})
    ck.restore(2, state)
    ck.save(3, state, {})                              # legacy: no topology
    ck.restore(3, state, expect_topology={"n_shards": 8, "batch_size": 64})


# ---------------------------------------------------------------------------
# multidevice: the real thing
# ---------------------------------------------------------------------------

def _runtime_spec(n_shards, **kw):
    from repro.configs.paper_gnn import paper_gnn_config
    from repro.graph.runtime import GraphSource, RuntimeSpec
    base = paper_gnn_config("sage", n_nodes=N, n_classes=8, fanout=5)
    model = dataclasses.replace(base, embedding=dataclasses.replace(
        base.embedding, c=16, m=8, d_c=32, d_m=32,
        lookup_impl="sharded:gather"))
    return RuntimeSpec(graph=GraphSource(n_nodes=N, n_classes=8, avg_degree=8,
                                         homophily=0.9),
                       model=model, batch_size=kw.pop("batch_size", BATCH),
                       n_shards=n_shards, pad_to=64, prefetch_depth=2,
                       total_steps=14, **kw)


@pytest.mark.multidevice(n=4)
def test_kill_rescale_continuation_bitwise():
    """The core elastic invariant (ISSUE 9): kill shard 2/4 at step 10,
    recover by peer transfer ONLY (no checkpoint dir exists at all),
    rescale to 3 shards, and the continued loss curve is bitwise the
    never-failed 3-shard continuation from the same transferred state."""
    from repro.graph.runtime import GraphRuntime
    spec = _runtime_spec(4, elastic=ElasticSpec(lease_steps=1,
                                                chunk_bytes=1 << 16))
    rt = GraphRuntime.from_spec(spec)
    plan = FailurePlan(kill=((2, 10),), corrupt_chunks=(1,))
    mgr = ElasticManager(rt, plan=plan)
    res = mgr.run(14)
    try:
        assert res.steps == 14 and len(res.losses) == 14
        assert res.history == [HEALTHY, DEGRADED, RESCALING, HEALTHY]
        (rep,) = res.reports
        assert rep.failed_shards == (2,)
        assert rep.detected_at_step == 11        # kill at 10 + lease grace 1
        assert rep.steps_lost == 1
        assert (rep.n_before, rep.n_after) == (4, 3)
        assert rep.retransmits == 1              # the corrupted chunk re-sent
        assert rep.bytes_transferred > rep.payload_bytes
        assert res.runtime.spec.n_shards == 3
        assert res.runtime.spec.ckpt_dir is None  # peer transfer only

        # reference: never-failed 4-shard run to the interrupt point, then
        # the same exact-rescale to 3 shards and the same remaining steps
        rt4 = GraphRuntime.from_spec(spec)
        ref_head = rt4.train(12)
        rt3 = rt4.rescale(3)
        rt4.close()
        try:
            ref_tail = rt3.train(2)
        finally:
            rt3.close()
        assert res.losses == ref_head.losses + ref_tail.losses
    finally:
        res.runtime.close()


@pytest.mark.multidevice(n=8)
def test_rescale_checkpoint_bitwise_vs_native(tmp_path):
    """An 8-shard rescale of a 4-shard checkpoint produces step-0 loss
    bitwise identical to a native 8-shard run (and 4->2 likewise)."""
    from repro.graph.runtime import GraphRuntime
    ck = str(tmp_path / "ck4")
    rt4 = GraphRuntime.from_spec(_runtime_spec(4, batch_size=64, ckpt_dir=ck))
    rt4.train(0)                     # publishes the step-0 checkpoint
    rt4.close()
    for target in (8, 2):
        rt = GraphRuntime.rescale_checkpoint(ck, target)
        try:
            got = rt.train(1).losses
        finally:
            rt.close()
        native = GraphRuntime.from_spec(_runtime_spec(target, batch_size=64))
        try:
            want = native.train(1).losses
        finally:
            native.close()
        assert got == want, f"rescale 4->{target} not bitwise: {got} vs {want}"


@pytest.mark.multidevice(n=4)
def test_runtime_topology_mismatch_points_at_rescale(tmp_path):
    """Naively pointing a different-n_shards spec at an existing checkpoint
    dir fails loudly at restore time, naming the sanctioned path."""
    from repro.graph.runtime import GraphRuntime
    ck = str(tmp_path / "ck")
    rt4 = GraphRuntime.from_spec(_runtime_spec(4, batch_size=64, ckpt_dir=ck,
                                               ckpt_every=2))
    rt4.train(2)
    rt4.close()
    bad = GraphRuntime.from_spec(_runtime_spec(2, batch_size=64, ckpt_dir=ck))
    try:
        with pytest.raises(TopologyMismatch, match="GraphRuntime.rescale"):
            bad.train(4)
    finally:
        bad.close()
