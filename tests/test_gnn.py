"""GNN stack tests: the paper's §5.2 models learn on synthetic graphs and
Hash >= Rand in accuracy (the paper's core end-to-end claim, small-scale)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_gnn import paper_gnn_config
from repro.core import lsh
from repro.graph import NeighborSampler, powerlaw_graph
from repro.graph.generate import holdout_edges, train_val_test_split
from repro.models import gnn
from repro.optim import AdamWConfig, adamw_init, adamw_update

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def graph():
    adj, labels = powerlaw_graph(0, 2000, avg_degree=8, n_classes=8, homophily=0.9)
    return adj, labels


def _small(cfg):
    return dataclasses.replace(
        cfg, embedding=dataclasses.replace(cfg.embedding, c=16, m=8, d_c=64, d_m=64))


def _train_fullgraph(cfg, adjn, labels, tr, steps=50, lr=1e-2, codes=None):
    p = gnn.init_gnn(KEY, cfg, codes=codes)
    st = adamw_init(p)
    ocfg = AdamWConfig(lr=lr, weight_decay=0.0)

    @jax.jit
    def step(p, st):
        def loss_fn(p):
            h = gnn.fullgraph_forward(p, adjn, cfg)
            return gnn.node_loss(gnn.node_logits(p, h, cfg)[tr], labels[tr])
        loss, g = jax.value_and_grad(loss_fn, allow_int=True)(p)
        p, st = adamw_update(p, g, st, ocfg)
        return p, st, loss

    for _ in range(steps):
        p, st, loss = step(p, st)
    return p, float(loss)


@pytest.mark.parametrize("model", ["gcn", "sgc", "gin"])
def test_fullgraph_models_learn(graph, model):
    adj, labels = graph
    cfg = _small(paper_gnn_config(model, n_nodes=2000, n_classes=8))
    codes = lsh.encode_lsh(KEY, adj, cfg.embedding.c, cfg.embedding.m)
    adjn = adj.with_self_loops().normalized("sym")
    tr, va, te = train_val_test_split(0, 2000)
    p, loss = _train_fullgraph(cfg, adjn, jnp.asarray(labels), jnp.asarray(tr),
                               codes=codes)
    h = gnn.fullgraph_forward(p, adjn, cfg)
    acc = gnn.accuracy(gnn.node_logits(p, h, cfg)[jnp.asarray(te)], labels[te])
    assert acc > 0.25, f"{model}: acc {acc} not above chance (0.125)"


def test_sage_minibatch_learns(graph):
    adj, labels = graph
    cfg = _small(paper_gnn_config("sage", n_nodes=2000, n_classes=8, fanout=5))
    codes = lsh.encode_lsh(KEY, adj, cfg.embedding.c, cfg.embedding.m)
    p = gnn.init_gnn(KEY, cfg, codes=codes)
    sampler = NeighborSampler(adj, cfg.fanouts, max_deg=32, seed=0)
    tr, va, te = train_val_test_split(0, 2000)
    st = adamw_init(p)

    @jax.jit
    def step(p, st, levels, y):
        def loss_fn(p):
            h = gnn.sage_forward(p, levels, cfg)
            return gnn.node_loss(gnn.node_logits(p, h, cfg), y)
        loss, g = jax.value_and_grad(loss_fn, allow_int=True)(p)
        p, st = adamw_update(p, g, st, AdamWConfig(lr=1e-2, weight_decay=0.0))
        return p, st, loss

    for _ in range(2):
        for levels, batch in sampler.minibatches(tr, 256):
            p, st, _ = step(p, st, [jnp.asarray(l) for l in levels],
                            jnp.asarray(labels[batch]))
    levels, batch = next(sampler.minibatches(te, 400, shuffle=False))
    h = gnn.sage_forward(p, [jnp.asarray(l) for l in levels], cfg)
    acc = gnn.accuracy(gnn.node_logits(p, h, cfg), labels[batch])
    assert acc > 0.25


def test_link_prediction_learns(graph):
    adj, _ = graph
    cfg = dataclasses.replace(
        _small(paper_gnn_config("gcn", n_nodes=2000, n_classes=8)), task="link")
    codes = lsh.encode_lsh(KEY, adj, cfg.embedding.c, cfg.embedding.m)
    train_adj, pos_eval = holdout_edges(0, adj, 0.15)
    adjn = train_adj.with_self_loops().normalized("sym")
    rng = np.random.default_rng(0)
    p = gnn.init_gnn(KEY, cfg, codes=codes)
    st = adamw_init(p)

    rid = np.asarray(train_adj.row_ids())
    cid = np.asarray(train_adj.indices)

    @jax.jit
    def step(p, st, pos, neg):
        def loss_fn(p):
            h = gnn.fullgraph_forward(p, adjn, cfg)
            return gnn.link_loss(h, pos, neg)
        loss, g = jax.value_and_grad(loss_fn, allow_int=True)(p)
        p, st = adamw_update(p, g, st, AdamWConfig(lr=1e-2, weight_decay=0.0))
        return p, st, loss

    for i in range(30):
        sel = rng.integers(0, rid.shape[0], 512)
        pos = jnp.stack([jnp.asarray(rid[sel]), jnp.asarray(cid[sel])], 1)
        neg = jnp.asarray(rng.integers(0, 2000, (512, 2)))
        p, st, loss = step(p, st, pos, neg)

    h = gnn.fullgraph_forward(p, adjn, cfg)
    neg_eval = rng.integers(0, 2000, pos_eval.shape)
    hits = gnn.hits_at_k(gnn.link_scores(h, jnp.asarray(pos_eval)),
                         gnn.link_scores(h, jnp.asarray(neg_eval)), 50)
    assert hits > 0.1


def test_hash_beats_random_coding(graph):
    """Paper Table 1 direction (small-scale): Hash > Rand for GCN."""
    adj, labels = graph
    adjn = adj.with_self_loops().normalized("sym")
    tr, va, te = train_val_test_split(0, 2000)
    accs = {}
    for kind in ("hash_full", "random_full"):
        cfg = _small(paper_gnn_config("gcn", n_nodes=2000, n_classes=8, kind=kind))
        codes = (lsh.encode_lsh(KEY, adj, 16, 8) if kind == "hash_full"
                 else lsh.encode_random(KEY, 2000, 16, 8))
        p, _ = _train_fullgraph(cfg, adjn, jnp.asarray(labels), jnp.asarray(tr),
                                steps=60, codes=codes)
        h = gnn.fullgraph_forward(p, adjn, cfg)
        accs[kind] = gnn.accuracy(
            gnn.node_logits(p, h, cfg)[jnp.asarray(te)], labels[te])
    assert accs["hash_full"] > accs["random_full"] - 0.02, accs
