# Alternate compression families behind the DecodeBackend registry (ISSUE 8):
# position-based hash embeddings ("hashemb", arXiv:2109.00101) and
# tensor-train factorized codebooks ("tt", arXiv:2206.10581) as peer
# lookup_impls of the paper's bit-code hashing — gradient parity vs the
# dense-gather oracle, spec/checkpoint round-trips, and composition with the
# cached / mixed-precision / collective machinery.
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend as backend_mod
from repro.core import codes as codes_lib
from repro.core import embedding as emb_lib
from repro.core.backend import (
    family_of, get_backend, tt_factor_pair, tt_materialize)
from repro.core.embedding import EmbeddingConfig, embed_lookup, init_embedding
from repro.nn import module as nn


def small_cfg(impl, kind="random_full", **kw):
    base = dict(kind=kind, n_entities=300, d_e=16, c=16, m=4, d_c=16, d_m=16,
                n_layers=2, tt_rank=4, lookup_impl=impl,
                compute_dtype="float32")
    base.update(kw)
    return EmbeddingConfig(**base)


# ---------------------------------------------------------------------------
# position hashes
# ---------------------------------------------------------------------------

def test_position_codes_shape_range_determinism():
    ids = jnp.arange(512)
    pc = codes_lib.position_codes(ids, 16, 8)
    assert pc.shape == (512, 8) and pc.dtype == jnp.int32
    assert int(pc.min()) >= 0 and int(pc.max()) < 16
    assert (pc == codes_lib.position_codes(ids, 16, 8)).all()


def test_position_codes_positions_independent():
    pc = np.asarray(codes_lib.position_codes(jnp.arange(2048), 16, 4))
    # distinct hash functions per position, and each roughly uniform
    for j in range(1, 4):
        assert not (pc[:, 0] == pc[:, j]).all()
    counts = np.bincount(pc.reshape(-1), minlength=16)
    assert counts.min() > 0.5 * counts.mean()


def test_position_codes_seed_and_validation():
    ids = jnp.arange(100)
    a = codes_lib.position_codes(ids, 16, 4, seed=0)
    b = codes_lib.position_codes(ids, 16, 4, seed=1)
    assert not (a == b).all()
    with pytest.raises(ValueError):
        codes_lib.position_codes(ids, 15, 4)     # not a power of two


# ---------------------------------------------------------------------------
# family selection / registry
# ---------------------------------------------------------------------------

def test_family_of_spellings():
    assert family_of("onehot") == "paper"
    assert family_of("auto") == "paper"
    assert family_of(None) == "paper"
    assert family_of("owner:gather") == "paper"
    assert family_of("hashemb") == "hashemb"
    assert family_of("hashemb:gather") == "hashemb"
    assert family_of("sharded:hashemb") == "hashemb"
    assert family_of("owner:hashemb:gather") == "hashemb"
    assert family_of("tt") == "tt"
    assert family_of("owner:tt") == "tt"


def test_registry_has_families():
    names = backend_mod.available_backends()
    assert "hashemb" in names and "tt" in names
    assert get_backend("hashemb:gather").base.name == "gather"
    assert get_backend("owner:tt").base.name == "tt"


def test_hashemb_rejects_collective_and_family_bases():
    with pytest.raises(ValueError):
        get_backend("hashemb:sharded")
    with pytest.raises(ValueError):
        get_backend("hashemb:tt")


def test_tt_takes_no_base_option():
    with pytest.raises(ValueError):
        get_backend("tt:gather")


def test_tt_factor_pair_balanced():
    assert tt_factor_pair(16) == (4, 4)
    assert tt_factor_pair(64) == (8, 8)
    assert tt_factor_pair(12) == (3, 4)
    a, b = tt_factor_pair(17)
    assert a * b == 17


# ---------------------------------------------------------------------------
# value + gradient parity vs the dense-gather oracle
# ---------------------------------------------------------------------------

def test_tt_decode_matches_materialized_gather():
    key = jax.random.PRNGKey(0)
    m, c, d_c, r, B = 4, 16, 24, 3, 64
    c1, c2 = tt_factor_pair(c)
    d1, d2 = tt_factor_pair(d_c)
    g0 = jax.random.normal(key, (m, c1, d1, r))
    g1 = jax.random.normal(jax.random.PRNGKey(1), (m, c2, r, d2))
    codes = jax.random.randint(jax.random.PRNGKey(2), (B, m), 0, c)
    out = get_backend("tt").decode(codes, (g0, g1))
    ref = get_backend("gather").decode(codes, tt_materialize(g0, g1))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert get_backend("tt").feature_dim((g0, g1)) == d_c


def test_tt_grad_parity_vs_materialized_oracle():
    key = jax.random.PRNGKey(3)
    m, c, d_c, r, B = 4, 16, 16, 3, 32
    c1, c2 = tt_factor_pair(c)
    d1, d2 = tt_factor_pair(d_c)
    g0 = jax.random.normal(key, (m, c1, d1, r))
    g1 = jax.random.normal(jax.random.PRNGKey(4), (m, c2, r, d2))
    codes = jax.random.randint(jax.random.PRNGKey(5), (B, m), 0, c)
    tgt = jax.random.normal(jax.random.PRNGKey(6), (B, d_c))

    def loss_tt(g0, g1):
        return ((get_backend("tt").decode(codes, (g0, g1)) - tgt) ** 2).sum()

    def loss_oracle(g0, g1):
        cb = tt_materialize(g0, g1)
        return ((get_backend("gather").decode(codes, cb) - tgt) ** 2).sum()

    ga = jax.grad(loss_tt, argnums=(0, 1))(g0, g1)
    gb = jax.grad(loss_oracle, argnums=(0, 1))(g0, g1)
    for a, b in zip(ga, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_hashemb_decode_matches_prescaled_gather():
    # the backend sees pools pre-scaled by wpos (apply_decoder folds them),
    # so hashemb:gather must be bitwise the plain gather on that product
    cfg = small_cfg("hashemb:gather")
    p = init_embedding(jax.random.PRNGKey(0), cfg)["decoder"]
    ids = jnp.arange(50)
    codes = codes_lib.position_codes(ids, cfg.c, cfg.m)
    cb = p["pools"] * p["wpos"][:, None, :]
    ref = get_backend("gather").decode(codes, cb)
    out = get_backend("hashemb:gather").decode(codes, cb)
    assert (np.asarray(out) == np.asarray(ref)).all()


def test_hashemb_grad_parity_vs_oracle():
    cfg = small_cfg("hashemb:gather")
    p = init_embedding(jax.random.PRNGKey(1), cfg)
    dec = p["decoder"]
    ids = jnp.arange(40)
    codes = codes_lib.position_codes(ids, cfg.c, cfg.m)

    def loss_family(dec):
        return embed_lookup({"decoder": dec}, ids, cfg).sum()

    def loss_oracle(dec):
        # hand-built oracle: gather(pools * wpos) + the same MLP
        from repro.core.decoder import apply_decoder
        cb = dec["pools"] * dec["wpos"][:, None, :]
        fake = {"codebooks": cb, "mlp": dec["mlp"]}
        dcfg = dataclasses.replace(cfg.decoder_config(), lookup_impl="gather")
        return apply_decoder(fake, codes, dcfg).sum()

    ga = jax.grad(loss_family)(dec)
    # oracle grads land on the product; chain-rule them back by hand
    gfake = jax.grad(loss_oracle)(dec)
    np.testing.assert_allclose(np.asarray(ga["pools"]),
                               np.asarray(gfake["pools"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ga["wpos"]),
                               np.asarray(gfake["wpos"]),
                               rtol=1e-5, atol=1e-6)
    for k in ga["mlp"]:
        np.testing.assert_allclose(np.asarray(ga["mlp"][k]),
                                   np.asarray(gfake["mlp"][k]),
                                   rtol=1e-5, atol=1e-6)


def test_hashemb_light_trains_wpos_only():
    cfg = small_cfg("hashemb:gather", kind="random_light")
    p = init_embedding(jax.random.PRNGKey(2), cfg)
    assert "pools_buf" in p["decoder"] and "wpos" in p["decoder"]
    mask = nn.trainable_mask(p["decoder"])
    assert mask["pools_buf"] is False and mask["wpos"] is True


def test_tt_light_freezes_cores():
    cfg = small_cfg("tt", kind="random_light")
    p = init_embedding(jax.random.PRNGKey(3), cfg)
    dec = p["decoder"]
    assert "tt_g0_buf" in dec and "tt_g1_buf" in dec and "w0" in dec
    mask = nn.trainable_mask(dec)
    assert mask["tt_g0_buf"] is False and mask["w0"] is True


# ---------------------------------------------------------------------------
# parameter accounting at matched budgets
# ---------------------------------------------------------------------------

def _n_bias(dcfg):
    return (dcfg.d_e if dcfg.n_layers == 1
            else dcfg.d_m * (dcfg.n_layers - 1) + dcfg.d_e)


@pytest.mark.parametrize("impl", ["onehot", "hashemb:gather", "tt"])
@pytest.mark.parametrize("kind", ["random_full", "random_light"])
def test_closed_form_param_counts(impl, kind):
    cfg = small_cfg(impl, kind=kind)
    p = init_embedding(jax.random.PRNGKey(4), cfg)
    dcfg = cfg.decoder_config()
    actual = nn.param_count(p["decoder"], trainable_only=True)
    # the paper's closed form has never counted MLP biases
    assert dcfg.trainable_params() + _n_bias(dcfg) == actual
    total = sum(l.size for l in jax.tree_util.tree_leaves(p["decoder"]))
    assert dcfg.frozen_params() == total - actual


def test_tt_cuts_decode_stage_params():
    paper = small_cfg("onehot").decoder_config()
    tt = small_cfg("tt").decoder_config()
    assert tt._decode_stage_params() < paper._decode_stage_params()


# ---------------------------------------------------------------------------
# embedding layer: no codes_buf for hashemb, one-field family switch
# ---------------------------------------------------------------------------

def test_hashemb_has_no_codes_buf():
    cfg = small_cfg("hashemb:gather")
    assert cfg.family == "hashemb" and not cfg.needs_codes
    p = init_embedding(jax.random.PRNGKey(5), cfg)
    assert set(p) == {"decoder"}
    out = embed_lookup(p, jnp.arange(10), cfg)
    assert out.shape == (10, cfg.d_e)
    assert bool(jnp.isfinite(out).all())


def test_paper_family_unchanged():
    cfg = small_cfg("onehot")
    assert cfg.family == "paper" and cfg.needs_codes
    p = init_embedding(jax.random.PRNGKey(6), cfg)
    assert "codes_buf" in p


def test_one_field_family_switch():
    for impl, keys in (("onehot", {"codebooks"}),
                       ("hashemb:gather", {"pools", "wpos"}),
                       ("tt", {"tt_g0", "tt_g1"})):
        cfg = small_cfg(impl)
        p = init_embedding(jax.random.PRNGKey(7), cfg)
        dec_keys = set(p["decoder"]) - {"mlp"}
        assert dec_keys == keys, (impl, dec_keys)
        out = embed_lookup(p, jnp.arange(6), cfg)
        assert out.shape == (6, cfg.d_e)


# ---------------------------------------------------------------------------
# mixed precision / int8 composition
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["hashemb:gather", "tt"])
def test_families_respect_drift_bounds(impl):
    cfg32 = small_cfg(impl)
    p = init_embedding(jax.random.PRNGKey(8), cfg32)
    ids = jnp.arange(64)
    ref = embed_lookup(p, ids, cfg32)
    scale = float(jnp.abs(ref).max())
    for pd, q, bound in (("bfloat16", "none",
                          backend_mod.DRIFT_BOUNDS["bfloat16"]),
                         (None, "int8", backend_mod.DRIFT_BOUNDS["int8"])):
        cfg = dataclasses.replace(cfg32, param_dtype=pd, quantize=q)
        out = embed_lookup(p, ids, cfg)
        drift = float(jnp.abs(out - ref).max()) / scale
        assert drift <= bound, (impl, pd, q, drift)


@pytest.mark.parametrize("impl", ["hashemb:gather", "tt"])
def test_family_dtype_contract(impl):
    policy = backend_mod.MixedPrecisionPolicy(param_dtype="bfloat16",
                                              compute_dtype="bfloat16")
    be = get_backend(impl, policy=policy)
    contract = be.dtype_contract()
    assert contract["backend"] == impl.split(":")[0]
    assert "family" in contract
    assert contract["output"] == "float32"


# ---------------------------------------------------------------------------
# cached decode composes (staleness 0 is bitwise)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["hashemb:gather", "tt"])
def test_cached_staleness0_bitwise(impl):
    from repro.core.backend import CachedDecodeBackend
    cfg = small_cfg(impl)
    p = init_embedding(jax.random.PRNGKey(9), cfg)
    ids = jnp.arange(32)
    decode_fn = lambda i: embed_lookup(p, i, cfg)
    cache = CachedDecodeBackend(staleness=0)
    state = cache.init_state(64, cfg.d_e)
    out1, state = cache.lookup(state, ids, decode_fn)
    out2, state = cache.lookup(state, ids, decode_fn)
    ref = decode_fn(ids)
    assert (np.asarray(out1) == np.asarray(ref)).all()
    assert (np.asarray(out2) == np.asarray(ref)).all()


# ---------------------------------------------------------------------------
# spec / checkpoint round-trip through GraphRuntime
# ---------------------------------------------------------------------------

def _family_spec(tmpdir, impl, **extra):
    from repro.configs.paper_gnn import paper_gnn_config
    from repro.graph.runtime import GraphSource, RuntimeSpec
    return RuntimeSpec(
        graph=GraphSource(kind="powerlaw", seed=0, n_nodes=300, n_classes=5),
        model=paper_gnn_config("sage", n_nodes=300, n_classes=5, fanout=5),
        batch_size=16, total_steps=2, log_every=1,
        ckpt_dir=str(tmpdir), ckpt_every=1,
    ).with_updates(c=16, m=4, d_c=16, d_m=16, lookup_impl=impl, **extra)


@pytest.mark.parametrize("impl,extra", [("hashemb:gather", {}),
                                        ("tt", {"tt_rank": 3})])
def test_spec_ckpt_resume_roundtrip(impl, extra, tmp_path):
    from repro.graph.runtime import GraphRuntime, RuntimeSpec
    spec = _family_spec(tmp_path, impl, **extra)
    assert RuntimeSpec.from_dict(spec.to_dict()) == spec      # JSON round-trip
    rt = GraphRuntime.from_spec(spec)
    try:
        if impl.startswith("hashemb"):
            assert rt.codes is None
            assert "codes_buf" not in rt.state["params"]["embed"]
        res = rt.train(2)
        assert all(math.isfinite(l) for l in res.losses)
        rt2 = GraphRuntime.resume(str(tmp_path))
        try:
            emb2 = rt2.spec.model.embedding
            assert emb2.lookup_impl == impl                   # same family
            assert emb2.tt_rank == spec.model.embedding.tt_rank
            a = sorted(jax.tree_util.tree_leaves_with_path(rt.state["params"]),
                       key=lambda t: str(t[0]))
            b = sorted(jax.tree_util.tree_leaves_with_path(rt2.state["params"]),
                       key=lambda t: str(t[0]))
            assert [str(pa) for pa, _ in a] == [str(pb) for pb, _ in b]
            for (pa, x), (_, y) in zip(a, b):                 # bitwise params
                assert (np.asarray(x) == np.asarray(y)).all(), pa
        finally:
            rt2.close()
    finally:
        rt.close()


def test_serving_rejects_family_switch(tmp_path):
    from repro.graph.runtime import GraphRuntime
    rt = GraphRuntime.from_spec(_family_spec(tmp_path, "hashemb:gather"))
    try:
        rt.train(1)
        with pytest.raises(ValueError, match="family"):
            rt.serve(serve_batch=16, decode_backend="tt")
        eng = rt.serve(serve_batch=16, decode_backend="hashemb:onehot")
        out = eng.serve(np.arange(8))
        assert np.isfinite(np.asarray(out.embeddings)).all()
    finally:
        rt.close()


# ---------------------------------------------------------------------------
# collective composition (owner/sharded wrap the families' pytree codebooks)
# ---------------------------------------------------------------------------

@pytest.mark.multidevice(4)
@pytest.mark.parametrize("impl", ["sharded:hashemb", "owner:tt"])
def test_collective_family_training(impl, tmp_path):
    from repro.configs.paper_gnn import paper_gnn_config
    from repro.graph.runtime import GraphRuntime, GraphSource, RuntimeSpec
    spec = RuntimeSpec(
        graph=GraphSource(kind="powerlaw", seed=0, n_nodes=1000, n_classes=8),
        model=paper_gnn_config("sage", n_nodes=1000, n_classes=8, fanout=10),
        batch_size=64, n_shards=4, total_steps=2, log_every=1,
    ).with_updates(c=16, m=8, d_c=64, d_m=64, lookup_impl=impl, tt_rank=4)
    rt = GraphRuntime.from_spec(spec)
    try:
        res = rt.train(2)
        assert all(math.isfinite(l) for l in res.losses), (impl, res.losses)
    finally:
        rt.close()


@pytest.mark.multidevice(4)
def test_owner_tt_matches_sharded_tt():
    # owner-computes dedup must not change values: same losses as the
    # row-partitioned decode of the same family
    from repro.configs.paper_gnn import paper_gnn_config
    from repro.graph.runtime import GraphRuntime, GraphSource, RuntimeSpec
    losses = {}
    for impl in ("sharded:tt", "owner:tt"):
        spec = RuntimeSpec(
            graph=GraphSource(kind="powerlaw", seed=0, n_nodes=1000,
                              n_classes=8),
            model=paper_gnn_config("sage", n_nodes=1000, n_classes=8,
                                   fanout=10),
            batch_size=64, n_shards=4, total_steps=2, log_every=1,
        ).with_updates(c=16, m=8, d_c=64, d_m=64, lookup_impl=impl, tt_rank=4)
        rt = GraphRuntime.from_spec(spec)
        try:
            losses[impl] = rt.train(2).losses
        finally:
            rt.close()
    assert losses["sharded:tt"][0] == losses["owner:tt"][0], losses
