"""Data pipeline determinism/state + serving engine behaviour."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.data import TokenStream, TokenStreamConfig, cooccurrence_matrix
from repro.models import init_lm
from repro.serving import DecodeEngine


def _stream(**kw):
    base = dict(vocab_size=128, seq_len=16, batch_size=4, seed=3)
    base.update(kw)
    return TokenStream(TokenStreamConfig(**base))


def test_stream_deterministic():
    a, b = _stream(), _stream()
    for _ in range(3):
        ba, bb = a.next_batch(), b.next_batch()
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])


def test_stream_state_restore():
    a = _stream()
    for _ in range(5):
        a.next_batch()
    st = a.state_dict()
    expected = a.next_batch()
    b = _stream()
    b.load_state_dict(st)
    np.testing.assert_array_equal(b.next_batch()["tokens"], expected["tokens"])


def test_stream_shards_differ():
    a = _stream(shard=0, n_shards=2)
    b = _stream(shard=1, n_shards=2)
    assert (a.next_batch()["tokens"] != b.next_batch()["tokens"]).any()


def test_labels_are_shifted_tokens():
    b = _stream().next_batch()
    assert b["tokens"].shape == b["labels"].shape


def test_cooccurrence_structure():
    """Tokens from the same topic co-occur: their aux rows correlate more."""
    s = _stream(vocab_size=64, seq_len=64, batch_size=8, n_topics=4,
                topic_stickiness=0.999)
    A = cooccurrence_matrix(s, n_batches=4, window=4, projection_dim=32)
    assert A.shape == (64, 32)
    norms = np.linalg.norm(A, axis=1)
    assert (norms[norms > 0] <= 1.001).all()


def test_decode_engine_greedy():
    cfg = reduced(get_config("qwen1.5-0.5b"))
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(cfg, params, s_max=64)
    prompts = np.array([[1, 2, 3, 4], [5, 6, 7, 8]], np.int32)
    res = eng.generate(prompts, max_new_tokens=6, temperature=0.0)
    assert res.tokens.shape == (2, 10)
    assert (res.tokens[:, :4] == prompts).all()
    assert (res.tokens >= 0).all() and (res.tokens < cfg.vocab_size).all()
    # greedy decode is deterministic
    res2 = eng.generate(prompts, max_new_tokens=6, temperature=0.0)
    np.testing.assert_array_equal(res.tokens, res2.tokens)
