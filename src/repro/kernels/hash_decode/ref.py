"""Pure-jnp oracle for the fused hash-decode kernel.

Semantics: codes (B, m) int32 in [0, c) index m codebooks (m, c, d_c);
retrieved vectors are summed; optional elementwise rescale by w0 (the light
decoder's trainable vector).  Output (B, d_c) in f32.

With ``scales`` (m, c) the codebooks are int8 absmax-quantized values and
the oracle dequantizes before the contraction — element-for-element the
same products as the fused kernel's scaled-one-hot path (each dot row has
exactly one nonzero, so ``onehot @ (q · s) == (onehot · s) @ q``).
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def hash_decode_ref(codes: jnp.ndarray, codebooks: jnp.ndarray,
                    w0: Optional[jnp.ndarray] = None,
                    scales: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    m, c, d_c = codebooks.shape
    cb = codebooks.astype(jnp.float32)
    if scales is not None:
        cb = cb * scales.astype(jnp.float32)[:, :, None]
    onehot = (codes[:, :, None] == jnp.arange(c)[None, None, :])
    out = jnp.einsum("bmc,mcd->bd", onehot.astype(jnp.float32), cb)
    if w0 is not None:
        out = out * w0.astype(jnp.float32)[None, :]
    return out
