"""Pallas TPU kernel: fused compositional-code decode (DESIGN.md §3.1).

The decoder's codebook retrieval — on GPU a batch of ``m`` gathers — is
re-expressed for the MXU as ``m`` one-hot × codebook matmuls accumulated in
VMEM.  The one-hot matrices are built in-register from ``broadcasted_iota``
+ compare (never materialised in HBM); the codebooks stream through VMEM in
``(m·c, block_d)`` column panels, the codes block stays resident.

Quantized decode (int8 codebooks + per-(codebook, code) f32 ``scales``)
fuses the dequant into the same matmul: the one-hot row is scaled by
``scales[j, code]`` *before* the int8 panel contraction, so
``(onehot · s) @ q  ==  onehot @ (q · s)`` bitwise — each dot row has
exactly one nonzero — and the dequantized codebooks never materialise in
HBM.  That is the whole point: at c=256, m=16, d_c=512 the codebook
traffic drops 4x (int8 values + a (m, c) f32 scale table that is ~d_c/4x
smaller than the values).

Accumulation is always f32 (``preferred_element_type``) regardless of the
codebook storage dtype — the MixedPrecisionPolicy's ``reduce_dtype``.

Grid: (B / block_b, d_c / block_d); both parallel.
VMEM per step (defaults block_b=256, block_d=256, c=256, m=16, f32):
  codes 256×16×4 = 16 KiB, codebook panel 4096×256×4 = 4 MiB,
  acc 256×256×4 = 256 KiB, onehot (register/VMEM temp) 256×256×4 = 256 KiB
  — ≈ 4.5 MiB, comfortably inside a v5e core's 16 MiB working budget.
  int8 panels are 1 MiB; the (m, c) scale table 16 KiB, grid-resident.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import TPUCompilerParams


def _decode_body(codes_ref, cb_ref, w0_ref, scales_ref, o_ref, *, c: int, m: int):
    codes = codes_ref[...]                       # (bB, m) int32
    bB = codes.shape[0]
    acc = jnp.zeros((bB, o_ref.shape[1]), jnp.float32)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (bB, c), 1)
    for j in range(m):                           # m is small & static: unrolled
        onehot = (codes[:, j][:, None] == iota_c).astype(jnp.float32)
        if scales_ref is not None:
            # fused dequant: scale the single nonzero of each one-hot row by
            # scales[j, code] — bitwise-equal to dequantizing the panel, but
            # the panel stays int8 in VMEM
            onehot = onehot * scales_ref[j, :][None, :].astype(jnp.float32)
        panel = cb_ref[j * c: (j + 1) * c, :].astype(jnp.float32)
        acc += jax.lax.dot_general(
            onehot, panel, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    if w0_ref is not None:
        acc *= w0_ref[...].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_d", "interpret")
)
def hash_decode_fwd(
    codes: jnp.ndarray,            # (B, m) int32
    codebooks: jnp.ndarray,        # (m, c, d_c) — f32 / bf16 / int8
    w0: Optional[jnp.ndarray] = None,      # (d_c,) or None
    scales: Optional[jnp.ndarray] = None,  # (m, c) f32 dequant scales or None
    *,
    block_b: int = 256,
    block_d: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    B, m = codes.shape
    m2, c, d_c = codebooks.shape
    assert m2 == m
    block_b = min(block_b, B)
    block_d = min(block_d, d_c)
    assert B % block_b == 0 and d_c % block_d == 0, (B, d_c, block_b, block_d)

    cb2d = codebooks.reshape(m * c, d_c)
    grid = (B // block_b, d_c // block_d)

    in_specs = [
        pl.BlockSpec((block_b, m), lambda i, j: (i, 0)),
        pl.BlockSpec((m * c, block_d), lambda i, j: (0, j)),
    ]
    args = [codes, cb2d]
    if w0 is not None:
        in_specs.append(pl.BlockSpec((1, block_d), lambda i, j: (0, j)))
        args.append(w0.reshape(1, d_c))
    if scales is not None:
        # the scale table is tiny — grid-resident, every program sees all of it
        in_specs.append(pl.BlockSpec((m, c), lambda i, j: (0, 0)))
        args.append(scales.astype(jnp.float32))

    have_w0, have_scales = w0 is not None, scales is not None

    def body(*refs):
        codes_ref, cb_ref = refs[0], refs[1]
        k = 2
        w0_ref = refs[k] if have_w0 else None
        k += int(have_w0)
        scales_ref = refs[k] if have_scales else None
        _decode_body(codes_ref, cb_ref, w0_ref, scales_ref, refs[-1], c=c, m=m)

    return pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct((B, d_c), jnp.float32),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, block_d), lambda i, j: (i, j)),
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
        name="hash_decode",
    )(*args)
