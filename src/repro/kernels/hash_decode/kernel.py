"""Pallas TPU kernel: fused compositional-code decode (DESIGN.md §3.1).

The decoder's codebook retrieval — on GPU a batch of ``m`` gathers — is
re-expressed for the MXU as ``m`` one-hot × codebook matmuls accumulated in
VMEM.  The one-hot matrices are built in-register from ``broadcasted_iota``
+ compare (never materialised in HBM); the codebooks stream through VMEM in
``(m·c, block_d)`` column panels, the codes block stays resident.

Grid: (B / block_b, d_c / block_d); both parallel.
VMEM per step (defaults block_b=256, block_d=256, c=256, m=16, f32):
  codes 256×16×4 = 16 KiB, codebook panel 4096×256×4 = 4 MiB,
  acc 256×256×4 = 256 KiB, onehot (register/VMEM temp) 256×256×4 = 256 KiB
  — ≈ 4.5 MiB, comfortably inside a v5e core's 16 MiB working budget.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import TPUCompilerParams


def _decode_body(codes_ref, cb_ref, w0_ref, o_ref, *, c: int, m: int):
    codes = codes_ref[...]                       # (bB, m) int32
    bB = codes.shape[0]
    acc = jnp.zeros((bB, o_ref.shape[1]), jnp.float32)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (bB, c), 1)
    for j in range(m):                           # m is small & static: unrolled
        onehot = (codes[:, j][:, None] == iota_c).astype(jnp.float32)
        panel = cb_ref[j * c: (j + 1) * c, :].astype(jnp.float32)
        acc += jax.lax.dot_general(
            onehot, panel, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    if w0_ref is not None:
        acc *= w0_ref[...].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_d", "interpret")
)
def hash_decode_fwd(
    codes: jnp.ndarray,            # (B, m) int32
    codebooks: jnp.ndarray,        # (m, c, d_c)
    w0: Optional[jnp.ndarray] = None,   # (d_c,) or None
    *,
    block_b: int = 256,
    block_d: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    B, m = codes.shape
    m2, c, d_c = codebooks.shape
    assert m2 == m
    block_b = min(block_b, B)
    block_d = min(block_d, d_c)
    assert B % block_b == 0 and d_c % block_d == 0, (B, d_c, block_b, block_d)

    cb2d = codebooks.reshape(m * c, d_c)
    grid = (B // block_b, d_c // block_d)

    in_specs = [
        pl.BlockSpec((block_b, m), lambda i, j: (i, 0)),
        pl.BlockSpec((m * c, block_d), lambda i, j: (0, j)),
    ]
    args = [codes, cb2d]
    if w0 is not None:
        in_specs.append(pl.BlockSpec((1, block_d), lambda i, j: (0, j)))
        args.append(w0.reshape(1, d_c))
        body = functools.partial(_decode_body, c=c, m=m)
    else:
        body = functools.partial(
            lambda codes_ref, cb_ref, o_ref, **kw: _decode_body(
                codes_ref, cb_ref, None, o_ref, **kw
            ),
            c=c, m=m,
        )

    return pl.pallas_call(
        body,
        out_shape=jax.ShapeDtypeStruct((B, d_c), jnp.float32),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, block_d), lambda i, j: (i, j)),
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
        name="hash_decode",
    )(*args)
