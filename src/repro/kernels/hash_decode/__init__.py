from repro.kernels.hash_decode.ops import hash_decode
from repro.kernels.hash_decode.ref import hash_decode_ref

__all__ = ["hash_decode", "hash_decode_ref"]
