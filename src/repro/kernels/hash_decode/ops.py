"""jit'd wrapper for the hash-decode kernel with custom VJP.

Forward runs the Pallas kernel (or the jnp oracle when ``use_kernel=False``
/ unaligned shapes); backward is expressed in XLA:
    d_codebooks[j, code, :] += g ⊙ w0       (scatter-add == onehotᵀ @ g)
    d_w0 = Σ_b g ⊙ codebook_sum             (recomputed, not saved)
Codes are integers — no gradient flows to them.

``quantize="int8"`` runs the decode against per-(codebook, code) absmax
int8 values with the dequant fused into the kernel (scales operand).  The
f32/bf16 master codebooks stay the differentiable primal: the codebook
cotangent is a value-independent scatter-add of the output cotangent, so
the straight-through estimator through round() is exactly the unquantized
backward; only ``d_w0`` (which linearizes through the decoded values) uses
the dequantized codebooks to match the forward.
"""

from __future__ import annotations

import warnings
from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.hash_decode.kernel import hash_decode_fwd
from repro.kernels.hash_decode.ref import hash_decode_ref

# f32 min tile (sublane, lane) on TPU — a block that isn't a multiple of
# this fails Mosaic layout even when it divides the array.
_SUBLANE = 8
_LANE = 128

# (B, d_c, reason) triples already warned about — one warning per distinct
# (shape, reason), so a new fallback cause is never silenced by an earlier,
# unrelated one.  Tests reset via ``reset_fallback_warnings()``.
_warned_fallback: set = set()


def reset_fallback_warnings() -> None:
    """Clear the warn-once memory (test hook: lets a test assert the
    fallback warning fires regardless of what ran before it)."""
    _warned_fallback.clear()


def _fallback_reasons(B: int, d_c: int, block_b: int, block_d: int,
                      *, c: Optional[int] = None, m: Optional[int] = None,
                      quantized: bool = False) -> List[str]:
    """Why the kernel can't run these shapes ([] == it can): the (clamped)
    blocks must divide the array dims AND be hardware-tileable, and the
    quantized path's (m, c) scale table must itself be a legal tile.  The
    old check ``B % min(block_b, B)`` was vacuously 0 whenever ``block_b >
    B`` — it reported e.g. B=100 as aligned, which only works in interpret
    mode (100 is not a sublane multiple) and silently diverged from TPU
    behaviour."""
    bb, bd = min(block_b, B), min(block_d, d_c)
    reasons = []
    if B % bb != 0 or d_c % bd != 0:
        reasons.append("block-divide")
    if bb % _SUBLANE != 0 or bd % _LANE != 0:
        reasons.append("block-tile")
    if quantized and (m % _SUBLANE != 0 or c % _LANE != 0):
        reasons.append("scales-tile")
    return reasons


def _aligned(B: int, d_c: int, block_b: int, block_d: int) -> bool:
    """True iff the (unquantized) kernel can run — see _fallback_reasons."""
    return not _fallback_reasons(B, d_c, block_b, block_d)


def quantize_codebooks(codebooks: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(codebook, code) absmax int8 quantization (the
    ``optim/compress.py`` idiom at code-vector granularity).

    codebooks (m, c, d_c) any float -> (q int8 (m, c, d_c), scales f32
    (m, c)); all-zero code vectors get scale 1 so dequant is exact."""
    cb = codebooks.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(cb), axis=2)
    scales = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(cb / scales[:, :, None]), -127, 127).astype(jnp.int8)
    return q, scales


def dequantize_codebooks(q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """(q int8 (m, c, d_c), scales f32 (m, c)) -> f32 (m, c, d_c)."""
    return q.astype(jnp.float32) * scales.astype(jnp.float32)[:, :, None]


@jax.custom_vjp
def quantize_dequantize(codebooks: jnp.ndarray) -> jnp.ndarray:
    """dequant(quantize(cb)): the decode-visible value of int8-stored
    codebooks, with a straight-through (identity) backward to the float
    masters.  The XLA backends use this to bitwise-match the fused kernel's
    scaled-one-hot dequant (same f32 products, see kernel.py)."""
    return dequantize_codebooks(*quantize_codebooks(codebooks))


def _qdq_fwd(codebooks):
    return quantize_dequantize(codebooks), jnp.zeros((), codebooks.dtype)


def _qdq_bwd(dtype_token, g):
    return (g.astype(dtype_token.dtype),)


quantize_dequantize.defvjp(_qdq_fwd, _qdq_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _hash_decode(codes, codebooks, w0, block_b, block_d, interpret, use_kernel):
    if use_kernel:
        return hash_decode_fwd(codes, codebooks, w0,
                               block_b=block_b, block_d=block_d,
                               interpret=interpret)
    return hash_decode_ref(codes, codebooks, w0)


def _fwd(codes, codebooks, w0, block_b, block_d, interpret, use_kernel):
    out = _hash_decode(codes, codebooks, w0, block_b, block_d, interpret, use_kernel)
    return out, (codes, codebooks, w0)


def _bwd(block_b, block_d, interpret, use_kernel, res, g):
    codes, codebooks, w0 = res
    m, c, _ = codebooks.shape
    g = g.astype(jnp.float32)
    gw = g * w0.astype(jnp.float32)[None, :] if w0 is not None else g
    onehot = (codes[:, :, None] == jnp.arange(c)[None, None, :]).astype(jnp.float32)
    d_cb = jnp.einsum("bmc,bd->mcd", onehot, gw).astype(codebooks.dtype)
    if w0 is not None:
        summed = jnp.einsum("bmc,mcd->bd", onehot, codebooks.astype(jnp.float32))
        d_w0 = jnp.einsum("bd,bd->d", g, summed).astype(w0.dtype)
    else:
        d_w0 = None
    return None, d_cb, d_w0


_hash_decode.defvjp(_fwd, _bwd)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _hash_decode_int8(codes, codebooks, w0, block_b, block_d, interpret, use_kernel):
    q, scales = quantize_codebooks(codebooks)
    if use_kernel:
        return hash_decode_fwd(codes, q, w0, scales,
                               block_b=block_b, block_d=block_d,
                               interpret=interpret)
    return hash_decode_ref(codes, q, w0, scales=scales)


def _fwd_int8(codes, codebooks, w0, block_b, block_d, interpret, use_kernel):
    out = _hash_decode_int8(codes, codebooks, w0, block_b, block_d, interpret,
                            use_kernel)
    return out, (codes, codebooks, w0)


def _bwd_int8(block_b, block_d, interpret, use_kernel, res, g):
    codes, codebooks, w0 = res
    m, c, _ = codebooks.shape
    g = g.astype(jnp.float32)
    gw = g * w0.astype(jnp.float32)[None, :] if w0 is not None else g
    onehot = (codes[:, :, None] == jnp.arange(c)[None, None, :]).astype(jnp.float32)
    # straight-through to the float masters: the codebook cotangent never
    # reads codebook VALUES, so it is identical to the unquantized backward
    d_cb = jnp.einsum("bmc,bd->mcd", onehot, gw).astype(codebooks.dtype)
    if w0 is not None:
        # d_w0 linearizes through the decoded values — use what the forward
        # actually decoded (the dequantized codebooks), not the masters
        deq = dequantize_codebooks(*quantize_codebooks(codebooks))
        summed = jnp.einsum("bmc,mcd->bd", onehot, deq)
        d_w0 = jnp.einsum("bd,bd->d", g, summed).astype(w0.dtype)
    else:
        d_w0 = None
    return None, d_cb, d_w0


_hash_decode_int8.defvjp(_fwd_int8, _bwd_int8)


def hash_decode(
    codes: jnp.ndarray,
    codebooks: jnp.ndarray,
    w0: Optional[jnp.ndarray] = None,
    *,
    block_b: int = 256,
    block_d: int = 256,
    interpret: bool = False,
    use_kernel: bool = True,
    quantize: str = "none",
) -> jnp.ndarray:
    """codes (B, m) int32, codebooks (m, c, d_c) -> (B, d_c) f32.

    ``quantize="int8"`` decodes against absmax-int8 codebooks with the
    dequant fused into the kernel; gradients flow straight-through to the
    float masters (module docstring).

    Unaligned shapes fall back to the jnp reference path with a one-time
    warning per (shape, reason); callers that want the kernel
    unconditionally should pad to block multiples first
    (``core.backend.PallasBackend`` does exactly that)."""
    if quantize not in ("none", "int8"):
        raise ValueError(f"quantize={quantize!r} not supported "
                         f"(expected 'none' or 'int8'; int4 packing is a "
                         f"documented future extension)")
    B = codes.shape[0]
    m, c, d_c = codebooks.shape
    if use_kernel:
        reasons = _fallback_reasons(B, d_c, block_b, block_d, c=c, m=m,
                                    quantized=(quantize == "int8"))
        if reasons:
            reason = "+".join(reasons)
            key = (B, d_c, reason)
            if key not in _warned_fallback:
                _warned_fallback.add(key)
                warnings.warn(
                    f"hash_decode: shapes B={B}, d_c={d_c} not tileable with "
                    f"blocks ({block_b}, {block_d}) [{reason}]; falling back "
                    f"to the jnp reference path (pad inputs, e.g. via "
                    f"repro.core.backend.PallasBackend, to run the kernel)",
                    stacklevel=2)
            use_kernel = False
    if quantize == "int8":
        return _hash_decode_int8(codes, codebooks, w0, block_b, block_d,
                                 interpret, use_kernel)
    return _hash_decode(codes, codebooks, w0, block_b, block_d, interpret,
                        use_kernel)
