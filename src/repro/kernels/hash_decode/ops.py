"""jit'd wrapper for the hash-decode kernel with custom VJP.

Forward runs the Pallas kernel (or the jnp oracle when ``use_kernel=False``
/ unaligned shapes); backward is expressed in XLA:
    d_codebooks[j, code, :] += g ⊙ w0       (scatter-add == onehotᵀ @ g)
    d_w0 = Σ_b g ⊙ codebook_sum             (recomputed, not saved)
Codes are integers — no gradient flows to them.
"""

from __future__ import annotations

import warnings
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.hash_decode.kernel import hash_decode_fwd
from repro.kernels.hash_decode.ref import hash_decode_ref

# f32 min tile (sublane, lane) on TPU — a block that isn't a multiple of
# this fails Mosaic layout even when it divides the array.
_SUBLANE = 8
_LANE = 128

_warned_fallback = False


def _aligned(B: int, d_c: int, block_b: int, block_d: int) -> bool:
    """True iff the kernel can run: the (clamped) blocks must divide the
    array dims AND be hardware-tileable.  The old check ``B % min(block_b,
    B)`` was vacuously 0 whenever ``block_b > B`` — it reported e.g. B=100
    as aligned, which only works in interpret mode (100 is not a sublane
    multiple) and silently diverged from TPU behaviour."""
    bb, bd = min(block_b, B), min(block_d, d_c)
    return (B % bb == 0 and d_c % bd == 0
            and bb % _SUBLANE == 0 and bd % _LANE == 0)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _hash_decode(codes, codebooks, w0, block_b, block_d, interpret, use_kernel):
    if use_kernel:
        return hash_decode_fwd(codes, codebooks, w0,
                               block_b=block_b, block_d=block_d,
                               interpret=interpret)
    return hash_decode_ref(codes, codebooks, w0)


def _fwd(codes, codebooks, w0, block_b, block_d, interpret, use_kernel):
    out = _hash_decode(codes, codebooks, w0, block_b, block_d, interpret, use_kernel)
    return out, (codes, codebooks, w0)


def _bwd(block_b, block_d, interpret, use_kernel, res, g):
    codes, codebooks, w0 = res
    m, c, _ = codebooks.shape
    g = g.astype(jnp.float32)
    gw = g * w0.astype(jnp.float32)[None, :] if w0 is not None else g
    onehot = (codes[:, :, None] == jnp.arange(c)[None, None, :]).astype(jnp.float32)
    d_cb = jnp.einsum("bmc,bd->mcd", onehot, gw).astype(codebooks.dtype)
    if w0 is not None:
        summed = jnp.einsum("bmc,mcd->bd", onehot, codebooks.astype(jnp.float32))
        d_w0 = jnp.einsum("bd,bd->d", g, summed).astype(w0.dtype)
    else:
        d_w0 = None
    return None, d_cb, d_w0


_hash_decode.defvjp(_fwd, _bwd)


def hash_decode(
    codes: jnp.ndarray,
    codebooks: jnp.ndarray,
    w0: Optional[jnp.ndarray] = None,
    *,
    block_b: int = 256,
    block_d: int = 256,
    interpret: bool = False,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """codes (B, m) int32, codebooks (m, c, d_c) -> (B, d_c) f32.

    Unaligned shapes fall back to the jnp reference path with a one-time
    warning; callers that want the kernel unconditionally should pad to
    block multiples first (``core.backend.PallasBackend`` does exactly
    that)."""
    global _warned_fallback
    B = codes.shape[0]
    d_c = codebooks.shape[2]
    if use_kernel and not _aligned(B, d_c, block_b, block_d):
        if not _warned_fallback:
            _warned_fallback = True
            warnings.warn(
                f"hash_decode: shapes B={B}, d_c={d_c} not tileable with "
                f"blocks ({block_b}, {block_d}); falling back to the jnp "
                f"reference path (pad inputs, e.g. via "
                f"repro.core.backend.PallasBackend, to run the kernel)",
                stacklevel=2)
        use_kernel = False
    return _hash_decode(codes, codebooks, w0, block_b, block_d, interpret, use_kernel)
