"""Pallas TPU kernels for the framework's compute hot-spots.

hash_decode     fused one-hot x codebook decode (the paper's hot op on TPU)
lsh_encode      streaming projection + binarise + bit-pack (Algorithm 1)
flash_attention blocked online-softmax attention w/ native GQA (LM backbone)

Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd wrapper,
custom VJP, oracle fallback), ref.py (pure-jnp oracle).  Kernels validate in
interpret mode on CPU; TPU is the deployment target.
"""

import jax.experimental.pallas.tpu as _pltpu

# jax renamed TPUCompilerParams -> CompilerParams and TPUMemorySpace ->
# MemorySpace (~0.5); support both spellings.
TPUCompilerParams = getattr(_pltpu, "CompilerParams", None) or \
    getattr(_pltpu, "TPUCompilerParams")
TPUMemorySpace = getattr(_pltpu, "MemorySpace", None) or \
    getattr(_pltpu, "TPUMemorySpace")
