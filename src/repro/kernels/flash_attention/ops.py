"""jit'd wrapper with custom VJP for the flash-attention kernel.

Public layout matches nn.attention: q (B, S, H, D), k/v (B, S, K, D).
Forward: Pallas kernel (or the jnp oracle for unaligned shapes / CPU).
Backward: XLA recompute (standard memory-saving trade: the bwd re-runs the
reference attention under the residual-free recompute policy; a dedicated
bwd kernel is a further optimisation recorded in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ref import mha_ref


def _to_bhsd(x):
    return jnp.swapaxes(x, 1, 2)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, block_q, block_k, interpret, use_kernel):
    if use_kernel:
        return flash_attention_bhsd(
            q, k, v, causal=causal, block_q=block_q, block_k=block_k,
            interpret=interpret,
        )
    return mha_ref(q, k, v, causal=causal)


def _fwd(q, k, v, causal, block_q, block_k, interpret, use_kernel):
    out = _flash(q, k, v, causal, block_q, block_k, interpret, use_kernel)
    return out, (q, k, v)


def _bwd(causal, block_q, block_k, interpret, use_kernel, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: mha_ref(q, k, v, causal=causal), q, k, v)
    return vjp(g)


_flash.defvjp(_fwd, _bwd)


def flash_attention(
    q: jnp.ndarray,       # (B, S, H, D)
    k: jnp.ndarray,       # (B, S, K, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
    use_kernel: bool = True,
) -> jnp.ndarray:
    qh, kh, vh = _to_bhsd(q), _to_bhsd(k), _to_bhsd(v)
    Sq, Skv = qh.shape[2], kh.shape[2]
    if Sq % min(block_q, Sq) or Skv % min(block_k, Skv):
        use_kernel = False
    out = _flash(qh, kh, vh, causal, block_q, block_k, interpret, use_kernel)
    return _to_bhsd(out)
