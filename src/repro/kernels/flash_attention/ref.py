"""Pure-jnp oracle for blocked (flash) attention.

q (B, H, Sq, D), k/v (B, K, Skv, D), GQA with G = H // K; f32 softmax;
optional causal mask with ``q_offset`` (decode windows).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha_ref(q, k, v, *, causal: bool = True, q_offset: int = 0) -> jnp.ndarray:
    B, H, Sq, D = q.shape
    K = k.shape[1]
    G = H // K
    qg = q.reshape(B, K, G, Sq, D)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k).astype(jnp.float32)
    s *= 1.0 / jnp.sqrt(D).astype(jnp.float32)
    if causal:
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(k.shape[2])
        mask = kpos[None, :] <= qpos[:, None]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bksd->bkgqd", w, v)
    return out.reshape(B, H, Sq, D)
