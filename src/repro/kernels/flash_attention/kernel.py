"""Pallas TPU kernel: FlashAttention-style blocked online-softmax attention
with native GQA (kv panels indexed by q-head // group via the BlockSpec
index map — no KV replication in HBM).

Grid: (B, H, Sq/block_q, Skv/block_k); the last axis is 'arbitrary'
(sequential) and carries the online-softmax state in VMEM scratch:
  m (block_q,)   running row max
  l (block_q,)   running row sum
  acc (block_q, D) running weighted values
Output is written once, at the final kv step.

VMEM at defaults (block_q=block_k=512, D=128, bf16 in / f32 acc):
  q 512·128·2 = 128 KiB, k/v panels 2·128 KiB, scores 512·512·4 = 1 MiB,
  acc 512·128·4 = 256 KiB  →  ≈ 1.8 MiB.

Causal skipping: fully-masked kv blocks short-circuit (pl.when), so the
causal pass does ~half the matmul work, matching the flash roofline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import TPUCompilerParams, TPUMemorySpace

NEG_INF = -1e30


def _flash_body(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale: float, causal: bool, block_q: int, block_k: int,
                n_kblocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # visible iff the block intersects the causal triangle
    run = True
    if causal:
        run = (ik * block_k) <= (iq * block_q + block_q - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                    # (bq, bk)
        if causal:
            qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(ik == n_kblocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"),
)
def flash_attention_bhsd(
    q: jnp.ndarray,       # (B, H, Sq, D)
    k: jnp.ndarray,       # (B, K, Skv, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, Sq, D = q.shape
    K, Skv = k.shape[1], k.shape[2]
    assert H % K == 0
    G = H // K
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv, block_q, block_k)
    grid = (B, H, Sq // block_q, Skv // block_k)
    scale = 1.0 / (D ** 0.5)

    return pl.pallas_call(
        functools.partial(
            _flash_body, scale=scale, causal=causal,
            block_q=block_q, block_k=block_k, n_kblocks=grid[3],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        scratch_shapes=[
            TPUMemorySpace.VMEM((block_q,), jnp.float32),
            TPUMemorySpace.VMEM((block_q,), jnp.float32),
            TPUMemorySpace.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
