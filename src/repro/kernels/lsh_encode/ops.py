"""jit'd wrapper: full packed-code encode built on the word kernel.

``lsh_encode_packed`` reproduces core.lsh.encode_lsh for dense auxiliary
matrices, word by word, with the projection+pack fused in Pallas.  The
median thresholds come from an exact in-core pass by default; at
out-of-core scale pass ``median_sample`` to estimate the median from a row
subsample (a √n-sample median is within O(n^-1/4) quantile error — fine for
a collision-reduction heuristic).  Encode-time only (no gradients;
Algorithm 1 is training-free).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import codes as codes_lib
from repro.kernels.lsh_encode.kernel import lsh_encode_word
from repro.kernels.lsh_encode.ref import lsh_encode_word_ref


def lsh_encode_packed(
    key: jax.Array,
    A: jnp.ndarray,
    c: int,
    m: int,
    *,
    threshold: str = "median",
    median_sample: Optional[int] = None,
    block_n: int = 1024,
    block_d: int = 512,
    interpret: bool = False,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """(n, d) dense aux -> (n, n_words) uint32 packed codes."""
    nb = codes_lib.n_bits(c, m)
    nw = codes_lib.n_words(c, m)
    n, d = A.shape
    if n % min(block_n, n) or d % min(block_d, d):
        use_kernel = False
    words = []
    for widx in range(nw):
        key, sub = jax.random.split(key)
        wbits = min(codes_lib.WORD_BITS, nb - widx * codes_lib.WORD_BITS)
        V = jax.random.normal(sub, (d, wbits), jnp.float32)
        if threshold == "median":
            if median_sample is not None and median_sample < n:
                ridx = jax.random.choice(jax.random.fold_in(sub, 1), n,
                                         (median_sample,), replace=False)
                t = jnp.median(A[ridx].astype(jnp.float32) @ V, axis=0)
            else:
                t = jnp.median(A.astype(jnp.float32) @ V, axis=0)
        elif threshold == "zero":
            t = jnp.zeros((wbits,), jnp.float32)
        else:
            raise ValueError(threshold)
        if use_kernel:
            word = lsh_encode_word(A, V, t, block_n=block_n, block_d=block_d,
                                   interpret=interpret)[:, 0]
        else:
            word = lsh_encode_word_ref(A, V, t)
        words.append(word)
    return jnp.stack(words, axis=1)
