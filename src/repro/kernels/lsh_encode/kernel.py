"""Pallas TPU kernel: fused random-projection + binarise + bit-pack
(one 32-bit code word per entity; Algorithm 1's inner loops).

This step is bandwidth-bound (A streams from HBM); the fusion keeps the
projection result, binarisation and bit-pack on-chip, so peak extra memory
is O(block) rather than O(n·32·4B).  At the paper's industrial scale
(n ≈ 10⁹ cards) a materialised projection would be ~128 GB — bigger than
HBM — so out-of-core encode *requires* this streaming form; thresholds are
supplied by the caller (exact median in-core, or a row-sampled median
estimate at out-of-core scale — see ops.lsh_encode_packed).

Grid: (n / block_n, d / block_d) — the d dimension accumulates into a VMEM
scratch; at the last d-step the thresholds (SMEM-resident, computed by the
host-level median pass) binarise the projection and the 32 bit-columns are
packed into one uint32 lane.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels import TPUCompilerParams, TPUMemorySpace


def _encode_body(a_ref, v_ref, t_ref, o_ref, acc_ref, *, n_dblocks: int):
    jd = pl.program_id(1)

    @pl.when(jd == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)           # (bn, bd)
    v = v_ref[...].astype(jnp.float32)           # (bd, w)
    acc_ref[...] += jax.lax.dot_general(
        a, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(jd == n_dblocks - 1)
    def _():
        u = acc_ref[...]                         # (bn, w)
        t = t_ref[...].astype(jnp.float32)       # (1, w)
        bits = (u > t).astype(jnp.uint32)
        shifts = jax.lax.broadcasted_iota(jnp.uint32, bits.shape, 1)
        word = jnp.sum(bits << shifts, axis=1, dtype=jnp.uint32, keepdims=True)
        o_ref[...] = word


@functools.partial(jax.jit, static_argnames=("block_n", "block_d", "interpret"))
def lsh_encode_word(
    A: jnp.ndarray,          # (n, d)
    V: jnp.ndarray,          # (d, w)  w <= 32
    t: jnp.ndarray,          # (w,) thresholds
    *,
    block_n: int = 1024,
    block_d: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    n, d = A.shape
    w = V.shape[1]
    block_n = min(block_n, n)
    block_d = min(block_d, d)
    assert n % block_n == 0 and d % block_d == 0, (n, d, block_n, block_d)
    grid = (n // block_n, d // block_d)
    return pl.pallas_call(
        functools.partial(_encode_body, n_dblocks=grid[1]),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.uint32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((block_d, w), lambda i, j: (j, 0)),
            pl.BlockSpec((1, w), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i, j: (i, 0)),
        scratch_shapes=[TPUMemorySpace.VMEM((block_n, w), jnp.float32)],
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="lsh_encode_word",
    )(A, V, t.reshape(1, w))
