"""Pure-jnp oracle for the LSH projection+binarise+pack kernel.

Semantics: one 32-bit output word per entity —
  U = A @ V            (A (n, d), V (d, w<=32))
  bits = U > t         (t (w,) thresholds, typically the per-column median)
  word = Σ bits_i << i (little-endian within the word)
"""

from __future__ import annotations

import jax.numpy as jnp


def lsh_encode_word_ref(A: jnp.ndarray, V: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    U = A.astype(jnp.float32) @ V.astype(jnp.float32)
    bits = (U > t[None, :]).astype(jnp.uint32)
    shifts = jnp.arange(V.shape[1], dtype=jnp.uint32)
    return (bits << shifts).sum(axis=-1, dtype=jnp.uint32)
