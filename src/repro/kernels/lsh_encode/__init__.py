from repro.kernels.lsh_encode.ops import lsh_encode_packed
from repro.kernels.lsh_encode.ref import lsh_encode_word_ref

__all__ = ["lsh_encode_packed", "lsh_encode_word_ref"]
