"""Continuous-batching serving tier (ISSUE 7).

``ServingBatcher`` puts an admission-controlled request queue in front of
any ``serving.Engine``.  Clients ``submit()`` a request and get a
``concurrent.futures.Future`` back (or call the synchronous ``serve()``,
which is just submit-and-wait — the batcher itself satisfies the
``Engine`` protocol, so it drops into every harness an engine does).  One
worker thread coalesces queued requests into microbatches, flushing when
``max_batch`` requests are waiting OR ``max_delay_ms`` has elapsed since
the oldest one arrived, and serves each microbatch in a single engine
call.

Engines exposing ``serve_many`` (the GNN ``GraphInferenceEngine``) get
**cross-request frontier dedup**: the whole microbatch dedups into one
unique-node frontier, so a hub node requested by many concurrent users
samples and decodes once per microbatch — the PR-1 per-request trick
applied across requests, on top of the shared hot-node cache.  Engines
without it (the LM ``DecodeEngine``) still sit behind the same queue: the
microbatch falls back to per-request ``serve`` calls, keeping admission,
backpressure, and the threading contract uniform across workloads.

Backpressure is a bounded queue: past ``queue_depth`` waiting requests,
``submit`` sheds LOUDLY — it raises ``Overloaded`` carrying a
``retry_after_s`` estimate derived from the flush cadence — instead of
growing an unbounded backlog whose tail latency nobody asked for.  Shed
requests are counted in ``stats()``.

Threading contract: ALL engine calls happen on the batcher's single
worker thread, so the engine needs no internal locking; once an engine is
wrapped, drive it only through the batcher.  ``close()`` drains every
admitted request before returning — an accepted request is never dropped.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Any, Deque, Dict, List, Optional, Tuple

__all__ = ["BatchingSpec", "Overloaded", "ServingBatcher"]


@dataclasses.dataclass(frozen=True)
class BatchingSpec:
    """Declarative continuous-batching knobs.

    Lives on ``RuntimeSpec.batching`` (``graph.runtime``), so turning the
    serving tier on is a spec field change that JSON/checkpoint
    round-trips like every other pipeline knob.

    ``max_batch``     requests coalesced per microbatch (size flush); also
                      sizes the engine's request-count jit buckets.
    ``max_delay_ms``  deadline flush: the longest a queued request waits
                      for company before the microbatch goes anyway — the
                      latency the tail of a quiet period pays for
                      coalescing.
    ``queue_depth``   admission bound: waiting requests beyond this are
                      shed with ``Overloaded`` (retry-after) instead of
                      queuing unboundedly.
    """

    max_batch: int = 8
    max_delay_ms: float = 2.0
    queue_depth: int = 64

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_delay_ms < 0:
            raise ValueError(
                f"max_delay_ms must be >= 0, got {self.max_delay_ms}")
        if self.queue_depth < 1:
            raise ValueError(
                f"queue_depth must be >= 1, got {self.queue_depth}")


class Overloaded(RuntimeError):
    """Admission control shed: the serving queue is full.

    ``retry_after_s`` estimates when a slot frees up (queue depth over the
    flush cadence) — a hint for client backoff, not a reservation."""

    def __init__(self, queued: int, retry_after_s: float):
        super().__init__(
            f"serving queue full ({queued} requests waiting); retry in "
            f"~{retry_after_s * 1e3:.0f} ms")
        self.queued = queued
        self.retry_after_s = retry_after_s


class ServingBatcher:
    """Async microbatching front end over a ``serving.Engine``.

    ``serve_kwargs`` are forwarded to every engine call (e.g.
    ``max_new_tokens`` for the LM engine) — per-batcher, not per-request,
    so one microbatch is always one engine configuration."""

    def __init__(self, engine, spec: Optional[BatchingSpec] = None,
                 serve_kwargs: Optional[Dict[str, Any]] = None):
        self.spec = spec if spec is not None else BatchingSpec()
        max_coalesce = getattr(engine, "max_coalesce", None)
        if max_coalesce is not None and self.spec.max_batch > max_coalesce:
            raise ValueError(
                f"BatchingSpec.max_batch={self.spec.max_batch} exceeds the "
                f"engine's max_coalesce={max_coalesce}; build the engine "
                f"with max_coalesce >= max_batch")
        self.engine = engine
        self._serve_kwargs = dict(serve_kwargs or {})
        self._serve_many = getattr(engine, "serve_many", None)
        self._q: Deque[Tuple[Any, Future]] = deque()
        self._lock = threading.Lock()
        self._wakeup = threading.Condition(self._lock)
        self._closed = False
        self._submitted = 0
        self._completed = 0
        self._shed = 0
        self._microbatches = 0
        self._max_coalesced = 0
        self._worker = threading.Thread(
            target=self._run, name="serving-batcher", daemon=True)
        self._worker.start()

    # -- client API ------------------------------------------------------
    def submit(self, request) -> Future:
        """Enqueue one request; resolves to the engine's result for it.
        Raises ``Overloaded`` (with ``retry_after_s``) when the queue is
        at ``queue_depth`` — admission control happens HERE, at the edge,
        so an accepted request is never silently dropped later."""
        with self._lock:
            if self._closed:
                raise RuntimeError("ServingBatcher is closed")
            if len(self._q) >= self.spec.queue_depth:
                self._shed += 1
                raise Overloaded(len(self._q), self._retry_after_locked())
            fut: Future = Future()
            self._q.append((request, fut))
            self._submitted += 1
            self._wakeup.notify_all()
        return fut

    def serve(self, request, **_ignored):
        """``Engine``-protocol entry point: submit and wait."""
        return self.submit(request).result()

    def stats(self) -> Dict[str, Any]:
        """Batcher counters plus (when available) the engine's own."""
        with self._lock:
            out: Dict[str, Any] = {
                "submitted": self._submitted,
                "completed": self._completed,
                "shed": self._shed,
                "queued": len(self._q),
                "microbatches": self._microbatches,
                "max_coalesced": self._max_coalesced,
                "mean_coalesced": (self._completed
                                   / max(self._microbatches, 1)),
            }
        engine_stats = getattr(self.engine, "stats", None)
        if callable(engine_stats):
            out["engine"] = engine_stats()
        return out

    def close(self) -> None:
        """Stop admitting, drain every already-admitted request, join the
        worker.  Idempotent."""
        with self._lock:
            self._closed = True
            self._wakeup.notify_all()
        self._worker.join()

    def __enter__(self) -> "ServingBatcher":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # -- worker ----------------------------------------------------------
    def _retry_after_locked(self) -> float:
        # drain-rate estimate: one flush cycle clears up to max_batch
        # requests per max_delay_ms (service time comes on top — this is a
        # backoff hint, not a promise)
        per_batch_s = max(self.spec.max_delay_ms, 1.0) / 1e3
        batches_ahead = len(self._q) // self.spec.max_batch + 1
        return batches_ahead * per_batch_s

    def _run(self) -> None:
        spec = self.spec
        while True:
            with self._wakeup:
                while not self._q and not self._closed:
                    self._wakeup.wait()
                if not self._q:          # closed AND drained
                    return
                if not self._closed and len(self._q) < spec.max_batch:
                    # deadline flush: wait (briefly) for company
                    deadline = time.monotonic() + spec.max_delay_ms / 1e3
                    while len(self._q) < spec.max_batch and not self._closed:
                        remaining = deadline - time.monotonic()
                        if remaining <= 0:
                            break
                        self._wakeup.wait(timeout=remaining)
                batch = [self._q.popleft()
                         for _ in range(min(len(self._q), spec.max_batch))]
            self._serve_batch(batch)

    def _serve_batch(self, batch: List[Tuple[Any, Future]]) -> None:
        requests = [r for r, _ in batch]
        futures = [f for _, f in batch]
        try:
            if self._serve_many is not None:
                results = self._serve_many(requests, **self._serve_kwargs)
            else:
                results = [self.engine.serve(r, **self._serve_kwargs)
                           for r in requests]
            if len(results) != len(requests):
                raise RuntimeError(
                    f"engine returned {len(results)} results for "
                    f"{len(requests)} requests")
        except BaseException as exc:          # noqa: BLE001 — futures carry it
            for fut in futures:
                if not fut.cancelled():
                    fut.set_exception(exc)
            return
        for fut, res in zip(futures, results):
            if not fut.cancelled():
                fut.set_result(res)
        with self._lock:
            self._completed += len(batch)
            self._microbatches += 1
            self._max_coalesced = max(self._max_coalesced, len(batch))
