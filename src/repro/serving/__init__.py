from repro.serving.engine import DecodeEngine, Engine, GenerationResult
from repro.serving.gnn import GraphInferenceEngine, GraphServeResult

__all__ = [
    "DecodeEngine", "Engine", "GenerationResult",
    "GraphInferenceEngine", "GraphServeResult",
]
