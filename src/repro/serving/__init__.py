from repro.serving.batcher import BatchingSpec, Overloaded, ServingBatcher
from repro.serving.engine import DecodeEngine, Engine, GenerationResult
from repro.serving.gnn import GraphInferenceEngine, GraphServeResult

__all__ = [
    "BatchingSpec", "Overloaded", "ServingBatcher",
    "DecodeEngine", "Engine", "GenerationResult",
    "GraphInferenceEngine", "GraphServeResult",
]
