"""Batched serving engines behind one ``Engine`` protocol.

Two engines share the serving surface: the LM ``DecodeEngine`` (prefill →
per-token decode against KV/SSM caches) and the GNN
``GraphInferenceEngine`` (``repro.serving.gnn``: frontier sample →
miss-only cached decode → forward).  Both freeze params at construction,
fail fast on unknown decode-backend names, run fixed-shape jitted steps,
and expose one batched ``serve(requests)`` entry point — which is what the
``Engine`` protocol pins down, so callers (examples, benchmarks, the CI
serve smoke) can drive either engine without caring which workload is
behind it.

Distribution comes from the same pjit policy as the dry-run
(params_shardings / cache_shardings_policy); on one host everything just
runs jit'd.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.core import backend as backend_mod
from repro.models.lm import init_cache, lm_forward
from repro.train.step import make_prefill_step, make_serve_step


@runtime_checkable
class Engine(Protocol):
    """Shared serving surface: frozen params + fixed-shape jitted steps
    behind one batched request entry point.

    ``serve(requests, **kwargs)`` takes one request batch (token prompts
    for the LM engine, node ids for the GNN engine) and returns a
    result dataclass; engines may add richer typed methods beside it
    (``generate``, ``embed``, ``predict``), but ``serve`` is the common
    denominator the protocol guarantees."""

    def serve(self, requests, **kwargs): ...


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray      # (B, prompt + generated)
    steps: int


class DecodeEngine:
    """``decode_backend`` pins the embedding decode path for serving
    (compressed vocabularies re-decode token embeddings every step, so the
    backend choice is on the serving hot path).  ``None`` keeps the config's
    ``lookup_impl``; ``"auto"`` resolves to the fused pallas kernel on TPU
    runtimes.  Unknown names fail here, at engine construction, not on the
    first request."""

    def __init__(self, cfg: LMConfig, params, s_max: int = 1024,
                 decode_backend: Optional[str] = None):
        if decode_backend is not None:
            resolved = (backend_mod.resolve_auto()
                        if decode_backend == "auto" else decode_backend)
            backend_mod.get_backend(resolved)   # fail fast on unknown names
            cfg = dataclasses.replace(
                cfg, embedding=dataclasses.replace(
                    cfg.embedding, lookup_impl=resolved))
        self.cfg = cfg
        self.decode_backend = cfg.embedding.lookup_impl
        self.params = params
        self.s_max = s_max
        self._prefill = jax.jit(make_prefill_step(cfg, s_max))
        self._serve = jax.jit(make_serve_step(cfg))

    def _sample(self, logits, key, temperature: float):
        logits = jnp.where(
            jnp.arange(logits.shape[-1]) >= self.cfg.vocab_size, -1e30, logits)
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 temperature: float = 0.0, seed: int = 0) -> GenerationResult:
        """prompts: (B, S0) int32 (audio: (B, S0, nq))."""
        key = jax.random.PRNGKey(seed)
        tokens = jnp.asarray(prompts, jnp.int32)
        B = tokens.shape[0]
        last_logits, cache = self._prefill(self.params, {"tokens": tokens})
        out = [tokens]
        for step in range(max_new_tokens):
            key, sub = jax.random.split(key)
            nxt = self._sample(last_logits, sub, temperature)
            if self.cfg.input_mode == "audio_tokens":
                nxt_tok = nxt[:, None, :] if nxt.ndim == 2 else nxt[:, None]
            else:
                nxt_tok = nxt[:, None]
            out.append(nxt_tok)
            last_logits, cache = self._serve(self.params, cache, {"tokens": nxt_tok})
        return GenerationResult(
            tokens=np.asarray(jnp.concatenate(out, axis=1)), steps=max_new_tokens)

    def serve(self, requests, max_new_tokens: int = 32,
              temperature: float = 0.0, seed: int = 0,
              **_ignored) -> GenerationResult:
        """``Engine``-protocol entry point: one batch of prompts in, a
        ``GenerationResult`` out (thin alias of ``generate``).  Unknown
        kwargs are ignored, so protocol-level callers (the batcher, shared
        harnesses) can pass engine-agnostic options."""
        return self.generate(np.asarray(requests), max_new_tokens,
                             temperature=temperature, seed=seed)
