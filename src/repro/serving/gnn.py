"""Batched GNN inference engine (the §5.3 merchant-system serving shape).

``GraphInferenceEngine`` is the GNN twin of the LM ``DecodeEngine`` behind
the shared ``serving.Engine`` protocol: frozen params, fixed-shape jitted
steps, a batched request entry point.  Per request:

    sample frontier  →  miss-only cached decode  →  forward  →  (h, logits)

The decode path is where serving differs from training: request streams
revisit hot (high-degree) nodes constantly and the params never change
between requests, so a decoded embedding never goes stale.  The engine
therefore keeps a device-resident ``CacheState`` across requests and
partitions every frontier host-side (``CachedDecodeBackend.plan_missonly``)
into a padded miss-prefix — **only cache misses enter the decoder**, and
``rows_decoded`` (vs the full frontier row count) is the measured win
(``benchmarks/serving_gnn.py``, ``BENCH_decode.json``).

Cross-request dedup (``serve_many``, ISSUE 7): a microbatch of concurrent
requests — coalesced by ``serving.batcher.ServingBatcher`` — concatenates
its sampled levels and dedups them in ONE ``FrontierBatch``, so a hub node
requested by many users in the same microbatch samples and decodes exactly
once; per-request results are rebuilt by slicing the combined forward.
This stacks as the third dedup tier: within-request (PR 1) → shared hot
``CacheState`` across requests (PR 4) → union-of-misses decode across the
microbatch.

Fixed shapes: the request batch pads to ``serve_batch``, the request count
to a power-of-two bucket (filler requests repeat request 0's levels, whose
rows are already in the union — zero extra decode work), and the frontier
to an exact per-bucket cap, so the forward jits once per
(miss-bucket, request-bucket) pair — buckets grow geometrically from
``pad_to``, bounding compilations at ~log2(cap/pad_to) + 2 per request
bucket (``decode_buckets``, asserted in tests/test_serving.py).

Bit-exactness: hits are embeddings the same frozen params decoded earlier,
and the request frontier is content-keyed (a pure function of the engine
seed and the requested ids, NOT of arrival order), so a batched response
is bitwise the sequential ``serve()`` response no matter how requests
interleave — cache reuse and cross-request coalescing are both free at
serving time (tests/test_runtime.py, tests/test_serving.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.core import backend as backend_mod
from repro.core.backend import CachedDecodeBackend, CacheState
from repro.graph.sampler import FrontierBatch, NeighborSampler, _mix64
from repro.models import gnn as gnn_lib


@dataclasses.dataclass
class GraphServeResult:
    """One served request batch."""
    embeddings: np.ndarray              # (B, H) final hidden per node
    logits: Optional[np.ndarray]        # (B, n_classes) when task == "node"
    predictions: Optional[np.ndarray]   # (B,) argmax labels (node task)
    rows_decoded: int                   # decoder rows the microbatch paid
    rows_total: int                     # frontier rows (padded cap × requests)
    batch_requests: int = 1             # requests coalesced in the microbatch


class GraphInferenceEngine:
    """Frozen-params GNN serving over the minibatched GraphSAGE path.

    ``decode_backend`` pins the embedding decode path (same contract as
    ``DecodeEngine``): ``None`` keeps the config's ``lookup_impl``,
    ``"auto"`` resolves for the current runtime, unknown names fail here —
    at engine construction — not on the first request.  ``cache_capacity``
    sizes the cross-request hot-node cache (0 disables it; the default
    keeps ~4 frontiers' worth of rows).

    ``host_codes`` is the full packed code buffer when the params were built
    with ``codes_placement="host"`` (they then carry no ``codes_buf``): the
    engine gathers each serving frontier's code rows host-side — after the
    miss-first permutation, so rows stay row-aligned — and the device holds
    only O(frontier) code bytes per microbatch.
    """

    def __init__(self, cfg: GNNConfig, params, sampler: NeighborSampler, *,
                 decode_backend: Optional[str] = None, serve_batch: int = 256,
                 frontier_cap: Optional[int] = None, pad_to: int = 256,
                 cache_capacity: Optional[int] = None, seed: int = 0,
                 max_coalesce: int = 8, interpret: bool = False,
                 host_codes: Optional[np.ndarray] = None):
        if cfg.model != "sage":
            raise ValueError(
                f"GraphInferenceEngine serves minibatched GraphSAGE; got "
                f"model={cfg.model!r} (full-graph models evaluate via "
                f"GraphRuntime.evaluate)")
        if decode_backend is not None:
            resolved = (backend_mod.resolve_auto()
                        if decode_backend == "auto" else decode_backend)
            backend_mod.get_backend(resolved, interpret=interpret)
            # execution strategy is servable-time-swappable; the compression
            # FAMILY is baked into the trained params' layout and is not
            have = backend_mod.family_of(cfg.embedding.lookup_impl)
            want = backend_mod.family_of(resolved)
            if want != have:
                raise ValueError(
                    f"decode_backend={decode_backend!r} selects compression "
                    f"family {want!r} but the params were trained as "
                    f"{have!r} (lookup_impl={cfg.embedding.lookup_impl!r}); "
                    f"the family is a training-time choice")
            cfg = dataclasses.replace(
                cfg, embedding=dataclasses.replace(
                    cfg.embedding, lookup_impl=resolved))
        self.cfg = cfg
        self.params = params
        self.sampler = sampler
        self.serve_batch = int(serve_batch)
        self.pad_to = int(pad_to)
        self.seed = int(seed)
        self.max_coalesce = int(max_coalesce)
        if self.max_coalesce < 1:
            raise ValueError(f"max_coalesce must be >= 1, got {max_coalesce}")
        self.interpret = bool(interpret)
        ecfg = cfg.embedding_config()
        self._backend = backend_mod.get_backend(ecfg.lookup_impl,
                                                interpret=interpret)
        self.host_codes = (None if host_codes is None
                           else np.asarray(host_codes, np.uint32))
        if ecfg.codes_on_host and self.host_codes is None:
            raise ValueError(
                "codes_placement='host' params carry no codes_buf — pass "
                "host_codes (the full packed buffer) to the engine")

        from repro.graph.engine import default_frontier_cap
        self.frontier_cap = int(
            frontier_cap if frontier_cap is not None
            else default_frontier_cap(self.serve_batch, cfg.fanouts,
                                      self.pad_to, cfg.n_nodes))

        if cache_capacity is None:
            cache_capacity = (min(4 * self.frontier_cap, cfg.n_nodes)
                              if ecfg.is_compressed else 0)
        self.cache_capacity = int(cache_capacity)
        self.cached = ecfg.is_compressed and self.cache_capacity > 0
        # params are frozen at serve time, so the version counter never
        # bumps and staleness 0 still means "every hit is forever fresh"
        self._cache = CachedDecodeBackend(staleness=0)
        self._cache_state = (CacheState.create(
            self.cache_capacity, cfg.d_e,
            jax.numpy.dtype(cfg.compute_dtype)) if self.cached else None)

        self._fwd_cache: Dict[int, object] = {}
        self._requests = 0
        self._microbatches = 0
        self._rows_decoded = 0
        self._rows_total = 0
        self._compile_count = 0

    # -- internals -------------------------------------------------------
    def _request_rng(self, padded_ids: np.ndarray) -> np.random.Generator:
        """Content-keyed request PRNG: the neighbour draws for a request are
        a pure function of ``(engine seed, requested ids)`` — NOT of arrival
        order or a request counter — so a request coalesced into any
        microbatch samples exactly the frontier a sequential ``serve`` of
        the same ids would (the ordering-independence the batcher's bitwise
        contract rests on)."""
        with np.errstate(over="ignore"):
            h = _mix64(padded_ids.astype(np.uint64)
                       + (np.arange(padded_ids.shape[0], dtype=np.uint64)
                          + np.uint64(1))
                       * np.uint64(0x9E3779B97F4A7C15))
            key = _mix64(np.bitwise_xor.reduce(h)
                         ^ np.uint64(self.seed * 1_000_003 + 777_767_777))
        return np.random.default_rng(int(key))

    def _sample_levels(self, padded_ids: np.ndarray) -> List[np.ndarray]:
        """Sampled (un-dedup'd) level tensors for one padded request."""
        return self.sampler.sample(padded_ids,
                                   rng=self._request_rng(padded_ids))

    def frontier_for(self, node_ids) -> FrontierBatch:
        """The exact (padded, fixed-cap) frontier ``serve`` samples for a
        request — exposed so parity tests can run ``GNNModel.apply`` on the
        same batch.  Deterministic in ``(seed, node_ids)``."""
        ids = self._pad_request(np.asarray(node_ids, np.int32))
        fb = FrontierBatch.from_levels(self._sample_levels(ids),
                                       pad_to=self.pad_to,
                                       cap=self.frontier_cap)
        return self._attach_codes(fb)

    def _attach_codes(self, fb: FrontierBatch) -> FrontierBatch:
        if self.host_codes is None:
            return fb
        from repro.graph.sampler import attach_codes
        return attach_codes(fb, self.host_codes)

    def _pad_request(self, ids: np.ndarray) -> np.ndarray:
        if ids.shape[0] > self.serve_batch:
            raise ValueError(
                f"request batch {ids.shape[0]} > serve_batch "
                f"{self.serve_batch}; chunk requests host-side")
        if ids.shape[0] < self.serve_batch:
            ids = np.concatenate(
                [ids, np.full(self.serve_batch - ids.shape[0], ids[0],
                              ids.dtype)])
        return ids

    def _bucket(self, n_miss: int, cap: Optional[int] = None) -> int:
        """Geometric miss-count buckets: one jit shape per bucket.  ``cap``
        defaults to the single-request ``frontier_cap``; microbatches pass
        their combined (request-bucket × cap) frontier size."""
        cap = self.frontier_cap if cap is None else cap
        if n_miss <= 0:
            return 0
        b = self.pad_to
        while b < n_miss:
            b *= 2
        return min(b, cap)

    def _request_bucket(self, k: int) -> int:
        """Power-of-two request-count buckets (capped at ``max_coalesce``)
        so the combined forward sees a bounded set of batch shapes."""
        b = 1
        while b < k:
            b *= 2
        return min(b, self.max_coalesce)

    def decode_buckets(self, max_requests: int = 1) -> Tuple[int, ...]:
        """Every static decode-row bucket a ≤ ``max_requests`` microbatch
        can produce — the jitted forward compiles at most once per bucket
        per request-count bucket, which is the compile bound the
        shape-bucketing regression test pins (tests/test_serving.py)."""
        cap = self._request_bucket(max_requests) * self.frontier_cap
        if not self.cached:
            return (cap,)
        out, b = [0, cap], self.pad_to
        while b < cap:
            out.append(b)
            b *= 2
        return tuple(sorted(set(out)))

    def _forward(self, n_decode: int):
        if n_decode not in self._fwd_cache:
            cfg, backend = self.cfg, self._backend
            node_task = cfg.task == "node"

            if self.cached:
                def fwd(params, fb, cache_state):
                    self._compile_count += 1     # trace-time side effect
                    h, new_state = gnn_lib.sage_forward_frontier_missonly(
                        params, fb, cfg, cache_state, n_decode,
                        backend=backend)
                    logits = (gnn_lib.node_logits(params, h, cfg)
                              if node_task else None)
                    return h, logits, new_state
            else:
                def fwd(params, fb, cache_state):
                    self._compile_count += 1     # trace-time side effect
                    h = gnn_lib.sage_forward_frontier(params, fb, cfg,
                                                      backend=backend)
                    logits = (gnn_lib.node_logits(params, h, cfg)
                              if node_task else None)
                    return h, logits, cache_state
            self._fwd_cache[n_decode] = jax.jit(fwd)
        return self._fwd_cache[n_decode]

    # -- request API -----------------------------------------------------
    def serve(self, node_ids, **_ignored) -> GraphServeResult:
        """Serve one request batch of node ids (≤ ``serve_batch``)."""
        return self.serve_many([node_ids])[0]

    def serve_many(self, requests: Sequence, **_ignored
                   ) -> List[GraphServeResult]:
        """Serve a microbatch of requests with **cross-request frontier
        dedup**: all requests' sampled levels concatenate into one
        ``FrontierBatch``, so a node appearing in several requests decodes
        at most once per microbatch (and not at all when the shared hot
        cache holds it).  Responses are bitwise what sequential ``serve``
        calls on the same requests return — frontiers are content-keyed and
        decode is row-pure, so coalescing is invisible to clients."""
        reqs = [np.asarray(r, np.int32) for r in requests]
        if not reqs:
            return []
        k = len(reqs)
        if k > self.max_coalesce:
            raise ValueError(
                f"microbatch of {k} requests > max_coalesce="
                f"{self.max_coalesce}; raise max_coalesce at engine "
                f"construction (or lower the batcher's max_batch)")
        sizes = [r.shape[0] for r in reqs]
        per_levels = [self._sample_levels(self._pad_request(r))
                      for r in reqs]
        kb = self._request_bucket(k)
        # filler requests repeat request 0's levels: every one of their
        # rows is already in the union, so padding the request axis to its
        # bucket adds ZERO decode work
        per_levels += [per_levels[0]] * (kb - k)
        levels = [np.concatenate([pl[i] for pl in per_levels], axis=0)
                  for i in range(len(per_levels[0]))]
        cap = kb * self.frontier_cap
        fb = FrontierBatch.from_levels(levels, pad_to=self.pad_to, cap=cap)

        if self.cached:
            host_ids = np.asarray(self._cache_state.node_ids)
            valid = np.arange(cap) < int(fb.n_unique)
            perm, n_miss = CachedDecodeBackend.plan_missonly(
                host_ids, np.asarray(fb.unique), valid)
            inv = np.empty_like(perm)
            inv[perm] = np.arange(perm.shape[0], dtype=np.int32)
            fb = FrontierBatch(
                unique=np.asarray(fb.unique)[perm],
                index_maps=tuple(inv[np.asarray(m)] for m in fb.index_maps),
                n_unique=fb.n_unique,
                valid=valid[perm])
            # codes attach AFTER the miss-first permutation so the rows stay
            # aligned with the (permuted) unique frontier
            fb = self._attach_codes(fb)
            n_dec = self._bucket(n_miss, cap)
            h, logits, self._cache_state = self._forward(n_dec)(
                self.params, jax.device_put(fb), self._cache_state)
        else:
            fb = self._attach_codes(fb)
            n_dec = cap
            h, logits, _ = self._forward(-1)(self.params, jax.device_put(fb),
                                             None)

        rows_total = k * self.frontier_cap
        self._requests += k
        self._microbatches += 1
        self._rows_decoded += n_dec
        self._rows_total += rows_total

        h = np.asarray(h)
        logits = None if logits is None else np.asarray(logits)
        out = []
        for i, B in enumerate(sizes):
            lo = i * self.serve_batch
            hi = h[lo:lo + B]
            lg = None if logits is None else logits[lo:lo + B]
            preds = (None if lg is None
                     else lg.argmax(-1).astype(np.int32))
            out.append(GraphServeResult(
                embeddings=hi, logits=lg, predictions=preds,
                rows_decoded=n_dec, rows_total=rows_total,
                batch_requests=k))
        return out

    def embed(self, node_ids) -> np.ndarray:
        """Final hidden representations (B, H) — bitwise identical to
        ``GNNModel.apply`` on ``frontier_for(node_ids)``."""
        return self.serve(node_ids).embeddings

    def predict(self, node_ids) -> np.ndarray:
        """Argmax class per requested node (node-classification task)."""
        res = self.serve(node_ids)
        if res.predictions is None:
            raise ValueError("predict() needs a node-classification config")
        return res.predictions

    def stats(self) -> Dict[str, float]:
        """Cumulative serving counters since construction (or the last
        ``reset()``), plus ``compile_count`` — the number of jit traces the
        engine has paid over its LIFETIME (never reset: benchmarks call
        ``reset()`` after a warmup pass instead of hand-excluding the
        first, compile-paying request, and still see the true compile
        bill)."""
        out = {"requests": self._requests,
               "microbatches": self._microbatches,
               "rows_decoded": self._rows_decoded,
               "rows_total": self._rows_total,
               "rows_decoded_per_request": (
                   self._rows_decoded / max(self._requests, 1)),
               "compile_count": self._compile_count}
        if self.cached:
            st = self._cache_state
            hits, misses = int(st.hits), int(st.misses)
            out.update(hits=hits, misses=misses,
                       hit_rate=hits / max(hits + misses, 1))
        return out

    def reset(self) -> None:
        """Zero the cumulative request/row/hit counters WITHOUT touching
        the cache contents or the jit cache — call after a warmup pass so
        measured stats cover only steady-state traffic.  ``compile_count``
        survives (it is an engine-lifetime cost, not a per-window one)."""
        self._requests = 0
        self._microbatches = 0
        self._rows_decoded = 0
        self._rows_total = 0
        if self._cache_state is not None:
            self._cache_state = dataclasses.replace(
                self._cache_state,
                hits=jnp.zeros_like(self._cache_state.hits),
                misses=jnp.zeros_like(self._cache_state.misses))
