"""Batched GNN inference engine (the §5.3 merchant-system serving shape).

``GraphInferenceEngine`` is the GNN twin of the LM ``DecodeEngine`` behind
the shared ``serving.Engine`` protocol: frozen params, fixed-shape jitted
steps, a batched request entry point.  Per request:

    sample frontier  →  miss-only cached decode  →  forward  →  (h, logits)

The decode path is where serving differs from training: request streams
revisit hot (high-degree) nodes constantly and the params never change
between requests, so a decoded embedding never goes stale.  The engine
therefore keeps a device-resident ``CacheState`` across requests and
partitions every frontier host-side (``CachedDecodeBackend.plan_missonly``)
into a padded miss-prefix — **only cache misses enter the decoder**, and
``rows_decoded`` (vs the full frontier row count) is the measured win
(``benchmarks/serving_gnn.py``, ``BENCH_decode.json``).

Fixed shapes: the request batch pads to ``serve_batch`` and the frontier to
an exact ``frontier_cap``, so the forward jits once per miss-count bucket
(buckets grow geometrically from ``pad_to``, bounding compilations at
~log2(cap/pad_to) + 2).

Bit-exactness: hits are embeddings the same frozen params decoded earlier,
so ``engine.embed(ids)`` equals ``GNNModel.apply`` on the same frontier
bitwise — cache reuse is free at serving time (tests/test_runtime.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs.base import GNNConfig
from repro.core import backend as backend_mod
from repro.core.backend import CachedDecodeBackend, CacheState
from repro.graph.sampler import FrontierBatch, NeighborSampler
from repro.models import gnn as gnn_lib


@dataclasses.dataclass
class GraphServeResult:
    """One served request batch."""
    embeddings: np.ndarray              # (B, H) final hidden per node
    logits: Optional[np.ndarray]        # (B, n_classes) when task == "node"
    predictions: Optional[np.ndarray]   # (B,) argmax labels (node task)
    rows_decoded: int                   # decoder rows this request paid
    rows_total: int                     # frontier rows (padded cap)


class GraphInferenceEngine:
    """Frozen-params GNN serving over the minibatched GraphSAGE path.

    ``decode_backend`` pins the embedding decode path (same contract as
    ``DecodeEngine``): ``None`` keeps the config's ``lookup_impl``,
    ``"auto"`` resolves for the current runtime, unknown names fail here —
    at engine construction — not on the first request.  ``cache_capacity``
    sizes the cross-request hot-node cache (0 disables it; the default
    keeps ~4 frontiers' worth of rows).
    """

    def __init__(self, cfg: GNNConfig, params, sampler: NeighborSampler, *,
                 decode_backend: Optional[str] = None, serve_batch: int = 256,
                 frontier_cap: Optional[int] = None, pad_to: int = 256,
                 cache_capacity: Optional[int] = None, seed: int = 0,
                 interpret: bool = False):
        if cfg.model != "sage":
            raise ValueError(
                f"GraphInferenceEngine serves minibatched GraphSAGE; got "
                f"model={cfg.model!r} (full-graph models evaluate via "
                f"GraphRuntime.evaluate)")
        if decode_backend is not None:
            resolved = (backend_mod.resolve_auto()
                        if decode_backend == "auto" else decode_backend)
            backend_mod.get_backend(resolved, interpret=interpret)
            cfg = dataclasses.replace(
                cfg, embedding=dataclasses.replace(
                    cfg.embedding, lookup_impl=resolved))
        self.cfg = cfg
        self.params = params
        self.sampler = sampler
        self.serve_batch = int(serve_batch)
        self.pad_to = int(pad_to)
        self.seed = int(seed)
        self.interpret = bool(interpret)
        ecfg = cfg.embedding_config()
        self._backend = backend_mod.get_backend(ecfg.lookup_impl,
                                                interpret=interpret)

        from repro.graph.engine import default_frontier_cap
        self.frontier_cap = int(
            frontier_cap if frontier_cap is not None
            else default_frontier_cap(self.serve_batch, cfg.fanouts,
                                      self.pad_to, cfg.n_nodes))

        if cache_capacity is None:
            cache_capacity = (min(4 * self.frontier_cap, cfg.n_nodes)
                              if ecfg.is_compressed else 0)
        self.cache_capacity = int(cache_capacity)
        self.cached = ecfg.is_compressed and self.cache_capacity > 0
        # params are frozen at serve time, so the version counter never
        # bumps and staleness 0 still means "every hit is forever fresh"
        self._cache = CachedDecodeBackend(staleness=0)
        self._cache_state = (CacheState.create(
            self.cache_capacity, cfg.d_e,
            jax.numpy.dtype(cfg.compute_dtype)) if self.cached else None)

        self._fwd_cache: Dict[int, object] = {}
        self._requests = 0
        self._rows_decoded = 0
        self._rows_total = 0

    # -- internals -------------------------------------------------------
    def frontier_for(self, node_ids, request_index: Optional[int] = None
                     ) -> FrontierBatch:
        """The exact (padded, fixed-cap) frontier ``serve`` samples for a
        request — exposed so parity tests can run ``GNNModel.apply`` on the
        same batch.  Deterministic in ``(seed, request_index)``."""
        ids = self._pad_request(np.asarray(node_ids, np.int32))
        ri = self._requests if request_index is None else request_index
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + 777_767_777) + ri)
        levels = self.sampler.sample(ids, rng=rng)
        return FrontierBatch.from_levels(levels, pad_to=self.pad_to,
                                         cap=self.frontier_cap)

    def _pad_request(self, ids: np.ndarray) -> np.ndarray:
        if ids.shape[0] > self.serve_batch:
            raise ValueError(
                f"request batch {ids.shape[0]} > serve_batch "
                f"{self.serve_batch}; chunk requests host-side")
        if ids.shape[0] < self.serve_batch:
            ids = np.concatenate(
                [ids, np.full(self.serve_batch - ids.shape[0], ids[0],
                              ids.dtype)])
        return ids

    def _bucket(self, n_miss: int) -> int:
        """Geometric miss-count buckets: one jit shape per bucket."""
        if n_miss <= 0:
            return 0
        b = self.pad_to
        while b < n_miss:
            b *= 2
        return min(b, self.frontier_cap)

    def _forward(self, n_decode: int):
        if n_decode not in self._fwd_cache:
            cfg, backend = self.cfg, self._backend
            node_task = cfg.task == "node"

            if self.cached:
                def fwd(params, fb, cache_state):
                    h, new_state = gnn_lib.sage_forward_frontier_missonly(
                        params, fb, cfg, cache_state, n_decode,
                        backend=backend)
                    logits = (gnn_lib.node_logits(params, h, cfg)
                              if node_task else None)
                    return h, logits, new_state
            else:
                def fwd(params, fb, cache_state):
                    h = gnn_lib.sage_forward_frontier(params, fb, cfg,
                                                      backend=backend)
                    logits = (gnn_lib.node_logits(params, h, cfg)
                              if node_task else None)
                    return h, logits, cache_state
            self._fwd_cache[n_decode] = jax.jit(fwd)
        return self._fwd_cache[n_decode]

    # -- request API -----------------------------------------------------
    def serve(self, node_ids, **_ignored) -> GraphServeResult:
        """Serve one request batch of node ids (≤ ``serve_batch``)."""
        ids = np.asarray(node_ids, np.int32)
        B = ids.shape[0]
        fb = self.frontier_for(ids)
        cap = self.frontier_cap

        if self.cached:
            host_ids = np.asarray(self._cache_state.node_ids)
            valid = np.arange(cap) < int(fb.n_unique)
            perm, n_miss = CachedDecodeBackend.plan_missonly(
                host_ids, np.asarray(fb.unique), valid)
            inv = np.empty_like(perm)
            inv[perm] = np.arange(perm.shape[0], dtype=np.int32)
            fb = FrontierBatch(
                unique=np.asarray(fb.unique)[perm],
                index_maps=tuple(inv[np.asarray(m)] for m in fb.index_maps),
                n_unique=fb.n_unique,
                valid=valid[perm])
            n_dec = self._bucket(n_miss)
            h, logits, self._cache_state = self._forward(n_dec)(
                self.params, jax.device_put(fb), self._cache_state)
        else:
            n_dec = cap
            h, logits, _ = self._forward(-1)(self.params, jax.device_put(fb),
                                             None)

        self._requests += 1
        self._rows_decoded += n_dec
        self._rows_total += cap

        h = np.asarray(h)[:B]
        logits = None if logits is None else np.asarray(logits)[:B]
        preds = None if logits is None else logits.argmax(-1).astype(np.int32)
        return GraphServeResult(embeddings=h, logits=logits,
                                predictions=preds, rows_decoded=n_dec,
                                rows_total=cap)

    def embed(self, node_ids) -> np.ndarray:
        """Final hidden representations (B, H) — bitwise identical to
        ``GNNModel.apply`` on ``frontier_for(node_ids)``."""
        return self.serve(node_ids).embeddings

    def predict(self, node_ids) -> np.ndarray:
        """Argmax class per requested node (node-classification task)."""
        res = self.serve(node_ids)
        if res.predictions is None:
            raise ValueError("predict() needs a node-classification config")
        return res.predictions

    def stats(self) -> Dict[str, float]:
        """Cumulative serving counters (the cache's rows_decoded claim)."""
        out = {"requests": self._requests,
               "rows_decoded": self._rows_decoded,
               "rows_total": self._rows_total}
        if self.cached:
            st = self._cache_state
            hits, misses = int(st.hits), int(st.misses)
            out.update(hits=hits, misses=misses,
                       hit_rate=hits / max(hits + misses, 1))
        return out
