"""Weighted HLO-text analyzer (DESIGN.md §8).

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a
``jax.lax.scan`` over 48 layers reports 1/48th of the real FLOPs (verified
empirically; see EXPERIMENTS.md §Dry-run).  Unrolling for the dry-run is
compile-time prohibitive, so this module re-derives per-chip totals from
``compiled.as_text()`` with a call-graph walk that multiplies while-loop
bodies by their ``known_trip_count``:

  flops:      dot ops (2 · |result| · |contracted|), weighted
  hbm bytes:  per top-level op: operand + result bytes (fusions count as one
              op — interior values never touch HBM), weighted
  collective: wire-byte model per op type (ring), weighted

Parsing relies on the stable long-form HLO printer: every op line is
``%name = TYPE op-name(%operand, ...), attrs...`` and computations are
``[ENTRY] %comp_name (params) -> type { ... }`` blocks.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\s*\{\s*$")
_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s*([\w\-]+)\((.*)$")
_SHAPE_TOK = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OPERAND = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_BDIMS = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_GROUPS_EXPL = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


@dataclasses.dataclass
class OpRec:
    name: str
    opname: str
    result_types: str       # raw text before op name (includes tuple types)
    operands: List[str]
    rest: str               # remainder of the line (attrs)


@dataclasses.dataclass
class CompAnalysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})


def _type_bytes(type_text: str) -> int:
    return sum(_nelems(d) * _DTYPE_BYTES.get(t, 0)
               for t, d in _SHAPE_TOK.findall(type_text))


def _nelems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n


def _first_shape(type_text: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_TOK.search(type_text)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d.strip()]
    return m.group(1), dims


def parse_module(text: str):
    """Returns (entry_name, comps: {name: [OpRec]}, types: {(comp, op): text})."""
    comps: Dict[str, List[OpRec]] = {}
    entry = None
    cur: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, rtypes, opname, rest = m.groups()
        # operands live before the closing paren of the op call; attrs after.
        depth, idx = 1, 0
        for idx, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_text, attrs = rest[:idx], rest[idx + 1:]
        comps[cur].append(OpRec(
            name=name, opname=opname, result_types=rtypes,
            operands=_OPERAND.findall(operand_text), rest=attrs))
    return entry, comps


def _group_size(attrs: str) -> int:
    m = _GROUPS_IOTA.search(attrs)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL.search(attrs)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 2


def _wire_bytes(opname: str, result_bytes: float, p: int) -> float:
    ring = (p - 1) / p
    if opname == "all-reduce":
        return 2.0 * result_bytes * ring
    if opname == "all-gather":
        return result_bytes * ring
    if opname == "reduce-scatter":
        return result_bytes * (p - 1)
    if opname == "all-to-all":
        return result_bytes * ring
    return float(result_bytes)  # collective-permute


class HLOAnalyzer:
    def __init__(self, text: str):
        self.entry, self.comps = parse_module(text)
        self.symbols: Dict[str, Dict[str, str]] = {
            c: {op.name: op.result_types for op in ops}
            for c, ops in self.comps.items()
        }
        self._memo: Dict[str, CompAnalysis] = {}

    # -- flops of one dot ------------------------------------------------
    def _dot_flops(self, comp: str, op: OpRec) -> float:
        res = _first_shape(op.result_types)
        if res is None:
            return 0.0
        _, rdims = res
        lhs_t = self.symbols[comp].get(op.operands[0], "") if op.operands else ""
        lhs = _first_shape(lhs_t)
        if lhs is None:
            return 0.0
        _, ldims = lhs
        m = _LHS_CDIMS.search(op.rest)
        cdims = [int(d) for d in m.group(1).split(",") if d.strip()] if m else []
        contracted = 1
        for d in cdims:
            if d < len(ldims):
                contracted *= ldims[d]
        return 2.0 * _nelems(",".join(map(str, rdims))) * contracted

    def _conv_flops(self, comp: str, op: OpRec) -> float:
        # not used by these models; coarse: 2 * |result| * |kernel|/out_ch
        res = _first_shape(op.result_types)
        ker = _first_shape(self.symbols[comp].get(op.operands[1], "")) if len(op.operands) > 1 else None
        if res is None or ker is None:
            return 0.0
        _, rdims = res
        _, kdims = ker
        kprod = 1
        for d in kdims[:-1]:
            kprod *= d
        out = 1
        for d in rdims:
            out *= d
        return 2.0 * out * kprod

    # -- per-computation accumulation -------------------------------------
    def analyze(self, comp: Optional[str] = None) -> CompAnalysis:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        acc = CompAnalysis()
        self._memo[comp] = acc   # cycles impossible in HLO, safe placeholder
        for op in self.comps.get(comp, []):
            o = op.opname
            if o in ("dot",):
                acc.flops += self._dot_flops(comp, op)
                acc.hbm_bytes += self._op_bytes(comp, op)
            elif o == "convolution":
                acc.flops += self._conv_flops(comp, op)
                acc.hbm_bytes += self._op_bytes(comp, op)
            elif o == "fusion":
                m = _CALLS.search(op.rest)
                if m:
                    sub = self.analyze(m.group(1))
                    acc.flops += sub.flops
                    for k in COLLECTIVES:
                        acc.coll[k] += sub.coll[k]
                # fusion interior stays on-chip: only boundary traffic counts
                acc.hbm_bytes += self._op_bytes(comp, op)
            elif o == "while":
                body = _BODY.search(op.rest)
                trip = 1
                mt = _TRIP.search(op.rest)
                if mt:
                    trip = int(mt.group(1))
                if body:
                    sub = self.analyze(body.group(1))
                    acc.flops += trip * sub.flops
                    acc.hbm_bytes += trip * sub.hbm_bytes
                    for k in COLLECTIVES:
                        acc.coll[k] += trip * sub.coll[k]
            elif o in ("call", "custom-call", "conditional", "async-start"):
                m = _CALLS.search(op.rest)
                if m:
                    sub = self.analyze(m.group(1))
                    acc.flops += sub.flops
                    acc.hbm_bytes += sub.hbm_bytes
                    for k in COLLECTIVES:
                        acc.coll[k] += sub.coll[k]
                else:
                    acc.hbm_bytes += self._op_bytes(comp, op)
            elif any(o.startswith(c) for c in COLLECTIVES):
                base = next(c for c in COLLECTIVES if o.startswith(c))
                if o.endswith("-done"):
                    continue
                rb = _type_bytes(op.result_types)
                acc.coll[base] += _wire_bytes(base, rb, _group_size(op.rest))
                acc.hbm_bytes += self._op_bytes(comp, op)
            elif o in _FREE_OPS:
                continue
            else:
                acc.hbm_bytes += self._op_bytes(comp, op)
        return acc

    def _op_bytes(self, comp: str, op: OpRec) -> float:
        # indexing ops only touch the addressed window, not the whole buffer
        if op.opname in ("dynamic-slice", "slice", "gather", "reshape",
                         "transpose", "broadcast"):
            return 2.0 * float(_type_bytes(op.result_types))
        if op.opname in ("dynamic-update-slice", "scatter"):
            upd = op.operands[1] if len(op.operands) > 1 else None
            upd_b = _type_bytes(self.symbols[comp].get(upd, "")) if upd else 0
            return 2.0 * float(upd_b) + float(_type_bytes(op.result_types)) * 0.0
        b = float(_type_bytes(op.result_types))
        for operand in op.operands:
            b += _type_bytes(self.symbols[comp].get(operand, ""))
        return b

    def totals(self) -> CompAnalysis:
        return self.analyze(self.entry)
