"""End-to-end training driver (deliverable b's e2e entry point).

Wires: config -> codes from the data pipeline's co-occurrence pass
(Algorithm 1 on the vocabulary) -> model init -> sharded train loop with
checkpointing/auto-resume.  On the CPU container run it with --preset tiny;
the same driver with --mesh production is the TPU entry point.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --preset tiny --steps 200 --ckpt-dir /tmp/run1
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core import lsh
from repro.data import TokenStream, TokenStreamConfig, cooccurrence_matrix
from repro.train import (CheckpointManager, LoopConfig, TrainHyper,
                         init_train_state, make_train_step, run_training)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--preset", choices=["tiny", "full"], default="tiny")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--embedding-kind", default=None,
                    help="dense | hash_full | hash_light | random_full | random_light")
    ap.add_argument("--cooc-batches", type=int, default=8,
                    help="co-occurrence pass batches for the LSH auxiliary")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = reduced(cfg)
    if args.embedding_kind:
        cfg = dataclasses.replace(
            cfg, embedding=dataclasses.replace(cfg.embedding, kind=args.embedding_kind))

    key = jax.random.PRNGKey(args.seed)
    stream = TokenStream(TokenStreamConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch,
        seed=args.seed))

    codes = None
    if cfg.embedding.kind.startswith("hash"):
        print(f"[encode] co-occurrence pass ({args.cooc_batches} batches) + "
              f"Algorithm 1 (c={cfg.embedding.c}, m={cfg.embedding.m})")
        aux_stream = TokenStream(TokenStreamConfig(
            vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch,
            seed=args.seed + 1))
        aux = cooccurrence_matrix(aux_stream, args.cooc_batches,
                                  projection_dim=min(512, cfg.vocab_size))
        ecfg = cfg.embedding_config()
        aux_pad = np.zeros((ecfg.n_entities, aux.shape[1]), np.float32)
        aux_pad[: cfg.vocab_size] = aux
        codes = lsh.encode_lsh(key, jnp.asarray(aux_pad), ecfg.c, ecfg.m)
        from repro.core.codes import count_collisions
        print(f"[encode] codes {tuple(codes.shape)} uint32, "
              f"collisions={count_collisions(codes[:cfg.vocab_size])}")

    state = init_train_state(key, cfg, codes=codes)
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"[init] {cfg.name} ({cfg.family}) params={n_params:,} "
          f"embedding={cfg.embedding.kind}")

    hyper = TrainHyper(total_steps=args.steps)
    step_fn = make_train_step(cfg, hyper)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    to_dev = lambda b: {k: jnp.asarray(v) for k, v in b.items()}

    t0 = time.time()
    res = run_training(
        step_fn, state, stream,
        LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every),
        ckpt, to_dev,
        on_metrics=lambda s, m: print(
            f"[step {s:5d}] loss={m['loss']:.4f} dt={m['step_time']*1e3:.0f}ms"),
    )
    dt = time.time() - t0
    print(f"[done] steps={len(res.losses)} loss {res.losses[0]:.4f} -> "
          f"{res.losses[-1]:.4f} wall={dt:.1f}s stragglers={res.stragglers}"
          + (f" resumed_from={res.resumed_from}" if res.resumed_from else ""))
    return res


if __name__ == "__main__":
    main()
