"""Launch layer: production mesh, multi-pod dry-run, drivers, roofline."""
