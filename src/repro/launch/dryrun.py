import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell: build the step function
(train / prefill / serve), attach the production sharding policy, then
``jax.jit(...).lower(**ShapeDtypeStructs).compile()`` — proving the
distribution config is coherent (sharding propagation succeeds, collectives
schedule, per-device memory fits) without hardware.  Emits one JSON record
per cell with memory_analysis, cost_analysis, and the roofline terms
(DESIGN.md §8); EXPERIMENTS.md §Dry-run/§Roofline are generated from these.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--multi-pod | --both-meshes] [--embedding-kind dense|hash_full]
      [--out results/dryrun] [--microbatches N]
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.archs import ASSIGNED
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, cell_is_applicable, input_specs
from repro.models.lm import init_cache
from repro.parallel.policy import (
    DEFAULT_STRATEGY, Strategy, batch_shardings, cache_shardings_policy,
    params_shardings, rules_for, state_shardings,
)
from repro.parallel.sharding import use_sharding
from repro.train.step import (
    TrainHyper, init_train_state, make_prefill_step, make_serve_step,
)

# per-arch default gradient-accumulation for train_4k (activation fit;
# tuned from memory_analysis — see EXPERIMENTS.md §Dry-run)
DEFAULT_MICROBATCHES = {
    "qwen1.5-0.5b": 2, "chatglm3-6b": 8, "internlm2-20b": 16, "yi-9b": 8,
    "musicgen-large": 4, "mamba2-2.7b": 8, "zamba2-7b": 8, "dbrx-132b": 16,
    "granite-moe-3b-a800m": 4, "qwen2-vl-7b": 8,
}


def build_cell(cfg, shape, mesh, microbatches: int,
               strategy: Strategy = DEFAULT_STRATEGY,
               moments_dtype: str = "float32"):
    """Returns the lowered step for one cell under the sharding policy."""
    import dataclasses as _dc
    from repro.parallel.policy import kv_seq_mesh_axis
    rules = rules_for(strategy, mesh)
    if shape.kind == "decode":
        # decode: score/cache constraints must match the sharded cache
        # layout (flash-decoding split-KV).  Prefill must NOT bind this —
        # the in-flight cache constraint forces a reshard every layer
        # (measured +2.9 s collective on internlm2 prefill_32k); its output
        # cache is resharded once by out_shardings instead.
        rules = _dc.replace(rules, rules={
            **rules.rules,
            "kv_seq": kv_seq_mesh_axis(cfg, mesh, strategy, shape.batch),
        })
    with use_sharding(mesh, rules):
        key = jax.random.PRNGKey(0)
        batch_tpl = input_specs(cfg, shape)
        b_shard = batch_shardings(batch_tpl, mesh, strategy)

        if shape.kind == "train":
            from repro.optim.adamw import AdamWConfig
            hyper = TrainHyper(
                microbatches=microbatches,
                optimizer=AdamWConfig(lr=1e-3, weight_decay=0.01, clip_norm=1.0,
                                      moments_dtype=moments_dtype))
            from repro.train.step import make_train_step
            step = make_train_step(cfg, hyper)
            mdt = jnp.dtype(moments_dtype)
            state_tpl = jax.eval_shape(
                lambda: init_train_state(key, cfg, moments_dtype=mdt))
            st_shard = state_shardings(cfg, state_tpl, mesh, strategy)
            jitted = jax.jit(step, in_shardings=(st_shard, b_shard),
                             out_shardings=(st_shard, None),
                             donate_argnums=(0,))
            return jitted.lower(state_tpl, batch_tpl)

        params_tpl = jax.eval_shape(
            lambda: __import__("repro.models.lm", fromlist=["init_lm"]).init_lm(key, cfg))
        p_shard = params_shardings(cfg, params_tpl, mesh, strategy)
        dtype = jnp.dtype(cfg.compute_dtype)

        if shape.kind == "prefill":
            step = make_prefill_step(cfg, shape.seq)
            cache_tpl = jax.eval_shape(
                lambda: init_cache(cfg, shape.batch, shape.seq, dtype))
            c_shard = cache_shardings_policy(cfg, cache_tpl, mesh, strategy)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard),
                             out_shardings=(None, c_shard))
            return jitted.lower(params_tpl, batch_tpl)

        # decode: one new token against a seq-sized cache
        step = make_serve_step(cfg)
        cache_tpl = jax.eval_shape(
            lambda: init_cache(cfg, shape.batch, shape.seq, dtype))
        c_shard = cache_shardings_policy(cfg, cache_tpl, mesh, strategy)
        jitted = jax.jit(step, in_shardings=(p_shard, c_shard, b_shard),
                         out_shardings=(None, c_shard), donate_argnums=(1,))
        return jitted.lower(params_tpl, cache_tpl, batch_tpl)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             embedding_kind=None, microbatches=None, overrides=None,
             strategy: Strategy = DEFAULT_STRATEGY,
             moments_dtype: str = "float32") -> dict:
    cfg = get_config(arch, **(overrides or {}))
    if embedding_kind is not None and cfg.embedding.kind != embedding_kind:
        if not (embedding_kind != "dense" and arch == "musicgen-large"):
            cfg = dataclasses.replace(
                cfg, embedding=dataclasses.replace(cfg.embedding, kind=embedding_kind))
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "embedding_kind": cfg.embedding.kind,
           "strategy": dataclasses.asdict(strategy)}
    if not cell_is_applicable(cfg, shape):
        rec["status"] = "skipped"
        rec["reason"] = ("long_500k requires sub-quadratic attention; "
                         f"{arch} is pure full-attention (DESIGN.md §4)")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    mb = microbatches or DEFAULT_MICROBATCHES.get(arch, 1)
    if shape.kind == "train":
        # per-microbatch batch must stay divisible by the DP extent
        import numpy as _np
        dp = int(_np.prod([mesh.shape[a] for a in strategy.batch_mesh_axes(mesh)]))
        while mb > 1 and (shape.batch % mb or (shape.batch // mb) % dp):
            mb //= 2
    t0 = time.time()
    try:
        lowered = build_cell(cfg, shape, mesh, mb, strategy, moments_dtype)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    except Exception as e:  # a failure here is a bug in the system
        rec["status"] = "FAILED"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        return rec

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    # weighted call-graph walk: XLA cost_analysis does not multiply
    # while-loop (scan) bodies by trip count — hloanalysis does.
    from repro.launch.hloanalysis import HLOAnalyzer
    from repro.launch.hbm_model import analytic_hbm_bytes
    hlo = HLOAnalyzer(text).totals()
    hbm = analytic_hbm_bytes(cfg, shape, mesh,
                             microbatches=mb if shape.kind == "train" else 1,
                             strategy=strategy)
    terms = roofline.RooflineTerms(
        flops=hlo.flops,
        bytes_accessed=hbm["total"],
        coll_bytes=sum(hlo.coll.values()),
        coll_breakdown=dict(hlo.coll),
        model_flops_per_chip=roofline.model_flops(cfg, shape, mesh.size),
        chips=mesh.size,
    )
    rec.update({
        "status": "ok",
        "microbatches": mb if shape.kind == "train" else None,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "xla_cost_analysis": {  # unweighted (while bodies counted once)
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "hlo_bytes_unfused": hlo.hbm_bytes,   # CPU-HLO parse (upper bound)
        "hbm_model": hbm,                     # analytic TPU-fused traffic

        "memory": {
            "argument_gib": mem.argument_size_in_bytes / 2**30,
            "output_gib": mem.output_size_in_bytes / 2**30,
            "temp_gib": mem.temp_size_in_bytes / 2**30,
            "alias_gib": mem.alias_size_in_bytes / 2**30,
            "peak_est_gib": (mem.argument_size_in_bytes + mem.output_size_in_bytes
                             + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30,
        },
        "roofline": terms.as_dict(),
    })
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--embedding-kind", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--moments-dtype", default="float32")
    ap.add_argument("--moe-impl", default=None)
    ap.add_argument("--profile", choices=["baseline", "optimized"],
                    default="baseline")
    ap.add_argument("--strategy", default=None,
                    help="JSON Strategy overrides, e.g. '{\"dp_over_model\": true}'")
    ap.add_argument("--tag", default="", help="suffix for output filenames")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    strategy = DEFAULT_STRATEGY
    if args.strategy:
        strategy = Strategy(**json.loads(args.strategy))

    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    print(f"cost_analysis calibration (per-chip ratio): "
          f"{roofline.calibrate_cost_analysis(make_production_mesh()):.3f}")

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                kw = dict(embedding_kind=args.embedding_kind,
                          microbatches=args.microbatches,
                          strategy=strategy,
                          moments_dtype=args.moments_dtype,
                          overrides={"moe_impl": args.moe_impl} if args.moe_impl else None)
                if args.profile == "optimized":
                    from repro.launch.profiles import optimized_cell_settings
                    opt = optimized_cell_settings(arch, SHAPES[shape_name].kind)
                    if opt:
                        kw["strategy"] = opt.get("strategy", kw["strategy"])
                        kw["microbatches"] = opt.get("microbatches", kw["microbatches"])
                        kw["moments_dtype"] = opt.get("moments_dtype", kw["moments_dtype"])
                        if opt.get("overrides"):
                            kw["overrides"] = {**(kw["overrides"] or {}), **opt["overrides"]}
                rec = run_cell(arch, shape_name, mp, **kw)
                tag = f"{arch}__{shape_name}__{rec['mesh']}"
                if args.embedding_kind:
                    tag += f"__{args.embedding_kind}"
                if args.tag:
                    tag += f"__{args.tag}"
                path = os.path.join(args.out, tag + ".json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec["status"] == "ok":
                    n_ok += 1
                    r = rec["roofline"]
                    print(f"OK   {tag:60s} compile={rec['compile_s']:7.1f}s "
                          f"mem={rec['memory']['peak_est_gib']:6.2f}GiB "
                          f"dom={r['dominant']:10s} "
                          f"terms(c/m/x)=({r['compute_s']:.4f}/{r['memory_s']:.4f}/"
                          f"{r['collective_s']:.4f})s frac={r['roofline_fraction']:.3f}")
                elif rec["status"] == "skipped":
                    n_skip += 1
                    print(f"SKIP {tag:60s} {rec['reason'][:70]}")
                else:
                    n_fail += 1
                    print(f"FAIL {tag:60s} {rec['error'][:120]}")
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_fail} FAILED")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
