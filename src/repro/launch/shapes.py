"""Assigned input-shape set + ShapeDtypeStruct input specs per cell.

  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill_step
  decode_32k   seq 32,768  global_batch 128   -> serve_step (1 new token,
                                                 cache holds seq_len)
  long_500k    seq 524,288 global_batch 1     -> serve_step; SSM/hybrid only

`input_specs(cfg, shape)` returns weak-type-correct ShapeDtypeStructs for
every model input — shardable, no device allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_is_applicable(cfg: LMConfig, shape: ShapeSpec) -> bool:
    """long_500k requires sub-quadratic sequence mixing (DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False
    return True


def _token_shape(cfg: LMConfig, batch: int, seq: int):
    if cfg.input_mode == "audio_tokens":
        return (batch, seq, cfg.n_codebooks)
    return (batch, seq)


def input_specs(cfg: LMConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step function's *batch* argument.

    train: {"tokens", "labels"[, "positions"]}
    prefill: {"tokens"[, "positions"]}
    decode: {"tokens" (B, 1[, nq])[, "positions"]}
    """
    i32 = jnp.int32
    B, S = shape.batch, shape.seq
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct(_token_shape(cfg, B, S), i32),
            "labels": jax.ShapeDtypeStruct(_token_shape(cfg, B, S), i32),
        }
        if cfg.input_mode == "tokens_mrope":
            specs["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct(_token_shape(cfg, B, S), i32)}
        if cfg.input_mode == "tokens_mrope":
            specs["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        return specs
    if shape.kind == "decode":
        specs = {"tokens": jax.ShapeDtypeStruct(_token_shape(cfg, B, 1), i32)}
        if cfg.input_mode == "tokens_mrope":
            specs["positions"] = jax.ShapeDtypeStruct((3, B, 1), i32)
        return specs
    raise ValueError(shape.kind)
