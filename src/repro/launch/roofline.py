"""Roofline analysis from compiled dry-run artifacts (DESIGN.md §8).

Terms per (arch × shape × mesh), all in seconds-per-step *per chip* (the
compiled HLO module is the per-device SPMD program, so cost_analysis FLOPs/
bytes and parsed collective operand bytes are already per-chip — verified
by ``calibrate_cost_analysis``):

  compute    = HLO_FLOPs / peak_FLOP/s
  memory     = HLO_bytes_accessed / HBM_bw
  collective = Σ wire_bytes(op) / link_bw
      wire_bytes: ring model — all-gather/reduce-scatter move operand·(p−1)/p,
      all-reduce moves 2·operand·(p−1)/p, all-to-all operand·(p−1)/p,
      collective-permute operand; p parsed from replica_groups.

Also reported: MODEL_FLOPS = 6·N(:=active params)·tokens (trains) or
2·N·tokens (forwards), and the usefulness ratio MODEL_FLOPS / (chips·HLO).
XLA:CPU caveat (documented): cost_analysis reports algebraic FLOPs of the
lowered ops; fusion differences vs TPU are second-order for these
matmul-dominated graphs.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(tail: str) -> Optional[int]:
    m = _GROUPS_IOTA_RE.search(tail)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(tail)
    if m:
        return len(m.group(1).split(","))
    return None


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Parses per-chip *wire* bytes by collective type from HLO long text.

    XLA prints only result types inline (operands are %refs), so wire bytes
    derive from the RESULT shape + op semantics (ring model, per chip):
      all-gather      result R gathered over p: send/recv R·(p−1)/p
      all-reduce      operand≡result R: 2·R·(p−1)/p (reduce-scatter+gather)
      reduce-scatter  result r = R/p: wire R·(p−1)/p = r·(p−1)
      all-to-all      result R: R·(p−1)/p crosses the wire
      collective-permute  result R: R
    """
    out: Dict[str, float] = {
        "all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
        "all-to-all": 0.0, "collective-permute": 0.0,
    }
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_types, op, startdone = m.group(1), m.group(2), m.group(3)
        if startdone == "-done":
            continue  # counted at the matching -start
        r_bytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(result_types))
        p = _group_size(line) or 2
        ring = (p - 1) / p
        if op == "all-reduce":
            wire = 2.0 * r_bytes * ring
        elif op == "all-gather":
            wire = r_bytes * ring
        elif op == "reduce-scatter":
            wire = r_bytes * (p - 1)
        elif op == "all-to-all":
            wire = r_bytes * ring
        else:  # collective-permute
            wire = float(r_bytes)
        out[op] += wire
    out["total"] = sum(out.values())
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                  # per-chip HLO flops
    bytes_accessed: float         # per-chip HBM bytes
    coll_bytes: float             # per-chip wire bytes
    coll_breakdown: Dict[str, float]
    model_flops_per_chip: float   # analytic useful flops
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        # ~2 usable ICI links per ring direction on the v5e 2D torus
        return self.coll_bytes / (2 * ICI_BW_PER_LINK)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step-time estimate: max of the three terms (perfectly
        overlapped model; the sum is the no-overlap bound)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops_per_chip / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the estimated step
        time: useful FLOPs / (peak · step_time)."""
        if self.step_s == 0:
            return 0.0
        return self.model_flops_per_chip / (PEAK_FLOPS_BF16 * self.step_s)

    def as_dict(self) -> Dict:
        return {
            "flops_per_chip": self.flops,
            "bytes_per_chip": self.bytes_accessed,
            "coll_bytes_per_chip": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "model_flops_per_chip": self.model_flops_per_chip,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_s": self.step_s,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, shape, n_chips: int) -> float:
    """Analytic MODEL_FLOPS for the cell, per chip.

    train: 6·N_active·tokens;  prefill: 2·N_active·tokens (+ attention
    quadratic term); decode: 2·N_active·batch (one token each).
    """
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        total = 6.0 * n_active * tokens
        # attention quadratic term (causal): 12·L·H·Dh·S²·B/2 fwd+bwd
        if cfg.n_heads:
            att = 12.0 * cfg.n_layers * cfg.n_heads * cfg.head_dim * shape.seq**2 * shape.batch / 2
            if cfg.family == "hybrid":
                att = att / cfg.attn_every
            total += att
    elif shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        total = 2.0 * n_active * tokens
        if cfg.n_heads:
            att = 4.0 * cfg.n_layers * cfg.n_heads * cfg.head_dim * shape.seq**2 * shape.batch / 2
            if cfg.family == "hybrid":
                att = att / cfg.attn_every
            total += att
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.batch
        if cfg.n_heads:
            att = 4.0 * cfg.n_layers * cfg.n_heads * cfg.head_dim * shape.seq * shape.batch
            if cfg.family == "hybrid":
                att = att / cfg.attn_every
            total += att
    return total / n_chips


# ---------------------------------------------------------------------------
# fused hash-decode roofline (kernels.hash_decode, ISSUE 6)
# ---------------------------------------------------------------------------

# Storage bytes per codebook element by decode precision policy
# (core.backend.MixedPrecisionPolicy): int8 is the quantized value byte —
# its f32 absmax scales are accounted separately (one per (m, c) codebook
# row, amortised over d_c).
DECODE_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "int8": 1}


def decode_hbm_bytes(B: int, c: int, m: int, d_c: int,
                     dtype: str = "float32", w0: bool = False) -> Dict[str, float]:
    """Modeled per-call HBM traffic of one fused hash-decode forward.

    The kernel reads each operand exactly once (codes and codebooks are
    grid-resident blocks, the output is written once), so the model is the
    sum of operand sizes — the best case any schedule can hit, which is
    what a roofline needs:

      codes      B·m·4              (int32)
      codebooks  m·c·d_c·bytes(dtype)
      scales     m·c·4              (int8 only: f32 absmax per codebook row)
      w0         d_c·bytes(dtype)   (light variant only)
      out        B·d_c·4            (f32 accumulator result)
    """
    db = DECODE_DTYPE_BYTES[dtype]
    parts = {
        "codes": B * m * 4.0,
        "codebooks": float(m * c * d_c * db),
        "scales": m * c * 4.0 if dtype == "int8" else 0.0,
        "w0": float(d_c * db) if w0 else 0.0,
        "out": B * d_c * 4.0,
    }
    parts["total"] = sum(parts.values())
    return parts


def decode_roofline(B: int, c: int, m: int, d_c: int, dtype: str = "float32",
                    w0: bool = False,
                    measured_us: Optional[float] = None) -> Dict[str, float]:
    """Roofline terms for the fused hash-decode at one shape/dtype.

    FLOPs use the kernel's MXU formulation (m one-hot × codebook-panel
    matmuls): 2·B·m·c·d_c.  ``step_us`` is the modeled per-call floor
    ``max(compute, memory)``; ``roofline_fraction`` is the fraction of the
    peak-FLOP/s roofline that floor achieves (memory-bound shapes sit below
    1.0 by exactly their arithmetic-intensity deficit).  With a
    ``measured_us`` wall time, ``achieved_vs_roofline = step_us /
    measured_us`` — only meaningful for ``mode: native`` timings; interpret
    mode timings are a semantics check, which is why every bench entry
    carries its mode."""
    bytes_ = decode_hbm_bytes(B, c, m, d_c, dtype, w0=w0)
    flops = 2.0 * B * m * c * d_c
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_["total"] / HBM_BW
    step_s = max(compute_s, memory_s)
    out = {
        "flops": flops,
        "hbm_bytes": bytes_["total"],
        "hbm_bytes_codebooks": bytes_["codebooks"] + bytes_["scales"],
        "arithmetic_intensity": flops / bytes_["total"],
        "compute_us": compute_s * 1e6,
        "memory_us": memory_s * 1e6,
        "step_us": step_s * 1e6,
        "bound": "compute" if compute_s >= memory_s else "memory",
        "roofline_fraction": flops / (PEAK_FLOPS_BF16 * step_s),
    }
    if measured_us is not None:
        out["achieved_vs_roofline"] = out["step_us"] / max(measured_us, 1e-9)
    return out


def calibrate_cost_analysis(mesh) -> float:
    """Compiles a known matmul sharded over the mesh and returns
    reported_flops / per_chip_flops — ≈1.0 when cost_analysis is per-chip."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    n = 1024
    chips = mesh.size
    a = jax.ShapeDtypeStruct((n, n), jnp.float32)
    b = jax.ShapeDtypeStruct((n, n), jnp.float32)
    axes = [ax for ax in ("pod", "data") if ax in mesh.shape]
    sh_a = NamedSharding(mesh, P(axes[0] if len(axes) == 1 else tuple(axes), None))
    sh_b = NamedSharding(mesh, P(None, "model"))
    c = jax.jit(lambda a, b: a @ b, in_shardings=(sh_a, sh_b)).lower(a, b).compile()
    reported = c.cost_analysis().get("flops", 0.0)
    per_chip = 2.0 * n * n * n / chips
    return reported / per_chip
