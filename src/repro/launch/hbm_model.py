"""Analytic per-chip HBM-traffic model (the roofline memory term).

Why analytic: XLA:CPU fuses far less than XLA:TPU, so bytes parsed from the
CPU-compiled HLO over-count TPU HBM traffic ~5-10x (measured: 60% of parsed
bytes are elementwise ops a TPU fusion absorbs; see EXPERIMENTS.md §Dry-run).
FLOPs and collective bytes parse reliably (they live in dot/collective ops);
the memory term instead uses this explicit, sharding-aware model.  Every
count below is per-chip per-step; tensors counted once per HBM write + once
per read (factor 2), with pass multipliers:

  train:   fwd + bwd + remat-recompute  => 3 passes over activations,
           weights read fwd+bwd+recompute per microbatch, optimizer does
           7 f32 passes over trainable params (read p/μ/ν/g, write p/μ/ν)
  prefill: 1 forward pass, cache written once
  decode:  weights read once, cache read once + one-slot write

Attention scores are NOT counted as HBM traffic (the deployed path is the
flash kernel — kernels/flash_attention — which keeps them in VMEM);
``attn_scores_hbm=True`` adds them back for the XLA-attention baseline, and
that delta is one of the §Perf levers.
"""

from __future__ import annotations

from typing import Dict

import jax
import numpy as np

from repro.configs.base import LMConfig
from repro.launch.shapes import ShapeSpec

BF16 = 2
F32 = 4


def _local_param_bytes(cfg: LMConfig, mesh, dtype_bytes: int,
                       trainable_only=False, strategy=None) -> float:
    """Per-chip bytes of the param tree under the production sharding."""
    from repro.parallel.policy import DEFAULT_STRATEGY, params_shardings
    from repro.models.lm import init_lm
    tpl = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    shards = params_shardings(cfg, tpl, mesh, strategy or DEFAULT_STRATEGY)
    total = 0.0
    for leaf, sh in zip(jax.tree.leaves(tpl), jax.tree.leaves(shards)):
        if trainable_only and not np.issubdtype(leaf.dtype, np.floating):
            continue
        shard_elems = np.prod(sh.shard_shape(leaf.shape)) if leaf.shape else 1
        total += float(shard_elems) * dtype_bytes
    return total


def _layer_boundary_bytes_per_token(cfg: LMConfig, model_sz: int) -> float:
    """bf16 bytes crossing HBM per token per layer at fusion boundaries."""
    D, F = cfg.d_model, cfg.d_ff
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    heads_ok = H and H % model_sz == 0
    hdiv = model_sz if heads_ok else 1
    fdiv = model_sz if F and F % model_sz == 0 else 1
    b = 0.0
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        qkv = (H * Dh + 2 * K * Dh) / hdiv
        attn_out = (H * Dh) / hdiv + D
        if cfg.family == "moe":
            k = cfg.moe_top_k
            ep = model_sz if cfg.n_experts_padded % model_sz == 0 else 1
            ffn = k * 3 * F / ep + k * D / ep + D   # dispatched rows + combine
        else:
            ffn = 3 * F / fdiv + D
        b = (2 * D + qkv + attn_out + ffn) * BF16   # + two norm outputs
    if cfg.family in ("ssm", "hybrid"):
        DI = cfg.ssm_expand * D
        N = cfg.ssm_state
        Hs = DI // cfg.ssm_headdim
        hs_div = model_sz if Hs % model_sz == 0 else 1
        L = cfg.ssm_chunk
        proj = (2 * DI + 2 * N + Hs)
        conv = (DI + 2 * N)
        ssd_scores = L * (Hs / hs_div) * F32        # intra-chunk (L,L,H) rows
        ssd_states = (Hs / hs_div) * N * F32 / max(L, 1) * cfg.ssm_headdim
        ssm_b = (D + proj + conv + 2 * DI) * BF16 + ssd_scores + ssd_states
        if cfg.family == "ssm":
            b = ssm_b
        else:  # hybrid: mamba layers + 1/attn_every share of the shared block
            qkv = (H * Dh + 2 * K * Dh) / hdiv
            attn_out = (H * Dh) / hdiv + D
            ffn = 3 * F / fdiv + D
            attn_b = (2 * D + qkv + attn_out + ffn) * BF16
            b = ssm_b + attn_b / max(cfg.attn_every, 1)
    return 2.0 * b      # write + read per boundary tensor


def _embed_head_bytes_per_token(cfg: LMConfig, model_sz: int, train: bool) -> float:
    e = cfg.embedding
    V_local = cfg.vocab_padded / (model_sz if cfg.vocab_padded % model_sz == 0 else 1)
    logits = V_local * F32 * (3 if train else 1) * 2
    if e.kind == "dense":
        emb = cfg.d_model * BF16 * 2
    else:
        # packed code row + decoder boundary tensors
        emb = e.m * (e.c.bit_length() - 1) / 8 \
            + (e.d_c + e.d_m + cfg.d_model) * BF16 * 2
        if train:
            emb *= 3
    return logits + emb


def analytic_hbm_bytes(cfg: LMConfig, shape: ShapeSpec, mesh,
                       microbatches: int = 1,
                       attn_scores_hbm: bool = False,
                       strategy=None) -> Dict[str, float]:
    from repro.parallel.policy import DEFAULT_STRATEGY
    strategy = strategy or DEFAULT_STRATEGY
    chips = mesh.size
    model_sz = mesh.shape.get("model", 1) if not strategy.dp_over_model else 1
    mb = max(1, microbatches)

    dp = int(np.prod([mesh.shape[a] for a in strategy.batch_mesh_axes(mesh)]))
    if shape.kind == "decode":
        # one token per sequence; batch shards over the data axes when it can
        tokens_local = shape.batch / dp if shape.batch % dp == 0 else float(shape.batch)
    else:
        tokens_local = shape.batch * shape.seq / dp

    w_bf16 = _local_param_bytes(cfg, mesh, BF16, strategy=strategy)
    w_f32_train = _local_param_bytes(cfg, mesh, F32, trainable_only=True,
                                     strategy=strategy)
    act_per_tok = _layer_boundary_bytes_per_token(cfg, model_sz)
    n_layers = cfg.n_layers
    eh_per_tok = _embed_head_bytes_per_token(cfg, model_sz, shape.kind == "train")

    out: Dict[str, float] = {}
    if shape.kind == "train":
        out["weights"] = 3.0 * mb * w_bf16            # fwd+bwd+remat, per microbatch
        out["optimizer"] = 7.0 * w_f32_train          # p,μ,ν,g reads + p,μ,ν writes
        out["grad_accum"] = (2.0 * (mb - 1)) * w_f32_train
        out["activations"] = 3.0 * tokens_local * act_per_tok * n_layers
        out["embed_head"] = tokens_local * eh_per_tok
        if attn_scores_hbm and cfg.n_heads:
            H_loc = cfg.n_heads / (model_sz if cfg.n_heads % model_sz == 0 else 1)
            per_mb_rows = tokens_local / mb
            sites = n_layers if cfg.family != "hybrid" else n_layers // cfg.attn_every
            out["attn_scores"] = (3.0 * 2.0 * sites * mb
                                  * per_mb_rows * shape.seq * H_loc * F32) / 2
    elif shape.kind == "prefill":
        out["weights"] = w_bf16
        out["activations"] = 1.0 * tokens_local * act_per_tok * n_layers
        out["embed_head"] = tokens_local * eh_per_tok
        out["cache_write"] = _cache_local_bytes(cfg, shape, mesh)
        if attn_scores_hbm and cfg.n_heads:
            H_loc = cfg.n_heads / (model_sz if cfg.n_heads % model_sz == 0 else 1)
            sites = n_layers if cfg.family != "hybrid" else n_layers // cfg.attn_every
            out["attn_scores"] = 2.0 * sites * tokens_local * shape.seq * H_loc * F32 / 2
    else:  # decode
        out["weights"] = w_bf16
        out["cache_read"] = _cache_local_bytes(cfg, shape, mesh)
        out["activations"] = tokens_local * act_per_tok * n_layers
        out["embed_head"] = tokens_local * eh_per_tok
    out["total"] = sum(out.values())
    return out


def _cache_local_bytes(cfg: LMConfig, shape: ShapeSpec, mesh) -> float:
    from repro.models.lm import init_cache
    from repro.parallel.policy import cache_shardings_policy
    import jax.numpy as jnp
    tpl = jax.eval_shape(lambda: init_cache(cfg, shape.batch, shape.seq,
                                            jnp.bfloat16))
    shards = cache_shardings_policy(cfg, tpl, mesh)
    total = 0.0
    for leaf, sh in zip(jax.tree.leaves(tpl), jax.tree.leaves(shards)):
        if sh is None or not hasattr(sh, "shard_shape"):
            total += float(np.prod(leaf.shape or (1,))) * leaf.dtype.itemsize
            continue
        total += float(np.prod(sh.shard_shape(leaf.shape))) * leaf.dtype.itemsize
    return total
