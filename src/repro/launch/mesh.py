"""Production mesh builders (spec'd in the brief; DESIGN.md §6).

Defined as FUNCTIONS so importing this module never touches jax device
state.  Single pod: v5e-256 as (16, 16) = (data, model).  Multi-pod: 2 pods
= 512 chips as (2, 16, 16) = (pod, data, model); the pod axis only carries
data parallelism (gradient all-reduce over DCI), model stays intra-pod.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh over however many (host) devices exist — tests/examples."""
    if pod:
        return jax.make_mesh(
            (pod, data, model), ("pod", "data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 3)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e chip constants (roofline; see EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # B/s per chip
ICI_BW_PER_LINK = 50e9        # B/s per link (~4 links usable per chip on 2D torus)
