"""Per-architecture OPTIMIZED distribution profiles (§Perf outcome).

The baseline table (results/dryrun_v2) uses one uniform policy: Megatron
TP/EP over the model axis + FSDP over data + per-arch microbatching.  The
hillclimbs (EXPERIMENTS.md §Perf) showed the right configuration is
arch-dependent:

  * <10B-parameter models at train_4k: the model axis is better spent on
    DATA parallelism (dp_over_model) — TP all-reduces dominated their step
    (e.g. qwen1.5-0.5b 0.98s collective vs 0.106s compute).  Their f32+bf16
    optimizer state fits under FSDP-over-data alone.
  * fine-grained MoE (granite): dense-dispatch MoE under pure DP (tiny
    expert GEMMs; E/top_k=5x FLOP overhead beats 16-way EP's psum+attention
    replication by 2.2x step time).
  * dbrx-132b: TP+EP mandatory (state does not fit otherwise); bf16 Adam
    moments + FSDP over (pod x data); fits only on the 2-pod mesh.
  * prefill/decode shapes keep the TP policy (their global batches are too
    small to spread over 256-512 DP shards).

Profiles apply per (arch, shape-kind).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

from repro.parallel.policy import Strategy

_DP_ALL = Strategy(dp_over_model=True)

# train_4k optimized settings; None field -> keep baseline default
OPTIMIZED_TRAIN: Dict[str, Dict[str, Any]] = {
    "qwen1.5-0.5b": dict(strategy=_DP_ALL, microbatches=1,
                         moments_dtype="float32",
                         overrides={"loss_vocab_chunk": 19008}),
    "chatglm3-6b": dict(strategy=_DP_ALL, microbatches=1,
                        moments_dtype="bfloat16",
                        overrides={"loss_vocab_chunk": 8128}),
    "yi-9b": dict(strategy=_DP_ALL, microbatches=1, moments_dtype="bfloat16",
                  overrides={"loss_vocab_chunk": 8000}),
    # 20B f32 masters do not fit under pure DP at mb=1 (32.8 GiB measured);
    # TP + mb8 + bf16 moments is the best FITTING config (15.9 GiB)
    # chunked CE hurts under TP (vocab-sharded head chunks force gathers):
    # plain loss with TP + mb8 + bf16 moments is the fitting config
    "internlm2-20b": dict(strategy=Strategy(), microbatches=8,
                          moments_dtype="bfloat16"),
    "musicgen-large": dict(strategy=_DP_ALL, microbatches=1,
                           moments_dtype="float32"),
    "mamba2-2.7b": dict(strategy=_DP_ALL, microbatches=1,
                        moments_dtype="bfloat16",
                        overrides={"loss_vocab_chunk": 6304}),
    "zamba2-7b": dict(strategy=_DP_ALL, microbatches=1,
                      moments_dtype="bfloat16",
                      overrides={"loss_vocab_chunk": 4000}),
    "qwen2-vl-7b": dict(strategy=_DP_ALL, microbatches=1,
                        moments_dtype="bfloat16",
                        overrides={"loss_vocab_chunk": 19008}),
    "granite-moe-3b-a800m": dict(strategy=_DP_ALL, microbatches=1,
                                 moments_dtype="bfloat16",
                                 overrides={"moe_impl": "dense"}),
    "dbrx-132b": dict(strategy=Strategy(), microbatches=8,
                      moments_dtype="bfloat16"),   # TP/EP mandatory at 132B
}


def optimized_cell_settings(arch: str, shape_kind: str) -> Optional[Dict[str, Any]]:
    if shape_kind == "train":
        return OPTIMIZED_TRAIN.get(arch)
    return None   # prefill/decode keep the baseline TP policy
