"""HashEmb: hash-based embedding compression (Yeh et al., KDD 2022) as a
first-class feature of a multi-pod JAX training/serving framework.

Subpackages
-----------
core      the paper's contribution: LSH coding, compositional codes, decoder
kernels   Pallas TPU kernels (hash_decode, lsh_encode, flash_attention)
nn        neural-net substrate (attention, MoE, SSD, norms, module system)
models    LM family (dense/MoE/SSM/hybrid) and GNNs (SAGE/GCN/SGC/GIN)
graph     CSR graphs, synthetic generators, neighbor sampling
data      synthetic token pipelines, checkpointable iterators
optim     AdamW, schedules, gradient compression
train     train-step factory, loop, checkpointing, fault tolerance
serving   single-token decode engine
parallel  logical-axis sharding rules, mesh helpers
launch    production mesh, multi-pod dry-run, drivers, roofline
configs   architecture registry (the 10 assigned archs + paper GNN stack)
"""

__version__ = "1.0.0"
