from repro.data.tokens import TokenStream, TokenStreamConfig, cooccurrence_matrix

__all__ = ["TokenStream", "TokenStreamConfig", "cooccurrence_matrix"]
