"""Synthetic LM token pipeline (offline container; DESIGN.md §7).

A latent Markov topic chain drives Zipf-distributed token emission, giving
the stream real co-occurrence structure — which is exactly the auxiliary
signal the paper's LSH coding consumes (tokens from the same topic hash to
nearby codes, the vocabulary analogue of adjacency rows).

`TokenStream` is deterministic in (seed, shard, position) and exposes
``state_dict``/``load_state_dict`` so the training checkpoint can resume the
pipeline exactly (fault tolerance requirement).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class TokenStreamConfig:
    vocab_size: int
    seq_len: int
    batch_size: int          # per-host batch
    n_topics: int = 64
    zipf_a: float = 1.2
    topic_stickiness: float = 0.98
    seed: int = 0
    shard: int = 0           # data-parallel shard id
    n_shards: int = 1


class TokenStream:
    def __init__(self, cfg: TokenStreamConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        V, T = cfg.vocab_size, cfg.n_topics
        # per-topic token distribution: Zipf ranks permuted per topic
        ranks = 1.0 / np.arange(1, V + 1) ** cfg.zipf_a
        self.topic_perm = np.stack([
            base.permutation(V) for _ in range(T)
        ])
        self.topic_probs = ranks / ranks.sum()
        self.step = 0

    def _rng(self, step: int) -> np.random.Generator:
        # deterministic per (seed, shard, step): restart-safe
        return np.random.default_rng(
            (self.cfg.seed * 1_000_003 + self.cfg.shard) * 1_000_003 + step
        )

    def next_batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = self._rng(self.step)
        B, S, T = cfg.batch_size, cfg.seq_len, cfg.n_topics
        topics = np.empty((B, S + 1), np.int64)
        topics[:, 0] = rng.integers(0, T, B)
        switch = rng.random((B, S)) > cfg.topic_stickiness
        new_topics = rng.integers(0, T, (B, S))
        for t in range(S):
            topics[:, t + 1] = np.where(switch[:, t], new_topics[:, t], topics[:, t])
        ranks = rng.choice(cfg.vocab_size, size=(B, S + 1), p=self.topic_probs)
        tokens = self.topic_perm[topics, ranks].astype(np.int32)
        self.step += 1
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    # -- checkpointable state -------------------------------------------
    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step, "seed": self.cfg.seed, "shard": self.cfg.shard}

    def load_state_dict(self, state: Dict[str, int]) -> None:
        assert state["seed"] == self.cfg.seed and state["shard"] == self.cfg.shard, \
            "restoring a token stream from a different run"
        self.step = int(state["step"])


def cooccurrence_matrix(
    stream: TokenStream, n_batches: int, window: int = 8,
    projection_dim: Optional[int] = 1024, seed: int = 17,
) -> np.ndarray:
    """One streaming pass building the vocabulary auxiliary matrix A for
    Algorithm 1 (the token analogue of the adjacency matrix).

    The full co-occurrence matrix is (V, V); we accumulate it through a
    count-sketch style random projection to (V, projection_dim) so the pass
    is O(V·p) memory — at V=152k full co-occurrence would be 92 GB, the
    projected one is 0.6 GB.  Random projection preserves the inner-product
    geometry LSH needs (Johnson–Lindenstrauss), and Algorithm 1 itself is
    projection-based, so this composes two projections.
    """
    V = stream.cfg.vocab_size
    p = projection_dim or V
    rng = np.random.default_rng(seed)
    signs = rng.choice([-1.0, 1.0], size=V).astype(np.float32)
    cols = rng.integers(0, p, V)
    A = np.zeros((V, p), np.float32)
    for _ in range(n_batches):
        toks = stream.next_batch()["tokens"]
        for row in toks:
            for off in range(1, window + 1):
                a, b = row[:-off], row[off:]
                np.add.at(A, (a, cols[b]), signs[b])
                np.add.at(A, (b, cols[a]), signs[a])
    # row-normalise (degree normalisation analogue)
    norms = np.linalg.norm(A, axis=1, keepdims=True)
    return A / np.maximum(norms, 1e-6)
