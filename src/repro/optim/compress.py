"""Gradient compression for cross-pod data parallelism (DESIGN.md §6).

int8 block-quantised all-reduce with error feedback (1-bit-Adam-family
technique, adapted):

  q, scale   = quantize(g + residual)        # per-block absmax int8
  g_hat      = psum(dequant(q)) / n_replicas # the collective carries 1/4 bytes
  residual'  = (g + residual) - dequant(q)   # error feedback accumulator

On a real fleet the psum over int8 happens on the wire (XLA all-reduce over
int32 accumulators); here we express quantise/dequantise around `jax.lax.psum`
inside shard_map so the collective payload in HLO is the quantised tensor —
visible to the roofline's collective-bytes parser.

Off by default: the assigned shapes are not DP-AR-bound (see §Roofline), so
the error-feedback state (1 extra f32 copy of grads) is not worth it there.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    flat = jnp.pad(x.reshape(-1), (0, pad))
    return flat.reshape(-1, BLOCK), n


def compress_gradients_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """g (any shape) -> (int8 blocks, f32 per-block scales)."""
    blocks, _ = _pad_to_block(g.astype(jnp.float32))
    absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def decompress_gradients_int8(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    deq = q.astype(jnp.float32) * scale[:, None]
    n = 1
    for s in shape:
        n *= s
    return deq.reshape(-1)[:n].reshape(shape)


def psum_compressed(g: jnp.ndarray, axis_name: str, residual: jnp.ndarray):
    """Error-feedback quantised psum.  Returns (mean_grad, new_residual).
    Must be called inside shard_map with ``axis_name`` bound.

    A per-replica scale cannot be applied after integer accumulation (an
    avg-scale heuristic measured 11% error), so replicas first agree on a
    SHARED per-block scale via a tiny pmax (n_blocks floats on the wire),
    then the int8 payload accumulates exactly in int32."""
    g_comp = g.astype(jnp.float32) + residual
    blocks, _ = _pad_to_block(g_comp)
    absmax = jnp.max(jnp.abs(blocks), axis=1)
    gmax = jax.lax.pmax(absmax, axis_name)               # shared scale
    scale = jnp.where(gmax > 0, gmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    deq_local = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[: g.size]
    new_residual = g_comp - deq_local.reshape(g.shape)
    # The wire payload: int32 accumulation of int8 values (XLA all-reduce).
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    deq = (summed.astype(jnp.float32) * scale[:, None]).reshape(-1)[: g.size]
    return (deq.reshape(g.shape) / n).astype(g.dtype), new_residual
