from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule, linear_warmup_cosine
from repro.optim.compress import compress_gradients_int8, decompress_gradients_int8

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update",
    "cosine_schedule", "linear_warmup_cosine",
    "compress_gradients_int8", "decompress_gradients_int8",
]
