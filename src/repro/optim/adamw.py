"""AdamW (Loshchilov & Hutter 2018) implemented from scratch.

Paper settings: lr=1e-3/1e-2, β1=0.9, β2=0.999, wd=0.01/0 (PyTorch defaults
— §5.1.2/§C.1/§5.3.2).  Decoupled weight decay; optional global-norm clip;
buffer leaves (``*_buf``) and non-float leaves are masked out, so packed
compositional codes and frozen codebooks ride along in the param pytree
without optimizer state or updates.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.nn.module import trainable_mask


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: Optional[float] = None
    moments_dtype: str = "float32"   # "bfloat16" halves optimizer HBM (dbrx)


def adamw_init(params, moments_dtype=jnp.float32) -> dict:
    mask = trainable_mask(params)

    def zeros_like_masked(p, m):
        return jnp.zeros_like(p, dtype=moments_dtype) if m else None

    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros_like_masked, params, mask),
        "nu": jax.tree.map(zeros_like_masked, params, mask),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)
              if x is not None and jnp.issubdtype(x.dtype, jnp.floating)]
    return jnp.sqrt(sum(leaves)) if leaves else jnp.zeros(())


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state).  ``lr_scale`` multiplies cfg.lr
    (schedule output)."""
    mask = trainable_mask(params)
    step = state["step"] + 1
    lr = cfg.lr * lr_scale

    if cfg.clip_norm is not None:
        masked_grads = jax.tree.map(lambda g, m: g if m else None, grads, mask)
        gn = global_norm(masked_grads)
        scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-9))
    else:
        scale = jnp.asarray(1.0, jnp.float32)

    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu, m):
        if not m:
            return p, mu, nu
        g = g.astype(jnp.float32) * scale
        mdt = mu.dtype
        mu = (cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g)
        nu = (cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g)
        mu_hat = mu / b1t
        nu_hat = nu / b2t
        newp = p - lr * (mu_hat / (jnp.sqrt(nu_hat) + cfg.eps) + cfg.weight_decay * p)
        return newp.astype(p.dtype), mu.astype(mdt), nu.astype(mdt)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    flat_m = treedef.flatten_up_to(mask)

    out = [upd(p, g, mu, nu, m)
           for p, g, mu, nu, m in zip(flat_p, flat_g, flat_mu, flat_nu, flat_m)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"step": step, "mu": new_mu, "nu": new_nu}
