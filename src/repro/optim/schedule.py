"""Learning-rate schedules (return multiplicative scale for AdamWConfig.lr)."""

from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(step):
    return jnp.ones_like(jnp.asarray(step, jnp.float32))


def cosine_schedule(step, total_steps: int, final_frac: float = 0.1):
    t = jnp.clip(jnp.asarray(step, jnp.float32) / max(total_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return final_frac + (1 - final_frac) * cos


def linear_warmup_cosine(step, warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = (step + 1.0) / jnp.maximum(warmup_steps, 1)  # step 0 trains too
    t = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup_steps, warm, cos)
