"""Mamba2 mixer via the chunked SSD (state-space duality) form
(Dao & Gu, arXiv:2405.21060) — DESIGN.md §5.

TPU adaptation: the chunked decomposition is already the MXU-native form —
the intra-chunk term is a masked (L×L) matmul and the inter-chunk term is a
short `lax.scan` over (H, N, P) states; no Pallas kernel is required (the
roofline confirms the layer is matmul-dominated).

Recurrence (per head h, state N, head-channels P):
    S_t = exp(dt_t·A_h) · S_{t-1} + (dt_t · x_t) ⊗ B_t
    y_t = C_t · S_t + D_h · x_t

Shapes: x (B,S,d_inner) viewed as (B,S,H,P); B_t/C_t (B,S,N) shared across
heads (n_groups=1); dt (B,S,H); A (H,) negative.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn import module as nn
from repro.nn.kvcache import SSMCache
from repro.parallel.sharding import logical

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128          # N
    headdim: int = 64           # P
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128            # SSD chunk length L
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.headdim == 0
        return self.d_inner // self.headdim

    @property
    def conv_channels(self) -> int:
        return self.d_inner + 2 * self.d_state


def init_ssm(key, cfg: SSMConfig) -> nn.Params:
    """Projections are stored per-component (w_z/w_x/w_b/w_c/w_dt instead of
    one fused w_in, and per-component depthwise convs) so every TP-sharded
    output dim aligns with SSD-head boundaries — the fused layout forced
    GSPMD to re-gather the (2·DI+2·N+H)-wide projection every layer because
    shard boundaries crossed the z/x/B/C/dt splits (measured 2.5x collective
    reduction on zamba2/mamba2; EXPERIMENTS.md §Perf).  Depthwise conv over
    the concatenation == concatenation of depthwise convs, so semantics are
    identical to the fused form."""
    ks = nn.split_keys(key, ["z", "x", "b", "c", "dtp", "conv", "dt", "out"])
    D, DI, N, H = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    # dt bias initialised so softplus(dt_bias) spans [dt_min, dt_max]
    u = jax.random.uniform(ks["dt"], (H,))
    dt_init = jnp.exp(u * (jnp.log(cfg.dt_max) - jnp.log(cfg.dt_min)) + jnp.log(cfg.dt_min))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus
    kcx, kcbc = jax.random.split(ks["conv"])
    return {
        "w_z": nn.dense_init(ks["z"], (D, DI)),
        "w_x": nn.dense_init(ks["x"], (D, DI)),
        "w_b": nn.dense_init(ks["b"], (D, N)),
        "w_c": nn.dense_init(ks["c"], (D, N)),
        "w_dt": nn.dense_init(ks["dtp"], (D, H)),
        "conv_x_w": nn.dense_init(kcx, (cfg.conv_width, DI),
                                  scale=1.0 / cfg.conv_width**0.5),
        "conv_x_b": jnp.zeros((DI,), jnp.float32),
        "conv_bc_w": nn.dense_init(kcbc, (cfg.conv_width, 2 * N),
                                   scale=1.0 / cfg.conv_width**0.5),
        "conv_bc_b": jnp.zeros((2 * N,), jnp.float32),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm_scale": jnp.ones((DI,), jnp.float32),
        "w_out": nn.dense_init(ks["out"], (DI, D)),
    }


def _causal_conv(x: Array, w: Array, b: Array, tail: Optional[Array] = None):
    """Depthwise causal conv along time.  x (B,S,C); w (W,C).  Returns
    (y (B,S,C), new_tail (B,W-1,C))."""
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)       # (B, S+W-1, C)
    y = sum(xp[:, i: i + x.shape[1]] * w[i][None, None].astype(x.dtype)
            for i in range(W))
    new_tail = xp[:, -(W - 1):] if W > 1 else xp[:, :0]
    return y + b.astype(x.dtype), new_tail


def ssd_chunked(X: Array, dt: Array, A: Array, Bc: Array, Cc: Array,
                chunk: int, init_state: Optional[Array] = None
                ) -> Tuple[Array, Array]:
    """Chunked SSD scan.

    X (B,S,H,P) f32; dt (B,S,H) f32 (post-softplus); A (H,) negative;
    Bc/Cc (B,S,N).  Returns (Y (B,S,H,P), final_state (B,H,N,P))."""
    B, S, H, Pd = X.shape
    N = Bc.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L

    la = dt * A[None, None, :]                                   # (B,S,H) ≤ 0
    lar = la.reshape(B, nc, L, H)
    cs = jnp.cumsum(lar, axis=2)                                 # inclusive
    Xd = (X * dt[..., None]).reshape(B, nc, L, H, Pd)
    Br = Bc.reshape(B, nc, L, N)
    Cr = Cc.reshape(B, nc, L, N)

    # ---- intra-chunk (masked matmul) ----
    G = jnp.einsum("bcin,bcjn->bcij", Cr, Br)                    # (B,nc,L,L)
    dec = cs[:, :, :, None, :] - cs[:, :, None, :, :]            # (B,nc,L,L,H) i,j
    causal = jnp.tril(jnp.ones((L, L), bool))
    M = jnp.where(causal[None, None, :, :, None], jnp.exp(dec), 0.0)
    scores = G[..., None] * M                                    # (B,nc,L,L,H)
    Y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, Xd)

    # ---- chunk states ----
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)                # (B,nc,L,H)
    S_chunk = jnp.einsum("bcln,bclh,bclhp->bchnp", Br, decay_to_end, Xd)

    # ---- inter-chunk scan ----
    T_c = jnp.exp(cs[:, :, -1, :])                               # (B,nc,H)
    if init_state is None:
        init_state = jnp.zeros((B, H, N, Pd), X.dtype)

    def body(s_prev, inp):
        t_c, s_c = inp                                           # (B,H), (B,H,N,P)
        s_new = t_c[:, :, None, None] * s_prev + s_c
        return s_new, s_prev                                     # emit state *before* chunk

    _final, S_prev = jax.lax.scan(
        body, init_state,
        (jnp.moveaxis(T_c, 1, 0), jnp.moveaxis(S_chunk, 1, 0)),
    )
    S_prev = jnp.moveaxis(S_prev, 0, 1)                          # (B,nc,H,N,P)

    Y_inter = jnp.einsum("bcln,bchnp->bclhp", Cr, S_prev) * jnp.exp(cs)[..., None]
    Y = (Y_intra + Y_inter).reshape(B, S, H, Pd)
    return Y, _final


def ssm_forward(
    params: nn.Params,
    x: Array,
    cfg: SSMConfig,
    cache: Optional[SSMCache] = None,
) -> Tuple[Array, Optional[SSMCache]]:
    """Full mixer. x (B,S,D).  cache!=None with S==1 -> single-step decode."""
    Bb, S, D = x.shape
    dt_all = x.dtype
    DI, N = cfg.d_inner, cfg.d_state
    z = x @ params["w_z"].astype(dt_all)
    xc = x @ params["w_x"].astype(dt_all)
    Bc = x @ params["w_b"].astype(dt_all)
    Cc = x @ params["w_c"].astype(dt_all)
    dt = x @ params["w_dt"].astype(dt_all)
    z = logical(z, "batch", "seq", "ssm_inner")
    xc = logical(xc, "batch", "seq", "ssm_inner")
    dt = logical(dt, "batch", "seq", "ssm_heads")

    tail = cache.conv if cache is not None else None
    tail_x = tail[..., :DI] if tail is not None else None
    tail_bc = tail[..., DI:] if tail is not None else None
    conv_x, new_tail_x = _causal_conv(xc, params["conv_x_w"], params["conv_x_b"], tail_x)
    bc = jnp.concatenate([Bc, Cc], axis=-1)
    conv_bc, new_tail_bc = _causal_conv(bc, params["conv_bc_w"], params["conv_bc_b"], tail_bc)
    xc = jax.nn.silu(conv_x)
    xc = logical(xc, "batch", "seq", "ssm_inner")
    conv_bc = jax.nn.silu(conv_bc)
    Bc = conv_bc[..., :N]
    Cc = conv_bc[..., N:]
    new_tail = (jnp.concatenate([new_tail_x, new_tail_bc], axis=-1)
                if cache is not None else None)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, None])
    A = -jnp.exp(params["A_log"])                                 # (H,) < 0
    H, Pd = cfg.n_heads, cfg.headdim
    X = xc.reshape(Bb, S, H, Pd).astype(jnp.float32)
    X = logical(X, "batch", "seq", "ssm_heads", None)
    Bf = Bc.astype(jnp.float32)
    Cf = Cc.astype(jnp.float32)

    if cache is not None and S == 1:
        # single-step recurrence
        a = jnp.exp(dt[:, 0] * A[None, :])                        # (B,H)
        Xd0 = X[:, 0] * dt[:, 0][..., None]                       # (B,H,P)
        state = cache.state * a[:, :, None, None] + jnp.einsum(
            "bn,bhp->bhnp", Bf[:, 0], Xd0)
        y = jnp.einsum("bn,bhnp->bhp", Cf[:, 0], state)[:, None]  # (B,1,H,P)
        new_cache = SSMCache(state=state, conv=new_tail).shard()
    else:
        init = cache.state if cache is not None else None
        y, final_state = ssd_chunked(X, dt, A, Bf, Cf, cfg.chunk, init)
        new_cache = SSMCache(state=final_state, conv=new_tail).shard() if cache is not None else None

    y = y + params["D_skip"].astype(y.dtype)[None, None, :, None] * X
    y = y.reshape(Bb, S, DI).astype(dt_all)

    # gated RMSNorm (mamba2): norm(y * silu(z)) * scale
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + 1e-6) * params["norm_scale"]).astype(dt_all)

    out = y @ params["w_out"].astype(dt_all)
    return out, new_cache


def ssd_reference(X, dt, A, Bc, Cc):
    """Naive O(S) per-step recurrence oracle (tests)."""
    B, S, H, Pd = X.shape
    N = Bc.shape[-1]
    state = jnp.zeros((B, H, N, Pd), jnp.float32)
    ys = []
    for t in range(S):
        a = jnp.exp(dt[:, t] * A[None, :])                        # (B,H)
        state = state * a[:, :, None, None] + jnp.einsum(
            "bn,bhp->bhnp", Bc[:, t], X[:, t] * dt[:, t][..., None])
        ys.append(jnp.einsum("bn,bhnp->bhp", Cc[:, t], state))
    return jnp.stack(ys, axis=1)
