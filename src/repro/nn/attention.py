"""Grouped-query attention with RoPE variants, KV cache, and selectable
implementation (XLA einsum oracle / Pallas flash kernel).

Shapes: x (B, S, D); q heads H, kv heads K (H % K == 0); head dim Dh.
TP sharding: heads over the "model" axis (q and kv; kv falls back to
replication when K < model-axis size via the divisibility guard).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn import module as nn
from repro.nn.kvcache import KVCache
from repro.nn.layers import init_linear, linear
from repro.nn.rope import apply_rope
from repro.parallel.sharding import logical

Array = jnp.ndarray

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    qkv_bias: bool = False
    out_bias: bool = False
    impl: str = "xla"          # "xla" | "flash"
    flash_block_q: int = 512
    flash_block_k: int = 512
    # beyond this kv length the XLA path runs q-chunked (scores never
    # materialise at (S, S) — the dry-run/memory stand-in for the flash
    # kernel's VMEM blocking)
    xla_chunk_threshold: int = 8192
    xla_chunk_q: int = 256


def init_attention(key, cfg: AttentionConfig) -> nn.Params:
    ks = nn.split_keys(key, ["q", "k", "v", "o"])
    H, K, Dh, D = cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_model
    return {
        "wq": init_linear(ks["q"], D, H * Dh, cfg.qkv_bias),
        "wk": init_linear(ks["k"], D, K * Dh, cfg.qkv_bias),
        "wv": init_linear(ks["v"], D, K * Dh, cfg.qkv_bias),
        "wo": init_linear(ks["o"], H * Dh, D, cfg.out_bias),
    }


def _qkv(params, x: Array, cfg: AttentionConfig, cos, sin):
    B, S, _ = x.shape
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = linear(params["wq"], x, x.dtype).reshape(B, S, H, Dh)
    k = linear(params["wk"], x, x.dtype).reshape(B, S, K, Dh)
    v = linear(params["wv"], x, x.dtype).reshape(B, S, K, Dh)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = logical(q, "batch", "seq", "heads", "head_dim")
    k = logical(k, "batch", "seq", "kv_heads", "head_dim")
    v = logical(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def _attend_xla(q: Array, k: Array, v: Array, *, causal: bool,
                q_offset: Array | int = 0, kv_valid: Optional[Array] = None,
                constrain_scores: bool = False) -> Array:
    """q (B,Sq,H,Dh), k/v (B,Sk,K,Dh) -> (B,Sq,H,Dh).  f32 softmax.

    constrain_scores pins the (…, S_kv) score dim to the cache's "kv_seq"
    mesh axis — without it GSPMD prefers all-gathering the seq-sharded
    decode cache (measured 96 GB/chip/step on internlm2 decode_32k); with
    it the softmax runs as sharded partials + tiny stat all-reduces
    (flash-decoding split-KV, expressed through GSPMD).
    """
    B, Sq, H, Dh = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, Dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    scores *= 1.0 / jnp.sqrt(Dh).astype(jnp.float32)
    if constrain_scores:
        scores = logical(scores, "batch", "kv_heads", None, None, "kv_seq")
    if causal:
        qpos = jnp.arange(Sq, dtype=jnp.int32) + q_offset
        kpos = jnp.arange(k.shape[1], dtype=jnp.int32)
        mask = kpos[None, :] <= qpos[:, None]                     # (Sq, Sk)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    if kv_valid is not None:
        scores = jnp.where(kv_valid[None, None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(B, Sq, H, Dh)


def _attend_xla_chunked(q: Array, k: Array, v: Array, *, causal: bool,
                        chunk: int, q_offset: Array | int = 0,
                        kv_valid: Optional[Array] = None) -> Array:
    """Exact attention with q processed in chunks (scores live at
    (B, K, G, chunk, S) instead of (…, S, S)).  Chunk bodies are
    checkpointed so the backward pass recomputes rather than saves them."""
    B, Sq, H, Dh = q.shape
    nc = Sq // chunk
    qc = q.reshape(B, nc, chunk, H, Dh)

    def body(_, inp):
        q_i, idx = inp
        off = idx * chunk + q_offset
        out_i = _attend_xla(q_i, k, v, causal=causal, q_offset=off,
                            kv_valid=kv_valid)
        return None, out_i

    _, out = jax.lax.scan(jax.checkpoint(body), None,
                          (jnp.moveaxis(qc, 1, 0), jnp.arange(nc)))
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, Dh)


def attention(
    params: nn.Params,
    x: Array,
    cfg: AttentionConfig,
    *,
    cos=None,
    sin=None,
    causal: bool = True,
    cache: Optional[KVCache] = None,
    interpret: bool = False,
) -> Tuple[Array, Optional[KVCache]]:
    """Returns (y (B,S,D), updated cache).

    Train/prefill: cache=None (or a cache being filled at offset 0).
    Decode: S is the new-token count (typically 1); attends over cache."""
    q, k, v = _qkv(params, x, cfg, cos, sin)

    if cache is not None:
        q_offset = cache.pos
        cache = cache.update(k, v)
        k_all, v_all = cache.k.astype(q.dtype), cache.v.astype(q.dtype)
        kv_valid = cache.valid_mask()
        Sq = q.shape[1]
        if Sq > cfg.xla_chunk_threshold and Sq % cfg.xla_chunk_q == 0:
            out = _attend_xla_chunked(q, k_all, v_all, causal=True,
                                      chunk=cfg.xla_chunk_q,
                                      q_offset=q_offset, kv_valid=kv_valid)
        else:
            out = _attend_xla(q, k_all, v_all, causal=True,
                              q_offset=q_offset, kv_valid=kv_valid,
                              constrain_scores=True)
    else:
        S = q.shape[1]
        if cfg.impl == "flash":
            from repro.kernels.flash_attention import ops as fa_ops
            out = fa_ops.flash_attention(
                q, k, v, causal=causal,
                block_q=cfg.flash_block_q, block_k=cfg.flash_block_k,
                interpret=interpret,
            )
        elif S > cfg.xla_chunk_threshold and S % cfg.xla_chunk_q == 0:
            out = _attend_xla_chunked(q, k, v, causal=causal,
                                      chunk=cfg.xla_chunk_q)
        else:
            out = _attend_xla(q, k, v, causal=causal)

    out = logical(out, "batch", "seq", "heads", "head_dim")
    B, S = x.shape[:2]
    y = linear(params["wo"], out.reshape(B, S, cfg.n_heads * cfg.d_head), x.dtype)
    return y, cache
