"""Top-k Mixture-of-Experts FFN.

Dispatch is sort-based: flatten (token, slot) assignments, argsort by expert
id, run grouped GEMMs with ``jax.lax.ragged_dot`` (verified CPU lowering +
grads), scatter-add back weighted by router probabilities.

Two execution paths:
  * ``moe_ffn``      — single-shard path, no token dropping (oracle + tests).
  * ``moe_ffn_ep``   — expert-parallel path under ``shard_map``: experts are
    sharded over the "model" mesh axis; each shard processes only the
    assignments routed to its local experts, bounded by a capacity factor
    (GShard-style dropping), then the partial outputs are psum-combined.
    Per-shard FLOPs scale as top_k/ep_degree — true EP compute scaling.

Expert-count padding: if n_experts is not divisible by the EP degree the
config pads with dummy experts whose router logits are masked to -inf
(granite's 40 experts on a 16-way model axis -> 48).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn import module as nn
from repro.parallel.sharding import current_mesh, current_rules, logical, shard_map

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                  # per-expert hidden
    n_experts: int             # logical experts
    top_k: int
    n_experts_padded: int = 0  # 0 => n_experts
    capacity_factor: float = 1.25
    act: str = "swiglu"
    router_dtype: str = "float32"
    impl: str = "ep"           # "ep" (shard_map EP) | "dense" (see moe_dense_ffn)

    @property
    def e_pad(self) -> int:
        return self.n_experts_padded or self.n_experts


def init_moe(key, cfg: MoEConfig) -> nn.Params:
    ks = nn.split_keys(key, ["router", "gate", "up", "down"])
    E, D, F = cfg.e_pad, cfg.d_model, cfg.d_ff
    return {
        "router": nn.dense_init(ks["router"], (D, E)),
        "w_gate": nn.dense_init(ks["gate"], (E, D, F)),
        "w_up": nn.dense_init(ks["up"], (E, D, F)),
        "w_down": nn.dense_init(ks["down"], (E, F, D)),
    }


def _topk_argmax(probs: Array, k: int):
    """top-k as k rounds of argmax+mask.  Equivalent to lax.top_k (up to tie
    order) but partitions trivially along the token dim — lax.top_k made
    GSPMD all-gather the full (T, E) router probs (measured 18 GB/chip/step
    on granite train_4k; EXPERIMENTS.md §Perf H4c)."""
    ws, ids = [], []
    p = probs
    for _ in range(k):
        i = jnp.argmax(p, axis=-1)
        w = jnp.max(p, axis=-1)
        ws.append(w)
        ids.append(i.astype(jnp.int32))
        p = p * (1.0 - jax.nn.one_hot(i, p.shape[-1], dtype=p.dtype))
    return jnp.stack(ws, -1), jnp.stack(ids, -1)


def router_probs(params, x: Array, cfg: MoEConfig):
    """x (T, D) -> (weights (T, k), idx (T, k)).  Softmax over real experts,
    padding experts masked; top-k renormalised."""
    logits = (x.astype(jnp.dtype(cfg.router_dtype)) @
              params["router"].astype(jnp.dtype(cfg.router_dtype)))
    if cfg.e_pad != cfg.n_experts:
        pad_mask = jnp.arange(cfg.e_pad) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = _topk_argmax(probs, cfg.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w.astype(x.dtype), idx.astype(jnp.int32)


def _grouped_ffn(xs: Array, group_sizes: Array, params, cfg: MoEConfig,
                 pad_zero_expert: bool = False) -> Array:
    """xs (R, D) rows sorted by expert; group_sizes (E[+1],)."""
    wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
    dt = xs.dtype
    wg, wu, wd = wg.astype(dt), wu.astype(dt), wd.astype(dt)
    if pad_zero_expert:
        wg = jnp.concatenate([wg, jnp.zeros_like(wg[:1])], 0)
        wu = jnp.concatenate([wu, jnp.zeros_like(wu[:1])], 0)
        wd = jnp.concatenate([wd, jnp.zeros_like(wd[:1])], 0)
    if cfg.act == "swiglu":
        g = jax.lax.ragged_dot(xs, wg, group_sizes)
        u = jax.lax.ragged_dot(xs, wu, group_sizes)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(jax.lax.ragged_dot(xs, wu, group_sizes))
    return jax.lax.ragged_dot(h, wd, group_sizes)


def moe_ffn(params, x: Array, cfg: MoEConfig) -> Array:
    """Single-shard MoE.  x (T, D) -> (T, D).  No dropping."""
    T, D = x.shape
    k = cfg.top_k
    w, idx = router_probs(params, x, cfg)
    e_flat = idx.reshape(-1)                            # (T*k,)
    t_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    w_flat = w.reshape(-1)
    order = jnp.argsort(e_flat)
    xs = jnp.take(x, t_flat[order], axis=0)
    group_sizes = jnp.bincount(e_flat, length=cfg.e_pad).astype(jnp.int32)
    ys = _grouped_ffn(xs, group_sizes, params, cfg)
    out = jnp.zeros_like(x)
    return out.at[t_flat[order]].add(ys * w_flat[order][:, None])


def _dense_expert_ffn(xs: Array, wg_e, wu_e, wd_e, cfg: MoEConfig) -> Array:
    """Plain dense FFN of ONE expert over its capacity slice (rows, D)."""
    if cfg.act == "swiglu":
        h = jax.nn.silu(xs @ wg_e) * (xs @ wu_e)
    else:
        h = jax.nn.gelu(xs @ wu_e)
    return h @ wd_e


def _ep_local_ffn(x, w, idx, params_local, cfg: MoEConfig, e_local: int,
                  capacity: int, axis_name: str) -> Array:
    """Runs on one EP shard inside shard_map.  params_local experts are the
    shard's slice; global expert range is [lo, lo + e_local).

    Per-expert capacity dropping (GShard-style): rows are sorted by local
    expert id; each local expert processes a fixed-size window of
    ``cap_e = capacity // e_local`` rows starting at its group offset (a
    dynamic_slice), as one DENSE matmul.  This keeps per-shard FLOPs at
    exactly cap_e·e_local·D·F on every backend — unlike ragged_dot, whose
    XLA:CPU reference lowering densifies over all groups (measured 16-38x
    FLOP inflation on dbrx; EXPERIMENTS.md §Perf).
    """
    T = x.shape[0]
    k = cfg.top_k
    dt = x.dtype
    shard = jax.lax.axis_index(axis_name)
    lo = shard * e_local
    e_flat = idx.reshape(-1) - lo                       # local ids
    t_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    w_flat = w.reshape(-1)
    local = (e_flat >= 0) & (e_flat < e_local)
    e_key = jnp.where(local, e_flat, e_local)           # non-local -> dummy
    order = jnp.argsort(e_key)
    e_s = e_key[order]
    t_s = t_flat[order]
    w_s = jnp.where(e_s < e_local, w_flat[order], 0.0)

    cap_e = max(1, capacity // e_local)
    group_sizes = jnp.bincount(e_s, length=e_local + 1).astype(jnp.int32)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(group_sizes)[:-1]])
    out = jnp.zeros_like(x)
    for e in range(e_local):                            # e_local is tiny: unrolled
        start = offsets[e]
        rows_t = jax.lax.dynamic_slice(t_s, (start,), (cap_e,))
        rows_w = jax.lax.dynamic_slice(w_s, (start,), (cap_e,))
        rows_e = jax.lax.dynamic_slice(e_s, (start,), (cap_e,))
        valid = rows_e == e                             # window may overrun
        xs = jnp.take(x, rows_t, axis=0)
        ys = _dense_expert_ffn(
            xs, params_local["w_gate"][e].astype(dt),
            params_local["w_up"][e].astype(dt),
            params_local["w_down"][e].astype(dt), cfg)
        out = out.at[rows_t].add(ys * (rows_w * valid)[:, None])
    return jax.lax.psum(out, axis_name)


def moe_dense_ffn(params, x: Array, cfg: MoEConfig) -> Array:
    """Dense-dispatch MoE: every expert runs on every token; router weights
    zero the non-selected ones.  FLOPs are E/top_k x the sparse ideal, which
    is the RIGHT trade for fine-grained experts under pure data parallelism
    (granite: E=40, d_ff=512 — expert GEMMs are too small to win from
    sort-based dispatch, and no EP axis is available under dp_over_model).
    Tokens stay batch-sharded; weights replicated; no collectives at all."""
    E = cfg.n_experts
    dt = x.dtype
    w, idx = router_probs(params, x, cfg)
    T = x.shape[0]
    wfull = jnp.zeros((T, E), dt).at[jnp.arange(T)[:, None], idx].add(w)
    wg = params["w_gate"][:E].astype(dt)
    wu = params["w_up"][:E].astype(dt)
    wd = params["w_down"][:E].astype(dt)
    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("td,edf->tef", x, wg)) * jnp.einsum(
            "td,edf->tef", x, wu)
    else:
        h = jax.nn.gelu(jnp.einsum("td,edf->tef", x, wu))
    return jnp.einsum("tef,te,efd->td", h, wfull, wd)


def moe_ffn_ep(params, x: Array, cfg: MoEConfig) -> Array:
    """Expert-parallel MoE.  Falls back to ``moe_ffn`` without a mesh or when
    the model axis cannot partition the experts."""
    mesh = current_mesh()
    if mesh is None:
        return moe_ffn(params, x, cfg)
    model_axes = current_rules().resolve("experts")
    if model_axes is None:
        return moe_ffn(params, x, cfg)
    if isinstance(model_axes, str):
        model_axes = (model_axes,)
    ep = 1
    for a in model_axes:
        ep *= mesh.shape[a]
    if ep == 1 or cfg.e_pad % ep != 0:
        return moe_ffn(params, x, cfg)
    axis_name = model_axes[0] if len(model_axes) == 1 else model_axes
    e_local = cfg.e_pad // ep

    T = x.shape[0]
    w, idx = router_probs(params, x, cfg)

    batch_axes = current_rules().resolve("batch") or ()
    if isinstance(batch_axes, str):
        batch_axes = (batch_axes,)
    batch_axes = tuple(a for a in batch_axes if a in mesh.shape)
    bspec = batch_axes[0] if len(batch_axes) == 1 else (batch_axes or None)
    tokens_spec = bspec if (batch_axes and T % _size(mesh, batch_axes) == 0) else None

    # per-shard capacity against SHARD-LOCAL rows: each shard sees T_local·k
    # assignments of which ~e_local/E are for its experts
    t_local = T // _size(mesh, batch_axes) if tokens_spec is not None else T
    rows_local = t_local * cfg.top_k
    capacity = int(rows_local * e_local / cfg.e_pad * cfg.capacity_factor) + 1
    capacity = min(capacity, rows_local)

    fn = partial(_ep_local_ffn, cfg=cfg, e_local=e_local,
                 capacity=capacity, axis_name=axis_name)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(tokens_spec, None), P(tokens_spec, None),
                  P(tokens_spec, None),
                  {"w_gate": P(model_axes if len(model_axes) > 1 else model_axes[0], None, None),
                   "w_up": P(model_axes if len(model_axes) > 1 else model_axes[0], None, None),
                   "w_down": P(model_axes if len(model_axes) > 1 else model_axes[0], None, None)}),
        out_specs=P(tokens_spec, None),
        check_vma=False,
    )(x, w, idx, {k2: params[k2] for k2 in ("w_gate", "w_up", "w_down")})


def _size(mesh, axes) -> int:
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s
