"""Minimal functional module conventions.

Parameters are nested dicts of jnp arrays (a pytree).  Every layer exposes
``init_<layer>(key, cfg...) -> params`` and ``<layer>(params, x, ...) -> y``.
No mutable module objects: this keeps pjit/shard_map, scan-over-layers and
checkpoint resharding trivial.

Conventions
-----------
* non-trainable buffers live under keys ending in ``_buf`` (the optimizer
  masks them out; see ``trainable_mask``) — e.g. packed compositional codes,
  frozen codebooks of the *light* decoder.
* compute dtype is controlled by the caller (bf16 activations typical);
  params are stored f32 ("master" copies) and cast at use sites.
"""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dense_init(key, shape, *, scale: Optional[float] = None, dtype=jnp.float32):
    """LeCun-normal (fan-in) initialisation by default."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * s).astype(dtype)


def embed_init(key, shape, *, scale: float = 0.02, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def zeros_init(_key, shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


def split_keys(key, names):
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


def param_count(params: Params, trainable_only: bool = False) -> int:
    total = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        if trainable_only and _path_is_buffer(path):
            continue
        total += leaf.size
    return total


def param_bytes(params: Params, trainable_only: bool = False) -> int:
    total = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        if trainable_only and _path_is_buffer(path):
            continue
        total += leaf.size * leaf.dtype.itemsize
    return total


def _path_is_buffer(path) -> bool:
    for p in path:
        k = getattr(p, "key", None)
        if isinstance(k, str) and k.endswith("_buf"):
            return True
    return False


def trainable_mask(params: Params) -> Params:
    """True for trainable leaves, False for ``*_buf`` buffers and integer
    leaves.  Shape-compatible pytree for the optimizer."""
    def mask_leaf(path, leaf):
        if _path_is_buffer(path):
            return False
        return jnp.issubdtype(leaf.dtype, jnp.floating)
    return jax.tree_util.tree_map_with_path(mask_leaf, params)


def cast_floats(tree: Params, dtype) -> Params:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )
