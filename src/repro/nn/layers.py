"""Basic layers: Linear, RMSNorm, LayerNorm, gated/plain MLPs."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import module as nn
from repro.parallel.sharding import logical

Array = jnp.ndarray


# ---- linear ---------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, bias: bool = False) -> nn.Params:
    p = {"w": nn.dense_init(key, (d_in, d_out))}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear(params: nn.Params, x: Array, dtype=None) -> Array:
    w = params["w"]
    if dtype is not None:
        w = w.astype(dtype)
    y = x @ w
    if "b" in params:
        b = params["b"].astype(y.dtype)
        y = y + b
    return y


# ---- norms ----------------------------------------------------------------

def init_rmsnorm(_key, d: int) -> nn.Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params: nn.Params, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(dt)


def init_layernorm(_key, d: int) -> nn.Params:
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params: nn.Params, x: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dt)


def init_norm(key, d: int, kind: str) -> nn.Params:
    return init_layernorm(key, d) if kind == "layernorm" else init_rmsnorm(key, d)


def norm(params: nn.Params, x: Array, kind: str) -> Array:
    return layernorm(params, x) if kind == "layernorm" else rmsnorm(params, x)


# ---- MLPs -----------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, act: str = "swiglu") -> nn.Params:
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "w_gate": nn.dense_init(ks[0], (d_model, d_ff)),
            "w_up": nn.dense_init(ks[1], (d_model, d_ff)),
            "w_down": nn.dense_init(ks[2], (d_ff, d_model)),
        }
    return {
        "w_up": nn.dense_init(ks[0], (d_model, d_ff)),
        "b_up": jnp.zeros((d_ff,), jnp.float32),
        "w_down": nn.dense_init(ks[1], (d_ff, d_model)),
        "b_down": jnp.zeros((d_model,), jnp.float32),
    }


def mlp(params: nn.Params, x: Array, act: str = "swiglu") -> Array:
    dt = x.dtype
    lead = ("batch",) + (None,) * (x.ndim - 2)   # (B, S, ·) activations
    if act == "swiglu":
        g = x @ params["w_gate"].astype(dt)
        u = x @ params["w_up"].astype(dt)
        h = jax.nn.silu(g) * u
        h = logical(h, *lead, "d_ff")
        return h @ params["w_down"].astype(dt)
    h = x @ params["w_up"].astype(dt) + params["b_up"].astype(dt)
    h = jax.nn.gelu(h)
    h = logical(h, *lead, "d_ff")
    return h @ params["w_down"].astype(dt) + params["b_down"].astype(dt)
