"""Decode-time caches.

KVCache: (B, S_max, n_kv, d_head) k/v ring buffers + scalar write position.
SSMCache: Mamba2 recurrent state (B, H, d_state, d_headdim) + conv tail.

Caches are plain pytrees so they thread through jit/scan and shard via the
logical rules ("kv_seq" binds to the data axis for long-context SP decode).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import logical

Array = jnp.ndarray


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class KVCache:
    k: Array      # (B, S_max, n_kv, d_head)
    v: Array
    pos: Array    # scalar int32 — next write index (same for all rows)

    def tree_flatten(self):
        return (self.k, self.v, self.pos), None

    @classmethod
    def tree_unflatten(cls, _aux, leaves):
        return cls(*leaves)

    @classmethod
    def zeros(cls, batch: int, s_max: int, n_kv: int, d_head: int, dtype=jnp.bfloat16):
        shape = (batch, s_max, n_kv, d_head)
        return cls(
            k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
            pos=jnp.zeros((), jnp.int32),
        )

    def shard(self) -> "KVCache":
        names = ("batch", "kv_seq", "kv_heads", "head_dim")
        return KVCache(logical(self.k, *names), logical(self.v, *names), self.pos)

    def update(self, k_new: Array, v_new: Array) -> "KVCache":
        """Append S_new timesteps (B, S_new, n_kv, d_head) at ``pos``.

        Sharding-aware write paths (EXPERIMENTS.md §Perf G6): a
        dynamic_update_slice into a cache whose sequence (or head) dim is
        sharded makes GSPMD all-gather the WHOLE cache every decode step
        (measured: 11.5 GB/chip/step on zamba2 long_500k).  So:
          * S_new == S_max  (prefill from zero): replace outright — no DUS.
          * S_new == 1      (decode): one-hot masked merge — elementwise,
            partitions cleanly on every dim; costs one cache re-write,
            which is the same order as the attention read it feeds.
          * otherwise (chunked prefill): DUS fallback.
        """
        kd, vd = k_new.astype(self.k.dtype), v_new.astype(self.v.dtype)
        s_new, s_max = k_new.shape[1], self.k.shape[1]
        if s_new == s_max:
            k, v = kd, vd
        elif s_new == 1:
            oh = (jnp.arange(s_max, dtype=jnp.int32) == self.pos)
            oh = oh.astype(self.k.dtype)[None, :, None, None]
            k = self.k * (1 - oh) + kd * oh
            v = self.v * (1 - oh) + vd * oh
        else:
            k = jax.lax.dynamic_update_slice(self.k, kd, (0, self.pos, 0, 0))
            v = jax.lax.dynamic_update_slice(self.v, vd, (0, self.pos, 0, 0))
        return KVCache(k, v, self.pos + s_new).shard()

    def valid_mask(self, s_max: Optional[int] = None) -> Array:
        """(S_max,) bool — which cache slots hold live tokens."""
        s_max = s_max or self.k.shape[1]
        return jnp.arange(s_max, dtype=jnp.int32) < self.pos


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SSMCache:
    state: Array      # (B, H, d_state, headdim)
    conv: Array       # (B, conv_width - 1, conv_channels)

    def tree_flatten(self):
        return (self.state, self.conv), None

    @classmethod
    def tree_unflatten(cls, _aux, leaves):
        return cls(*leaves)

    @classmethod
    def zeros(cls, batch: int, n_heads: int, d_state: int, headdim: int,
              conv_width: int, conv_channels: int, dtype=jnp.float32):
        return cls(
            state=jnp.zeros((batch, n_heads, d_state, headdim), dtype),
            conv=jnp.zeros((batch, conv_width - 1, conv_channels), dtype),
        )

    def shard(self) -> "SSMCache":
        return SSMCache(
            logical(self.state, "batch", "ssm_heads", "ssm_state", None),
            logical(self.conv, "batch", None, "d_ff"),
        )
