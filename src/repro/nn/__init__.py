from repro.nn import module
from repro.nn.module import param_count, param_bytes, dense_init, zeros_init

__all__ = ["module", "param_count", "param_bytes", "dense_init", "zeros_init"]
