"""Rotary position embeddings: standard, partial (chatglm3 "2d"), and
multimodal M-RoPE (qwen2-vl).

All variants share the rotate-half convention over the *rotated fraction* of
head dims.  ``positions`` is int32:
  standard / partial : (B, S)
  mrope              : (3, B, S) — temporal / height / width streams; head-dim
                       frequency bands are split into ``mrope_sections`` and
                       each band reads its own stream (arXiv:2409.12191).
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

Array = jnp.ndarray


def _freqs(d_rot: int, theta: float, dtype=jnp.float32) -> Array:
    return 1.0 / theta ** (jnp.arange(0, d_rot, 2, dtype=dtype) / d_rot)  # (d_rot/2,)


def rope_cos_sin(
    positions: Array,
    d_head: int,
    *,
    theta: float = 10000.0,
    fraction: float = 1.0,
    mrope_sections: Optional[Sequence[int]] = None,
) -> Tuple[Array, Array]:
    """Returns (cos, sin) of shape (B, S, d_rot/2) in f32."""
    d_rot = int(d_head * fraction) // 2 * 2
    inv = _freqs(d_rot, theta)                                   # (d_rot/2,)
    if mrope_sections is None:
        ang = positions[..., None].astype(jnp.float32) * inv     # (B, S, d_rot/2)
    else:
        if sum(mrope_sections) != d_rot // 2:
            raise ValueError(f"mrope sections {mrope_sections} != d_rot/2 {d_rot//2}")
        ang_all = positions[..., None].astype(jnp.float32) * inv  # (3, B, S, d_rot/2)
        pieces = []
        start = 0
        for sec_idx, sec in enumerate(mrope_sections):
            pieces.append(ang_all[sec_idx, :, :, start: start + sec])
            start += sec
        ang = jnp.concatenate(pieces, axis=-1)                   # (B, S, d_rot/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x (B, S, H, d_head); rotates the first 2*cos.shape[-1] dims."""
    d_rot = 2 * cos.shape[-1]
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = jnp.split(xr, 2, axis=-1)
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    r1 = x1 * c - x2 * s
    r2 = x2 * c + x1 * s
    out = jnp.concatenate([r1, r2], axis=-1)
    if xp.shape[-1]:
        out = jnp.concatenate([out, xp], axis=-1)
    return out


def default_positions(batch: int, seq: int, variant: str) -> Array:
    """Text-only position ids (the VLM/audio frontends are stubs; their
    position streams coincide with the temporal stream)."""
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (batch, seq))
    if variant == "mrope":
        return jnp.broadcast_to(pos[None], (3, batch, seq))
    return pos
