from repro.parallel.sharding import (
    ShardingRules,
    DEFAULT_RULES,
    use_sharding,
    logical,
    logical_sharding,
    current_mesh,
)

__all__ = [
    "ShardingRules", "DEFAULT_RULES", "use_sharding", "logical",
    "logical_sharding", "current_mesh",
]
