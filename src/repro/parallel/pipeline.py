"""GPipe-style pipeline parallelism as a shard_map primitive (DESIGN.md §6).

``gpipe(stage_fn, stage_params, microbatches, mesh, axis)`` runs
``n_stages = mesh.shape[axis]`` pipeline stages, one per shard of ``axis``:
each schedule tick, every stage applies its layer chunk to its live
microbatch and rotates the result to the next stage with
``lax.ppermute`` — the classic circular-pipeline schedule
(n_micro + n_stages − 1 ticks; bubble fraction (S−1)/(M+S−1)).

The rotation is differentiable (ppermute's transpose is the reverse
permutation), so the same primitive serves training; the bubble cost is
analytic, not hidden — report it alongside the roofline when using PP
(the dry-run's per-chip FLOPs don't model idle ticks).

Scope note: this is the PP building block (correctness-tested vs the
sequential reference on a host mesh).  The production profiles in
launch/profiles.py use TP/EP/DP — at the assigned shapes those dominated PP
in napkin math (16 stages on the model axis give a 48% bubble at 16
microbatches); PP becomes the right tool at longer pipelines-per-pod or
with interleaved schedules, both of which layer on top of this primitive.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.sharding import shard_map

Array = jnp.ndarray


def _pipeline_shard(stage_params, microbatches, *, stage_fn: Callable,
                    axis: str, n_stages: int):
    """Runs on one stage shard.  stage_params: this stage's layer stack
    (leading dim = layers-per-stage); microbatches (M, mb, S, D) replicated."""
    stage = jax.lax.axis_index(axis)
    # shard_map keeps the sharded stage dim at local size 1: squeeze it
    stage_params = jax.tree.map(lambda p: p[0], stage_params)
    M = microbatches.shape[0]
    ticks = M + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
    mb_shape = microbatches.shape[1:]

    def tick(carry, t):
        live, out_acc = carry
        # stage 0 injects microbatch t (or zeros in the drain phase)
        inject = jnp.where(
            t < M,
            jax.lax.dynamic_index_in_dim(microbatches, jnp.minimum(t, M - 1),
                                         keepdims=False),
            jnp.zeros(mb_shape, microbatches.dtype))
        x = jnp.where(stage == 0, inject, live)
        y = stage_fn(stage_params, x)
        # the final stage's output for microbatch (t - (S-1)) is ready
        emit_idx = t - (n_stages - 1)
        is_emit = (emit_idx >= 0) & (stage == n_stages - 1)
        out_acc = jax.lax.cond(
            emit_idx >= 0,
            lambda acc: acc.at[jnp.maximum(emit_idx, 0)].add(
                jnp.where(is_emit, y, 0.0)),
            lambda acc: acc,
            out_acc)
        live_next = jax.lax.ppermute(y, axis, perm)
        return (live_next, out_acc), None

    init = (jnp.zeros(mb_shape, microbatches.dtype),
            jnp.zeros((M,) + mb_shape, microbatches.dtype))
    (_, outputs), _ = jax.lax.scan(tick, init, jnp.arange(ticks))
    # outputs are zero everywhere except the final stage: psum broadcasts
    return jax.lax.psum(outputs, axis)


def gpipe(stage_fn: Callable, stage_params, microbatches: Array,
          mesh: Mesh, axis: str = "model") -> Array:
    """Pipeline-parallel apply.

    stage_fn(params_one_stage, x (mb, S, D)) -> (mb, S, D)
    stage_params: pytree with leading dim n_stages (sharded over ``axis``)
    microbatches: (M, mb, S, D), replicated over ``axis``
    Returns (M, mb, S, D) — equal to running all stages sequentially.
    """
    n_stages = mesh.shape[axis]

    def strip_stage(spec_tree):
        return jax.tree.map(lambda _: P(axis), spec_tree)

    fn = partial(_pipeline_shard, stage_fn=stage_fn, axis=axis,
                 n_stages=n_stages)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(strip_stage(stage_params), P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, microbatches)


def pipeline_reference(stage_fn: Callable, stage_params, microbatches: Array
                       ) -> Array:
    """Sequential oracle: run every stage on every microbatch in order."""
    n_stages = jax.tree.leaves(stage_params)[0].shape[0]

    def run_mb(x):
        for s in range(n_stages):
            params_s = jax.tree.map(lambda p: p[s], stage_params)
            x = stage_fn(params_s, x)
        return x

    return jax.vmap(run_mb)(microbatches)
