"""Concrete sharding policies: params (TP ⊗ FSDP), caches, batches.

Policy (DESIGN.md §6):
  * 2-D weights (stacked (L, D_in, D_out) or flat): TP-shard the
    "parallel" dim over ``model`` — column-parallel for in-projections
    (w_gate/w_up/wq/wk/wv/head), row-parallel for out-projections
    (w_down/wo) — and FSDP-shard the other dim over ``data`` (ZeRO-style;
    XLA all-gathers per scan step and reduce-scatters grads).
  * attention weights only TP-shard when the *head count* divides the model
    axis (never split inside a head); granite (24H) and qwen2-vl (28H) fall
    back to FSDP-only attention — documented in DESIGN.md.
  * MoE experts: E over ``model`` (EP ≡ TP axis), D over ``data``.
  * embedding: dense table vocab-parallel; compressed codes + decoder
    replicated (the decoder is ≤ 10 MB — that IS the paper's point).
  * KV caches: kv_heads over ``model`` when divisible, else the cache
    *sequence* dim takes ``model`` (flash-decoding style partial-softmax
    sharding); batch over (pod, data); batch==1 long-context gives the
    sequence dim the data axis too (SP decode).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import LMConfig


@dataclasses.dataclass(frozen=True)
class Strategy:
    """Distribution strategy knobs (the §Perf hillclimb surface).

    tp_attn/tp_ffn/tp_vocab: Megatron-style tensor parallelism over the
      ``model`` axis for the respective weights + activations.
    dp_over_model: fold the model axis into data parallelism (batch shards
      over pod×data×model) — the right call for small models where TP
      all-reduces dominate (e.g. qwen1.5-0.5b; see EXPERIMENTS.md §Perf).
    fsdp: ZeRO-style parameter/optimizer sharding over the data axis.
    seq_shard_activations: sequence-shard the residual stream over `model`
      between blocks (Megatron sequence parallelism; pairs with tp).
    """
    tp_attn: bool = True
    tp_ffn: bool = True
    tp_vocab: bool = True
    dp_over_model: bool = False
    fsdp: bool = True
    seq_shard_activations: bool = False

    def batch_mesh_axes(self, mesh: Mesh) -> Tuple[str, ...]:
        axes = [a for a in ("pod", "data") if a in mesh.shape]
        if self.dp_over_model and "model" in mesh.shape:
            axes.append("model")
        return tuple(axes)


DEFAULT_STRATEGY = Strategy()


def rules_for(strategy: Strategy, mesh: Mesh):
    """ShardingRules (activation annotations) matching a Strategy."""
    from repro.parallel.sharding import DEFAULT_RULES, ShardingRules
    rules = dict(DEFAULT_RULES.rules)
    rules["batch"] = strategy.batch_mesh_axes(mesh)
    if not strategy.tp_attn or strategy.dp_over_model:
        rules["heads"] = None
        rules["kv_heads"] = None
    if not strategy.tp_ffn or strategy.dp_over_model:
        rules["d_ff"] = None
        rules["experts"] = None
        rules["ssm_heads"] = None
        rules["ssm_inner"] = None
    if not strategy.tp_vocab or strategy.dp_over_model:
        rules["vocab"] = None
    if strategy.seq_shard_activations:
        rules["seq"] = "model" if not strategy.dp_over_model else None
    return ShardingRules(rules=rules)


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    axes = (axes,) if isinstance(axes, str) else axes
    s = 1
    for a in axes:
        s *= mesh.shape.get(a, 1)
    return s


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    axes = (axes,) if isinstance(axes, str) else tuple(axes or ())
    if not all(a in mesh.shape for a in axes):
        return False
    return dim % _axsize(mesh, axes) == 0


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

_COL_PAR = re.compile(r"(w_gate|w_up|wq|wk|wv|head)$")
_ROW_PAR = re.compile(r"(w_down|wo)$")


def _leaf_spec(path_keys, leaf, cfg: LMConfig, mesh: Mesh,
               strategy: Strategy = DEFAULT_STRATEGY) -> P:
    path = "/".join(path_keys)
    shape = leaf.shape
    ndim = len(shape)
    model_sz = mesh.shape.get("model", 1)
    tp_attn = strategy.tp_attn and not strategy.dp_over_model
    tp_ffn = strategy.tp_ffn and not strategy.dp_over_model
    tp_vocab = strategy.tp_vocab and not strategy.dp_over_model
    if strategy.dp_over_model:
        fsdp_axes = (("pod", "data"), ("data",), ("model",))
    else:
        fsdp_axes = (("pod", "data"), ("data",))
    def fsdp_axis(dim):
        if not strategy.fsdp:
            return None
        for ax in fsdp_axes:
            if all(a in mesh.shape for a in ax) and _fits(dim, mesh, ax):
                return ax[0] if len(ax) == 1 else ax
        return None

    # ---- embedding subtree ----
    if "embed/" in path or path.startswith("embed"):
        if path.endswith("table"):  # dense NC table: vocab-parallel + FSDP
            spec = [None] * ndim
            if tp_vocab and _fits(shape[0], mesh, "model"):
                spec[0] = "model"
            if ndim > 1 and strategy.fsdp and _fits(shape[1], mesh, "data"):
                spec[1] = "data"
            return P(*spec)
        return P(*([None] * ndim))     # codes + decoder: replicated (tiny)

    # ---- attention projections: only split whole heads ----
    is_attn = "/attn/" in path or path.endswith("attn")
    leafname = path_keys[-2] if path_keys[-1] in ("w", "b") else path_keys[-1]
    if is_attn and path_keys[-1] == "w":
        n_heads = cfg.n_heads if leafname in ("wq", "wo") else cfg.n_kv_heads
        heads_ok = tp_attn and n_heads and n_heads % model_sz == 0
        spec = [None] * ndim
        if leafname in ("wq", "wk", "wv"):
            if heads_ok and _fits(shape[-1], mesh, "model"):
                spec[-1] = "model"
            spec[-2] = fsdp_axis(shape[-2])
        else:  # wo: row-parallel
            if heads_ok and _fits(shape[-2], mesh, "model"):
                spec[-2] = "model"
            spec[-1] = fsdp_axis(shape[-1])
        if spec[-1] == spec[-2] and spec[-1] is not None:
            spec[-2] = None
        return P(*spec)
    if is_attn and path_keys[-1] == "b":
        return P(*([None] * ndim))

    # ---- MoE experts: (L, E, D, F) / (L, E, F, D); router (L, D, E) ----
    if "/moe/" in path:
        spec = [None] * ndim
        if leafname in ("w_gate", "w_up", "w_down") and ndim >= 3:
            e_dim = ndim - 3
            if tp_ffn and _fits(shape[e_dim], mesh, "model"):
                spec[e_dim] = "model"
            d_dim = ndim - 2 if leafname != "w_down" else ndim - 1
            ax = fsdp_axis(shape[d_dim])
            if ax is not None and ax != spec[e_dim]:
                spec[d_dim] = ax
        elif leafname == "router":
            spec[-2] = fsdp_axis(shape[-2])
        return P(*spec)

    # ---- generic 2D+ weights ----
    if leafname in ("w_b", "w_c"):   # SSD B/C projections: N stays whole
        spec = [None] * ndim
        spec[-2] = fsdp_axis(shape[-2])
        return P(*spec)
    if ndim >= 2 and path_keys[-1].startswith("w") or leafname in ("head",):
        spec = [None] * ndim
        if _COL_PAR.search(leafname or "") or leafname in ("w_in", "head"):
            col, row = ndim - 1, ndim - 2
        elif _ROW_PAR.search(leafname or "") or leafname == "w_out":
            col, row = ndim - 2, ndim - 1
        else:
            col, row = ndim - 1, ndim - 2
        if ndim >= 2:
            tp_here = tp_vocab if leafname == "head" else tp_ffn
            if tp_here and _fits(shape[col], mesh, "model"):
                spec[col] = "model"
            ax = fsdp_axis(shape[row])
            if ax is not None and ax != spec[col]:
                spec[row] = ax
            return P(*spec)

    # ---- everything else (norms, biases, scalars, conv) ----
    return P(*([None] * len(shape)))


def params_shardings(cfg: LMConfig, params_tree, mesh: Mesh,
                     strategy: Strategy = DEFAULT_STRATEGY):
    """Maps an (abstract) param pytree to NamedShardings."""
    def fn(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        return NamedSharding(mesh, _leaf_spec(keys, leaf, cfg, mesh, strategy))
    return jax.tree_util.tree_map_with_path(fn, params_tree)


def state_shardings(cfg: LMConfig, state_tree, mesh: Mesh,
                    strategy: Strategy = DEFAULT_STRATEGY):
    """Shardings for {'params', 'opt': {'step','mu','nu'}, 'step'} — the
    Adam moments inherit their param's sharding (ZeRO: optimizer state is
    sharded at least as much as the weights)."""
    pshard = params_shardings(cfg, state_tree["params"], mesh, strategy)
    def moment_shard(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        return NamedSharding(mesh, _leaf_spec(keys, leaf, cfg, mesh, strategy))
    return {
        "params": pshard,
        "opt": {
            "step": NamedSharding(mesh, P()),
            "mu": jax.tree_util.tree_map_with_path(moment_shard, state_tree["opt"]["mu"]),
            "nu": jax.tree_util.tree_map_with_path(moment_shard, state_tree["opt"]["nu"]),
        },
        "step": NamedSharding(mesh, P()),
    }


# ---------------------------------------------------------------------------
# batches & caches
# ---------------------------------------------------------------------------

def batch_shardings(batch_tree, mesh: Mesh,
                    strategy: Strategy = DEFAULT_STRATEGY):
    """Token batches: leading dim over the DP axes; positions (3,B,S) on
    dim 1; everything else replicated on trailing dims."""
    baxes = strategy.batch_mesh_axes(mesh)

    def fn(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        b_dim = 1 if keys and keys[-1] == "positions" and len(leaf.shape) == 3 else 0
        spec = [None] * len(leaf.shape)
        ax = tuple(baxes)
        while ax and not _fits(leaf.shape[b_dim] if leaf.shape else 0, mesh, ax):
            ax = ax[1:]   # shed leading axes (see sharding._spec_for)
        if leaf.shape and ax:
            spec[b_dim] = ax if len(ax) > 1 else ax[0]
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(fn, batch_tree)


def frontier_batch_shardings(batch, mesh: Mesh, axis: Optional[str] = None):
    """Shardings for a streaming-engine batch dict ({"frontier":
    FrontierBatch, "labels": ...}): the frontier's row-parallel leaves
    (``unique`` ids and the ``valid`` mask) go on the data axis — shard s's
    block of a ``ShardedSageBatchSource`` stack lands on device s — while
    index maps, labels and counters stay replicated (they feed the
    post-all_gather combine, which every device runs on the full batch)."""
    from repro.graph.sampler import FrontierBatch
    from repro.parallel.sharding import data_axis

    axis = axis or data_axis(mesh)
    k = mesh.shape[axis]
    rep = NamedSharding(mesh, P())

    def rows(leaf):
        if leaf.shape and leaf.shape[0] % k == 0:
            return NamedSharding(mesh, P(axis))
        return rep

    def fn(v):
        if isinstance(v, FrontierBatch):
            # OwnerPlan leaves are stacked along the shard axis (leading dim
            # n_shards), so each shard's slice of the routing lands with its
            # frontier rows
            return FrontierBatch(
                unique=rows(v.unique),
                index_maps=tuple(rep for _ in v.index_maps),
                n_unique=rep,
                valid=None if v.valid is None else rows(v.valid),
                plan=None if v.plan is None else jax.tree.map(rows, v.plan),
                n_decode=v.n_decode,
                # batch-carried packed code rows (codes_placement="host"):
                # row-aligned with ``unique``, so they split the same way
                codes=None if v.codes is None else rows(v.codes))
        return jax.tree.map(lambda _: rep, v)

    return {key: fn(v) for key, v in batch.items()}


def make_frontier_placement(mesh: Mesh, axis: Optional[str] = None):
    """``device`` callable for ``PrefetchIterator``: the producer thread
    places each batch straight into the sharded layout above, so per-shard
    frontier rows never bounce through a single device."""
    def place(batch):
        return jax.device_put(batch, frontier_batch_shardings(batch, mesh, axis))
    return place


def kv_seq_mesh_axis(cfg: LMConfig, mesh: Mesh,
                     strategy: Strategy = DEFAULT_STRATEGY,
                     batch: int = 0):
    """Mesh axis carrying the KV-cache sequence dim (None if kv_heads take
    the model axis and batch takes data) — must match cache_shardings_policy
    so attention-score constraints line up with the cache layout."""
    model_sz = mesh.shape.get("model", 1)
    kv_model_ok = (cfg.n_kv_heads and cfg.n_kv_heads % model_sz == 0
                   and not strategy.dp_over_model)
    baxes = strategy.batch_mesh_axes(mesh)
    batch_shardable = batch > 1 and _fits(batch, mesh, baxes)
    if kv_model_ok:
        return None if batch_shardable else "data"
    return "model" if batch_shardable else tuple(
        a for a in ("data", "model") if a in mesh.shape)


def cache_shardings_policy(cfg: LMConfig, cache_tree, mesh: Mesh,
                           strategy: Strategy = DEFAULT_STRATEGY):
    """LMCache shardings (see module docstring for the kv_seq fallback)."""
    baxes = strategy.batch_mesh_axes(mesh)
    model_sz = mesh.shape.get("model", 1)
    kv_model_ok = (cfg.n_kv_heads and cfg.n_kv_heads % model_sz == 0
                   and not strategy.dp_over_model)

    def kv_spec(leaf):
        sites, B, S, K, Dh = leaf.shape
        spec = [None] * 5
        used_data = False
        if _fits(B, mesh, baxes) and B > 1:
            spec[1] = baxes if len(baxes) > 1 else baxes[0]
            used_data = True
        if kv_model_ok:
            spec[3] = "model"
            if not used_data and _fits(S, mesh, "data"):
                spec[2] = "data"      # SP decode (batch==1 long context)
        else:
            seq_axes = ("model",) if used_data else tuple(
                a for a in ("data", "model") if a in mesh.shape)
            seq_axes = tuple(a for a in seq_axes if a in mesh.shape)
            if seq_axes and _fits(S, mesh, seq_axes):
                spec[2] = seq_axes if len(seq_axes) > 1 else seq_axes[0]
        return NamedSharding(mesh, P(*spec))

    def ssm_spec(leaf):
        L, B, H, N, Pd = leaf.shape
        spec = [None] * 5
        if _fits(B, mesh, baxes) and B > 1:
            spec[1] = baxes if len(baxes) > 1 else baxes[0]
        if _fits(H, mesh, "model"):
            spec[2] = "model"
        return NamedSharding(mesh, P(*spec))

    def conv_spec(leaf):
        L, B, W, C = leaf.shape
        spec = [None] * 4
        if _fits(B, mesh, baxes) and B > 1:
            spec[1] = baxes if len(baxes) > 1 else baxes[0]
        return NamedSharding(mesh, P(*spec))

    from repro.models.lm import LMCache
    return LMCache(
        pos=NamedSharding(mesh, P()),
        kv_k=kv_spec(cache_tree.kv_k) if cache_tree.kv_k is not None else None,
        kv_v=kv_spec(cache_tree.kv_v) if cache_tree.kv_v is not None else None,
        ssm_state=ssm_spec(cache_tree.ssm_state) if cache_tree.ssm_state is not None else None,
        conv=conv_spec(cache_tree.conv) if cache_tree.conv is not None else None,
    )
