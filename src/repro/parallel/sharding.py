"""Logical-axis sharding rules (DESIGN.md §6).

Model code annotates tensors with *logical* axis names
(``logical(x, "batch", "seq", "embed")``); the active ``ShardingRules`` maps
logical names to mesh axes.  Outside a ``use_sharding`` context every
annotation is a no-op, so the same model code runs single-device tests and
512-chip dry-runs unchanged.

Divisibility guard: a logical axis only binds to its mesh axes if the tensor
dimension is divisible by the mesh-axis-product; otherwise that dimension is
replicated (e.g. chatglm3's 2 KV heads on a 16-way model axis — standard
practice is KV replication when kv_heads < TP degree).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# shard_map graduated from jax.experimental in ~0.5 and renamed its
# replication-check kwarg check_rep -> check_vma; support both homes.
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pragma: no cover - old-jax fallback
    from jax.experimental.shard_map import shard_map as _shard_map_experimental

    def shard_map(f, *, check_vma=None, **kwargs):
        if check_vma is not None:
            kwargs["check_rep"] = check_vma
        return _shard_map_experimental(f, **kwargs)

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: Dict[str, MeshAxes]

    def resolve(self, name: Optional[str]) -> MeshAxes:
        if name is None:
            return None
        return self.rules.get(name)


# DP over (pod, data); TP/EP over model; SP (long-context cache) over data.
DEFAULT_RULES = ShardingRules(rules={
    "batch": ("pod", "data"),
    "batch_nopod": "data",
    "seq": None,
    "kv_seq": None,        # overridden to "data" for long-context decode (SP)
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "d_ff": "model",
    "experts": "model",
    "expert_ff": None,
    "vocab": "model",
    "ssm_heads": "model",
    "ssm_inner": "model",   # d_inner sharded on SSD-head boundaries
    "ssm_state": None,
    "fsdp": "data",        # parameter/optimizer-state sharding axis (ZeRO)
    "codebook": None,      # hash-decoder codebooks: replicated (tiny)
    "entities": None,      # packed code rows (override to "data" to shard
                           # the code buffer row-wise across hosts)
    "frontier": "data",    # unique-node decode frontier: data-parallel rows
})


class _State(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: ShardingRules = DEFAULT_RULES


_STATE = _State()


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], rules: Optional[ShardingRules] = None):
    prev = (_STATE.mesh, _STATE.rules)
    _STATE.mesh = mesh
    _STATE.rules = rules or DEFAULT_RULES
    try:
        yield
    finally:
        _STATE.mesh, _STATE.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _STATE.mesh


def current_rules() -> ShardingRules:
    return _STATE.rules


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def _spec_for(shape: Sequence[int], names: Sequence[Optional[str]]) -> Optional[P]:
    mesh = _STATE.mesh
    if mesh is None:
        return None
    rules = _STATE.rules
    parts = []
    used: set = set()
    for dim, name in zip(shape, names):
        axes = rules.resolve(name)
        if axes is None:
            parts.append(None)
            continue
        ax_tuple = (axes,) if isinstance(axes, str) else tuple(axes)
        # skip axes already used by an earlier dim or absent from the mesh
        ax_tuple = tuple(a for a in ax_tuple if a in mesh.shape and a not in used)
        # greedy fallback: drop leading axes until the product divides the
        # dim (e.g. batch 256 on a 512-chip (pod,data,model) DP binding
        # sheds "pod" and shards over (data, model))
        while ax_tuple:
            size = 1
            for a in ax_tuple:
                size *= mesh.shape[a]
            if size > 1 and dim % size == 0:
                break
            ax_tuple = ax_tuple[1:]
        if not ax_tuple:
            parts.append(None)
            continue
        used.update(ax_tuple)
        parts.append(ax_tuple[0] if len(ax_tuple) == 1 else ax_tuple)
    return P(*parts)


def logical_sharding(shape: Sequence[int], *names: Optional[str]) -> Optional[NamedSharding]:
    """NamedSharding for a logical shape, or None when no mesh is active."""
    if len(names) != len(shape):
        raise ValueError(f"{len(names)} names for rank-{len(shape)} shape")
    spec = _spec_for(shape, names)
    if spec is None:
        return None
    return NamedSharding(_STATE.mesh, spec)


def logical(x, *names: Optional[str]):
    """Annotate array ``x`` with logical axis names (no-op without a mesh)."""
    s = logical_sharding(x.shape, *names)
    if s is None:
        return x
    return jax.lax.with_sharding_constraint(x, s)


def data_axis(mesh: Mesh) -> str:
    """The mesh axis carrying data-parallel rows: ``"data"`` when present,
    else the first axis (1-axis ad-hoc meshes in tests/benchmarks)."""
    return "data" if "data" in mesh.shape else mesh.axis_names[0]


def data_axis_size(mesh: Optional[Mesh] = None) -> int:
    """Shard count of the active (or given) mesh's data axis; 1 without a
    mesh — the single-device no-op the sharded decode backend falls back to."""
    mesh = mesh if mesh is not None else _STATE.mesh
    if mesh is None:
        return 1
    return mesh.shape[data_axis(mesh)]


def all_to_all(x, axis: str, split_axis: int = 0, concat_axis: int = 0):
    """Tiled ``all_to_all`` over a named mesh axis (inside ``shard_map``):
    splits ``x``'s ``split_axis`` into one block per shard, sends block *j*
    to shard *j*, and concatenates the received blocks in shard order along
    ``concat_axis`` — the owner-computes exchange primitive (requests out,
    embeddings back).  Route new collective code through this spelling, not
    raw ``jax.lax`` (same policy as ``shard_map``/``make_mesh`` above)."""
    return jax.lax.all_to_all(x, axis, split_axis, concat_axis, tiled=True)


def data_mesh(n_shards: int, devices: Optional[Sequence] = None) -> Optional[Mesh]:
    """(Re)build the 1-axis ``("data",)`` mesh an N-shard run trains under —
    the mesh-rebuild step of an elastic rescale (``repro.elastic.rescale``)
    and the mesh ``GraphRuntime`` wires at construction.  ``None`` for
    ``n_shards <= 1`` (the single-device paths take the no-mesh branch);
    loud error when the process sees fewer devices than shards, since a
    silent truncation would train a different topology than the spec says."""
    if n_shards <= 1:
        return None
    import numpy as np
    devices = list(devices if devices is not None else jax.devices())
    if len(devices) < n_shards:
        raise ValueError(
            f"n_shards={n_shards} but only {len(devices)} jax devices are "
            f"visible (force host devices via XLA_FLAGS=--xla_force_host_"
            f"platform_device_count=N, see tools/ci.sh --multidevice)")
    return Mesh(np.asarray(devices[:n_shards]), ("data",))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    # jax.sharding.AxisType landed after 0.4.x; older versions default to
    # auto axes, which is exactly what we ask for on newer ones.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
