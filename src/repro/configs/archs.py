"""Imports every per-architecture config module so the registry populates."""

import repro.configs.zamba2_7b          # noqa: F401
import repro.configs.qwen1_5_0_5b       # noqa: F401
import repro.configs.internlm2_20b      # noqa: F401
import repro.configs.chatglm3_6b        # noqa: F401
import repro.configs.yi_9b              # noqa: F401
import repro.configs.musicgen_large     # noqa: F401
import repro.configs.mamba2_2_7b        # noqa: F401
import repro.configs.dbrx_132b          # noqa: F401
import repro.configs.granite_moe_3b     # noqa: F401
import repro.configs.qwen2_vl_7b        # noqa: F401
import repro.configs.paper_gnn          # noqa: F401

ASSIGNED = [
    "zamba2-7b", "qwen1.5-0.5b", "internlm2-20b", "chatglm3-6b", "yi-9b",
    "musicgen-large", "mamba2-2.7b", "dbrx-132b", "granite-moe-3b-a800m",
    "qwen2-vl-7b",
]
