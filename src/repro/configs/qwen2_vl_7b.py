"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064; M-RoPE (temporal/height/width rotary sections), dynamic
resolution.  [arXiv:2409.12191; hf]

Frontend stub: the vision tower is out of scope; the multimodal sequence is
represented by token ids + a 3-stream M-RoPE position-id tensor (3, B, S)
supplied by input_specs() — dynamic resolution manifests entirely through
those position streams.  head_dim 128 -> 64 rotary freqs split (16, 24, 24).
"""

from repro.configs.base import EmbeddingSpec, LMConfig, register


@register("qwen2-vl-7b")
def config() -> LMConfig:
    return LMConfig(
        name="qwen2-vl-7b",
        family="vlm",
        n_layers=28,
        d_model=3584,
        vocab_size=152064,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        qkv_bias=True,
        rope_variant="mrope",
        mrope_sections=(16, 24, 24),
        input_mode="tokens_mrope",
        act="swiglu",
        norm="rmsnorm",
        embedding=EmbeddingSpec(kind="hash_full"),
    )
