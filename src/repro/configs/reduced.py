"""Reduced same-family configs for CPU smoke tests.

Shrinks width/depth/vocab/experts while preserving every structural feature
of the full architecture (family, GQA ratio, RoPE variant, QKV bias, MoE
top-k, SSD state, hybrid sharing period), per the assignment brief.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import EmbeddingSpec, LMConfig


def reduced(cfg: LMConfig) -> LMConfig:
    scale = {}
    # depth: keep >= 2 scan steps; hybrid keeps one full group + tail
    if cfg.family == "hybrid":
        scale["n_layers"] = 2 * cfg.attn_every + 1
    else:
        scale["n_layers"] = 2
    # width
    d_model = 128
    if cfg.n_heads:
        n_heads = min(cfg.n_heads, 4)
        n_kv = max(1, min(cfg.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        d_head = 32
        scale.update(n_heads=n_heads, n_kv_heads=n_kv, d_head=d_head)
    scale.update(
        d_model=d_model,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        vocab_round=64,
    )
    if cfg.family == "moe":
        scale.update(n_experts=min(cfg.n_experts, 8),
                     moe_top_k=min(cfg.moe_top_k, 2))
    if cfg.family in ("ssm", "hybrid"):
        scale.update(ssm_state=min(cfg.ssm_state, 16), ssm_headdim=16,
                     ssm_chunk=16)
    if cfg.rope_variant == "mrope":
        # head_dim 32 -> 16 rotary freqs split proportionally
        scale["mrope_sections"] = (4, 6, 6)
    scale["embedding"] = dataclasses.replace(
        cfg.embedding, c=min(cfg.embedding.c, 16), m=min(cfg.embedding.m, 8),
        d_c=64, d_m=64)
    scale["compute_dtype"] = "float32"
    scale["remat"] = False
    return dataclasses.replace(cfg, **scale)
