from repro.configs.base import (
    EmbeddingSpec, GNNConfig, LMConfig, get_config, list_archs, register,
)
from repro.configs.reduced import reduced

__all__ = ["EmbeddingSpec", "GNNConfig", "LMConfig", "get_config",
           "list_archs", "register", "reduced"]
