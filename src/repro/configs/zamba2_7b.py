"""zamba2-7b [hybrid] — Mamba2 backbone + ONE shared transformer block
re-invoked every 6 mamba layers (weight sharing, per-site KV caches).
81L d_model=3584 32H (GQA kv=32 => MHA in the shared block) d_ff=14336
vocab=32000 ssm_state=64.  [arXiv:2411.15242; unverified]

Paper-technique fit: vocab 32,000 — hash-compressed input embedding on by
default.  Sub-quadratic (SSD mixer) => runs the long_500k cell.
"""

from repro.configs.base import EmbeddingSpec, LMConfig, register


@register("zamba2-7b")
def config() -> LMConfig:
    return LMConfig(
        name="zamba2-7b",
        family="hybrid",
        n_layers=81,
        d_model=3584,
        vocab_size=32000,
        n_heads=32,
        n_kv_heads=32,
        d_head=112,
        d_ff=14336,
        ssm_state=64,
        ssm_headdim=64,
        ssm_expand=2,
        attn_every=6,
        rope_variant="standard",
        act="swiglu",
        norm="rmsnorm",
        embedding=EmbeddingSpec(kind="hash_full"),
        subquadratic=True,
        notes="81 = 13 groups x 6 mamba layers + 3 tail; shared attn after each group",
    )
