"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
per expert, vocab=49155, MoE 40 experts top-8 (fine-grained).
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

EP: 40 experts don't divide the 16-way model axis — padded to 48 (router
logits for the 8 pad experts masked to -inf; see nn.moe).
"""

from repro.configs.base import EmbeddingSpec, LMConfig, register


@register("granite-moe-3b-a800m")
def config() -> LMConfig:
    return LMConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        n_layers=32,
        d_model=1536,
        vocab_size=49155,
        n_heads=24,
        n_kv_heads=8,
        d_ff=512,
        n_experts=40,
        moe_top_k=8,
        rope_variant="standard",
        act="swiglu",
        norm="rmsnorm",
        embedding=EmbeddingSpec(kind="hash_full"),
    )
