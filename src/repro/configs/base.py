"""Config dataclasses + registry for the architecture pool.

Every assigned architecture is a ``LMConfig``; the paper's own GNN stack is
a ``GNNConfig``.  Embedding compression (the paper's technique) is selected
per-arch by ``EmbeddingSpec.kind`` and applies to any large entity table —
vocabularies here, node sets in the GNN stack.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

from repro.core.embedding import EmbeddingConfig


@dataclasses.dataclass(frozen=True)
class EmbeddingSpec:
    kind: str = "hash_full"   # dense | hash_full | hash_light | random_full | random_light
    c: int = 256
    m: int = 16
    d_c: int = 512
    d_m: int = 512
    n_layers: int = 3         # paper §5.3: l=3, d_c=d_m=512
    lookup_impl: str = "onehot"  # decode backend name or "auto" (core.backend)
    threshold: str = "median" # Algorithm-1 binarisation ("zero" = Charikar baseline)
    hops: int = 1             # §6.1 higher-order adjacency (A^k auxiliary)
    cache_capacity: int = 0   # hot-node decode cache slots (0 = disabled)
    cache_staleness: int = 0  # codebook versions a cached embedding may lag
    # Plan-ahead miss partition for cached *training* (graph.engine.
    # MissPlanningSource): the prefetch thread permutes batch k+1's frontier
    # miss-first against a host cache shadow while step k runs, so the train
    # step decodes only (predicted) misses.  Single-shard dedup runs only.
    cache_plan_misses: bool = False
    # Decode precision (core.backend.MixedPrecisionPolicy): codebook/w0
    # storage dtype (None = the model's compute dtype) and absmax-int8
    # codebook quantization with dequant fused into the decode.  A quantized
    # or bf16 run is a spec field change — JSON / checkpoint round-trips.
    param_dtype: Optional[str] = None   # e.g. "bfloat16"
    quantize: str = "none"              # "none" | "int8"
    # TT rank r of the "tt" compression family (lookup_impl="tt" — see
    # core.backend.family_of); ignored by the paper and hashemb families.
    tt_rank: int = 8
    # Where the packed ``codes_buf`` lives: "device" replicates it in HBM
    # (O(#nodes) device memory); "host" keeps it in host RAM and the batch
    # source / prefetch producer gathers each frontier's code rows into the
    # ``FrontierBatch.codes`` leaf, so the device holds O(frontier) code
    # bytes.  Bitwise-identical outputs either way (the gather commutes with
    # decode).  Ignored by kinds/families without a codes_buf.
    codes_placement: str = "device"     # "device" | "host"

    def to_config(self, n_entities: int, d_e: int, compute_dtype: str) -> EmbeddingConfig:
        return EmbeddingConfig(
            kind=self.kind, n_entities=n_entities, d_e=d_e,
            c=self.c, m=self.m, d_c=self.d_c, d_m=self.d_m,
            n_layers=self.n_layers, lookup_impl=self.lookup_impl,
            compute_dtype=compute_dtype,
            threshold=self.threshold, hops=self.hops,
            cache_capacity=self.cache_capacity,
            cache_staleness=self.cache_staleness,
            param_dtype=self.param_dtype, quantize=self.quantize,
            tt_rank=self.tt_rank, codes_placement=self.codes_placement,
        )


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    vocab_size: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    d_head: int = 0           # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    moe_impl: str = "ep"             # ep | dense (nn.moe)
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    # --- hybrid (zamba2-style shared attention) ---
    attn_every: int = 0       # shared attn block after every k mamba layers
    # --- positional / attention details ---
    rope_variant: str = "standard"   # standard | half | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()
    qkv_bias: bool = False
    attn_impl: str = "xla"           # xla | flash (flash on TPU runtime)
    # --- misc ---
    act: str = "swiglu"
    norm: str = "rmsnorm"
    input_mode: str = "tokens"       # tokens | audio_tokens | tokens_mrope
    n_codebooks: int = 1             # audio_tokens: EnCodec streams
    embedding: EmbeddingSpec = dataclasses.field(default_factory=EmbeddingSpec)
    compute_dtype: str = "bfloat16"
    vocab_round: int = 256           # pad vocab for TP divisibility
    loss_vocab_chunk: int = 0        # >0: chunked CE (logits never (B,S,V))
    remat: bool = True               # scan-level activation checkpointing
    unroll_scan: bool = False        # dry-run cost-analysis mode (see models.lm)
    subquadratic: bool = False       # eligible for long_500k
    notes: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // max(self.n_heads, 1))

    @property
    def vocab_padded(self) -> int:
        r = self.vocab_round
        return -(-self.vocab_size // r) * r

    @property
    def n_experts_padded(self) -> int:
        if not self.n_experts:
            return 0
        # pad to a multiple of 16 (the production model-axis extent)
        return -(-self.n_experts // 16) * 16 if self.n_experts % 16 else self.n_experts

    def embedding_config(self) -> EmbeddingConfig:
        return self.embedding.to_config(self.vocab_padded, self.d_model, self.compute_dtype)

    def param_count(self) -> int:
        """Analytic total parameter count (for roofline MODEL_FLOPS)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_padded
        Dh, H, K = self.head_dim, self.n_heads, self.n_kv_heads
        attn = D * H * Dh + 2 * D * K * Dh + H * Dh * D
        ffn = 3 * D * F if self.act == "swiglu" else 2 * D * F
        if self.family == "moe":
            ffn = self.n_experts * ffn + D * self.n_experts
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            DI = self.ssm_expand * D
            N = self.ssm_state
            Hs = DI // self.ssm_headdim
            ssm = D * (2 * DI + 2 * N + Hs) + DI * D + 4 * (DI + 2 * N)
        per_layer = {
            "dense": attn + ffn, "moe": attn + ffn, "audio": attn + ffn,
            "vlm": attn + ffn, "ssm": ssm, "hybrid": ssm,
        }[self.family]
        total = self.n_layers * per_layer
        if self.family == "hybrid" and self.attn_every:
            total += attn + 3 * D * F  # one shared attn+mlp block
        emb = V * D  # dense-equivalent (NC baseline)
        head = D * V * (self.n_codebooks if self.input_mode == "audio_tokens" else 1)
        return total + emb + head

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        D, F = self.d_model, self.d_ff
        ffn_all = self.n_experts * 3 * D * F
        ffn_act = self.moe_top_k * 3 * D * F
        return self.param_count() - self.n_layers * (ffn_all - ffn_act)


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    model: str                 # sage | gcn | sgc | gin
    n_nodes: int
    n_classes: int
    d_e: int = 64              # paper §C.1: d_e = 64
    hidden: int = 128
    n_gnn_layers: int = 2
    fanouts: Tuple[int, ...] = (15, 15)   # sage neighbour fanout
    task: str = "node"         # node | link
    embedding: EmbeddingSpec = dataclasses.field(default_factory=EmbeddingSpec)
    compute_dtype: str = "float32"

    def embedding_config(self) -> EmbeddingConfig:
        return self.embedding.to_config(self.n_nodes, self.d_e, self.compute_dtype)


# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], LMConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str, **overrides) -> LMConfig:
    import repro.configs.archs  # noqa: F401  (populates the registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def list_archs():
    import repro.configs.archs  # noqa: F401
    return sorted(_REGISTRY)
