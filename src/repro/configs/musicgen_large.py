"""musicgen-large [audio] — decoder-only over EnCodec tokens.
48L d_model=2048 32H (MHA) d_ff=8192 vocab=2048 (per codebook, 4 codebooks,
delay-pattern interleaving).  [arXiv:2306.05284; hf]

Frontend stub: inputs are the 4 parallel EnCodec token streams (B, S, 4);
the 4 codebook embeddings are summed (MusicGen's own input path); the head
predicts 4x2048 logits per step.  Sinusoidal positions (no RoPE), LayerNorm
+ GELU per the original transformer recipe.

Paper-technique note (DESIGN.md §4): vocab 2,048/codebook is tiny — the
hash-compressed table is LARGER than dense at paper hyper-params (ratio<1),
so `dense` is the default; compressed kinds remain selectable for ablation.
"""

from repro.configs.base import EmbeddingSpec, LMConfig, register


@register("musicgen-large")
def config() -> LMConfig:
    return LMConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        vocab_size=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        rope_variant="none",
        act="gelu",
        norm="layernorm",
        input_mode="audio_tokens",
        n_codebooks=4,
        embedding=EmbeddingSpec(kind="dense"),
        notes="hash embedding inapplicable in practice: n=2048/codebook gives ratio<1",
    )
