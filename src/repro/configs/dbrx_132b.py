"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
16 experts top-4 (fine-grained).  [hf:databricks/dbrx-base; unverified]

EP: 16 experts over the 16-way model axis — exactly 1 expert/shard.
"""

from repro.configs.base import EmbeddingSpec, LMConfig, register


@register("dbrx-132b")
def config() -> LMConfig:
    return LMConfig(
        name="dbrx-132b",
        family="moe",
        n_layers=40,
        d_model=6144,
        vocab_size=100352,
        n_heads=48,
        n_kv_heads=8,
        d_ff=10752,
        n_experts=16,
        moe_top_k=4,
        rope_variant="standard",
        act="swiglu",
        norm="rmsnorm",
        embedding=EmbeddingSpec(kind="hash_full"),
    )
