"""The paper's own experimental stack (§5.2/§5.3): GraphSAGE / GCN / SGC /
GIN with hash-compressed node embeddings on attribute-less graphs.

Hyper-parameters per §C.1: decoder l=3, d_c=d_m=512, d_e=64; GraphSAGE
2 layers x 128 hidden, fanout 15; merchant system (§5.3.2): c=256, m=16,
fanout 5, 2 layers x 128.
"""

from repro.configs.base import EmbeddingSpec, GNNConfig


def paper_gnn_config(model: str = "sage", n_nodes: int = 10000,
                     n_classes: int = 16, kind: str = "hash_full",
                     task: str = "node", fanout: int = 15) -> GNNConfig:
    return GNNConfig(
        name=f"paper-{model}-{kind}",
        model=model,
        n_nodes=n_nodes,
        n_classes=n_classes,
        d_e=64,
        hidden=128,
        n_gnn_layers=2,
        fanouts=(fanout, fanout),
        task=task,
        embedding=EmbeddingSpec(kind=kind, c=256, m=16, d_c=512, d_m=512, n_layers=3),
    )


def merchant_config(n_nodes: int, n_classes: int = 64,
                    kind: str = "hash_full") -> GNNConfig:
    """§5.3.2 settings: l=3, d_c=d_m=512, d_e=64, c=256, m=16, fanout 5."""
    return GNNConfig(
        name=f"merchant-sage-{kind}",
        model="sage",
        n_nodes=n_nodes,
        n_classes=n_classes,
        d_e=64,
        hidden=128,
        n_gnn_layers=2,
        fanouts=(5, 5),
        task="node",
        embedding=EmbeddingSpec(kind=kind, c=256, m=16, d_c=512, d_m=512, n_layers=3),
    )
