"""qwen1.5-0.5b [dense] — 24L d_model=1024 16H (GQA kv=16) d_ff=2816
vocab=151936, QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]

Paper-technique fit: the BEST case in the pool — the 151,936x1024 embedding
table is ~39% of all parameters; hash compression shrinks it ~40x.
"""

from repro.configs.base import EmbeddingSpec, LMConfig, register


@register("qwen1.5-0.5b")
def config() -> LMConfig:
    return LMConfig(
        name="qwen1.5-0.5b",
        family="dense",
        n_layers=24,
        d_model=1024,
        vocab_size=151936,
        n_heads=16,
        n_kv_heads=16,
        d_ff=2816,
        qkv_bias=True,
        rope_variant="standard",
        act="swiglu",
        norm="rmsnorm",
        embedding=EmbeddingSpec(kind="hash_full"),
    )
