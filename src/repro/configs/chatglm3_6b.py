"""chatglm3-6b [dense] — 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024; RoPE applied to half the head dims ("2d" RoPE), QKV bias.
[arXiv:2406.12793; hf]

TP note: kv_heads=2 < model-axis 16 — the sharding resolver replicates KV
heads (DESIGN.md §6), the standard fallback for narrow GQA under TP.
"""

from repro.configs.base import EmbeddingSpec, LMConfig, register


@register("chatglm3-6b")
def config() -> LMConfig:
    return LMConfig(
        name="chatglm3-6b",
        family="dense",
        n_layers=28,
        d_model=4096,
        vocab_size=65024,
        n_heads=32,
        n_kv_heads=2,
        d_ff=13696,
        qkv_bias=True,
        rope_variant="half",
        act="swiglu",
        norm="rmsnorm",
        embedding=EmbeddingSpec(kind="hash_full"),
    )
