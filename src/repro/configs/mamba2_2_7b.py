"""mamba2-2.7b [ssm] — attention-free SSD (state-space duality).
64L d_model=2560 vocab=50280 ssm_state=128.  [arXiv:2405.21060; unverified]

d_inner = 2*2560 = 5120, headdim 64 -> 80 SSD heads.  Sub-quadratic: runs
the long_500k cell with O(1)-per-step state decode.
"""

from repro.configs.base import EmbeddingSpec, LMConfig, register


@register("mamba2-2.7b")
def config() -> LMConfig:
    return LMConfig(
        name="mamba2-2.7b",
        family="ssm",
        n_layers=64,
        d_model=2560,
        vocab_size=50280,
        ssm_state=128,
        ssm_headdim=64,
        ssm_expand=2,
        rope_variant="none",
        norm="rmsnorm",
        embedding=EmbeddingSpec(kind="hash_full"),
        subquadratic=True,
    )
