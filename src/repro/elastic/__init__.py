"""Elastic sharded training: live shard join/leave, checkpointless peer
recovery, and exact rescale (docs/elastic.md, ROADMAP item 2).

Public surface:
  ``ElasticSpec`` / ``ElasticManager`` / ``ElasticResult`` — the step-fenced
      membership state machine (``manager``);
  ``FailurePlan`` — deterministic fault injection (``failures``);
  ``pack_state`` / ``transfer_state`` / ``unpack_state`` — the chunked,
      CRC-verified peer wire (``transfer``);
  ``rescale_spec`` / ``rescale_runtime`` — exact shard-count changes
      (``rescale``; also reachable as ``GraphRuntime.rescale``).
"""

from repro.elastic.failures import FailurePlan
from repro.elastic.manager import (DEGRADED, HEALTHY, RESCALING, ElasticError,
                                   ElasticManager, ElasticResult, ElasticSpec,
                                   RecoveryReport)
from repro.elastic.rescale import install_state, rescale_runtime, rescale_spec
from repro.elastic.transfer import (Chunk, ChunkCorruption, TransferStats,
                                    chunk_payload, pack_state, transfer_state,
                                    unpack_state)

__all__ = [
    "HEALTHY", "DEGRADED", "RESCALING",
    "ElasticError", "ElasticManager", "ElasticResult", "ElasticSpec",
    "RecoveryReport", "FailurePlan",
    "Chunk", "ChunkCorruption", "TransferStats",
    "chunk_payload", "pack_state", "transfer_state", "unpack_state",
    "install_state", "rescale_runtime", "rescale_spec",
]
