"""Deterministic fault injection for elastic training (docs/elastic.md).

A ``FailurePlan`` is a frozen, declarative schedule of faults — *when* a
shard dies, *when* its heartbeats lag, *which* transfer chunk arrives
corrupted — evaluated as pure predicates of ``(shard, step)`` /
``(seq, attempt)``.  Nothing here flips coins: the same plan against the
same run produces the same failure sequence every time, which is what lets
``tests/test_elastic.py`` assert bitwise post-recovery equality and
``benchmarks/elastic_failover.py`` report reproducible recovery numbers.

The plan is consulted by ``ElasticManager`` (liveness at every step fence)
and by ``transfer.transfer_state`` (chunk tampering on the simulated wire).
Kill entries are *events*: the recovery they trigger consumes them
(manager-side), because the rescale renumbers survivors ``0..n-1`` and a
spent entry must not re-kill the new shard wearing the old id.  Entries
scheduled for later steps address the post-rescale topology by its new
ids, so multi-failure plans compose.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class FailurePlan:
    """Declarative fault schedule.

    ``kill``: ``(shard, step)`` pairs — shard ``shard`` stops renewing its
    step lease from global step ``step`` onward (it is dead, permanently).

    ``heartbeat_delay``: ``(shard, from_step, n_steps)`` triples — shard
    ``shard`` misses its lease renewal for ``n_steps`` fences starting at
    ``from_step`` but is *not* dead; a delay shorter than
    ``ElasticSpec.lease_steps`` must be tolerated without triggering
    recovery (tested).

    ``corrupt_chunks``: chunk sequence numbers whose *first* transmission
    arrives with a flipped payload byte (the original checksum rides along,
    so the receiver detects the corruption and requests a retransmit).
    """

    kill: Tuple[Tuple[int, int], ...] = ()
    heartbeat_delay: Tuple[Tuple[int, int, int], ...] = ()
    corrupt_chunks: Tuple[int, ...] = ()

    def alive(self, shard: int, step: int) -> bool:
        """False once ``step`` reaches a scheduled kill for ``shard``."""
        return not any(s == shard and step >= at for s, at in self.kill)

    def delayed(self, shard: int, step: int) -> bool:
        """True while ``shard`` is inside a scheduled heartbeat-delay
        window at ``step`` (the lease is simply not renewed that fence)."""
        return any(s == shard and t0 <= step < t0 + n
                   for s, t0, n in self.heartbeat_delay)

    def tamper(self, seq: int, attempt: int) -> bool:
        """True when transmission ``attempt`` (0-based) of chunk ``seq``
        should arrive corrupted.  Only the first attempt is tampered —
        retransmits go through clean, so a plan exercises exactly one
        detect-and-retry cycle per listed chunk."""
        return attempt == 0 and seq in self.corrupt_chunks
