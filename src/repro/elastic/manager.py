"""ElasticManager: step-fenced shard membership for sharded training.

State machine (docs/elastic.md):

    HEALTHY --lease expired--> DEGRADED --survivors >= min_shards--> RESCALING
       ^                           |                                    |
       |                           +--survivors < min_shards--> ElasticError
       +------------- rescaled runtime resumes training ----------------+

The manager owns the training loop's shard membership the way the torchft
``Manager`` owns its process group: training advances through step fences
(``train.loop`` calls back every ``fence_every`` steps), each fence renews
the step lease of every shard that is alive per the heartbeat source
(deterministically simulated by a ``FailurePlan`` here; a real fleet wires
actual heartbeats, with ``heartbeat_timeout_s`` as the wall-clock
backstop).  A shard whose lease lapses more than ``lease_steps`` fences is
declared dead; the fence raises ``FenceInterrupt``, training stops at a
step boundary, and the manager runs recovery:

  1. capture the survivors' replicated state (params/opt/cache) + batch
     source state — data-parallel training means any survivor has it;
  2. push it through the chunked, CRC-verified peer wire
     (``transfer.transfer_state``; corrupted chunks are detected and
     retransmitted, bounded by ``max_transfer_retries``) — the checkpoint
     directory is **never** read;
  3. build the rescaled runtime at the survivor count
     (``rescale.rescale_runtime`` — exact, see that module) and resume.

The manager refuses runtimes with ``spec.ckpt_dir`` set: checkpointed runs
use absolute-step training semantics and auto-resume, which would fight
the manager's own step accounting — checkpoint-based topology changes go
through ``GraphRuntime.rescale_checkpoint`` instead.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.elastic.failures import FailurePlan
from repro.elastic.transfer import transfer_state, pack_state, unpack_state
from repro.train.loop import FenceInterrupt

HEALTHY = "HEALTHY"
DEGRADED = "DEGRADED"
RESCALING = "RESCALING"


class ElasticError(RuntimeError):
    """Recovery is impossible (e.g. survivors < ``min_shards``)."""


@dataclasses.dataclass(frozen=True)
class ElasticSpec:
    """Elastic-training knobs (rides on ``RuntimeSpec.elastic``).

    ``lease_steps``: fences a shard may miss before it is declared dead.
    Larger tolerates longer heartbeat hiccups; smaller detects real deaths
    sooner (fewer steps lost).

    ``min_shards``: floor on the post-recovery shard count; shrinking below
    it raises ``ElasticError`` instead of silently degrading.

    ``chunk_bytes``: peer-transfer wire chunk size (CRC per chunk, so this
    is also the retransmission granularity on corruption).

    ``max_transfer_retries``: retransmissions allowed per corrupted chunk
    before the transfer aborts with ``ChunkCorruption``.

    ``heartbeat_timeout_s``: wall-clock liveness backstop for real fleets
    where a shard can wedge *between* fences; the in-process simulation is
    step-driven and only records it.
    """

    lease_steps: int = 2
    min_shards: int = 1
    chunk_bytes: int = 1 << 20
    max_transfer_retries: int = 2
    heartbeat_timeout_s: float = 30.0

    def __post_init__(self):
        if self.lease_steps < 1:
            raise ValueError(f"lease_steps must be >= 1, got {self.lease_steps}")
        if self.min_shards < 1:
            raise ValueError(f"min_shards must be >= 1, got {self.min_shards}")
        if self.chunk_bytes < 1:
            raise ValueError(f"chunk_bytes must be >= 1, got {self.chunk_bytes}")
        if self.max_transfer_retries < 0:
            raise ValueError(
                f"max_transfer_retries must be >= 0, got {self.max_transfer_retries}")

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ElasticSpec":
        return cls(**d)


@dataclasses.dataclass
class RecoveryReport:
    """One failure → recovery cycle, in the units that matter: steps lost
    to detection latency and bytes moved over the peer wire (wall-clock on
    a CPU container lies; see ROADMAP "CPU timings lie")."""

    failed_shards: Tuple[int, ...]
    detected_at_step: int      # global 0-based step index of the detecting fence
    steps_lost: int            # steps run past the dead shard's lease grace
    n_before: int
    n_after: int
    payload_bytes: int
    bytes_transferred: int     # wire bytes including retransmissions
    chunks: int
    retransmits: int


@dataclasses.dataclass
class ElasticResult:
    losses: List[float]
    steps: int                       # completed global steps
    reports: List[RecoveryReport]
    history: List[str]               # state-machine transitions, in order
    runtime: Any                     # the (possibly rescaled) live runtime


class ElasticManager:
    """Owns shard membership for one training run over a ``GraphRuntime``.

    ``plan`` injects deterministic faults (tests/benchmarks); ``None`` means
    no shard ever dies and ``run`` degenerates to plain training.  ``spec``
    defaults to the runtime's ``RuntimeSpec.elastic`` (or ``ElasticSpec()``).
    """

    def __init__(self, runtime, plan: Optional[FailurePlan] = None,
                 spec: Optional[ElasticSpec] = None):
        if runtime.spec.ckpt_dir:
            raise ValueError(
                "ElasticManager needs a checkpoint-free runtime: with "
                "spec.ckpt_dir set, train() uses absolute-step auto-resume "
                "semantics that fight the manager's step accounting.  Peer "
                "recovery never reads checkpoints anyway; for checkpoint-"
                "based topology changes use GraphRuntime.rescale_checkpoint.")
        self.rt = runtime
        self.plan = plan
        self.spec = spec or runtime.spec.elastic or ElasticSpec()
        self.state = HEALTHY
        self.history: List[str] = [HEALTHY]
        self.reports: List[RecoveryReport] = []
        self.n_shards = max(1, int(runtime.spec.n_shards))
        self._done = 0                      # completed global steps
        self._leases = {s: -1 for s in range(self.n_shards)}
        self._pending: Optional[Tuple[Tuple[int, ...], int]] = None
        # kill events already recovered from: after a rescale renumbers the
        # survivors 0..n-1, a consumed (shard, step) entry must not re-fire
        # against the *new* shard wearing the old id
        self._consumed: set = set()

    # -- liveness ---------------------------------------------------------
    def _fence(self, step: int) -> None:
        """Step-fence callback: renew leases, detect expiries.  ``step`` is
        the loop-local 0-based index just finished; global index is offset
        by the steps completed before the current ``train`` call."""
        gstep = self._done + step
        for s in range(self.n_shards):
            if not self._alive(s, gstep):
                continue
            if self.plan is not None and self.plan.delayed(s, gstep):
                continue
            self._leases[s] = gstep
        dead = tuple(s for s in range(self.n_shards)
                     if gstep - self._leases[s] > self.spec.lease_steps)
        if dead:
            self.state = DEGRADED
            self.history.append(DEGRADED)
            self._pending = (dead, gstep)
            raise FenceInterrupt(f"shards {list(dead)} lease-expired at "
                                 f"step {gstep}")

    def _alive(self, shard: int, gstep: int) -> bool:
        """Plan liveness minus already-consumed kill events: a kill entry
        that triggered a recovery is spent — the rescaled topology reuses
        shard ids, and the new shard wearing the dead one's id is alive."""
        if self.plan is None:
            return True
        return not any(s == shard and gstep >= at
                       and (s, at) not in self._consumed
                       for s, at in self.plan.kill)

    # -- recovery ---------------------------------------------------------
    def _recover(self) -> None:
        dead, detected = self._pending
        self._pending = None
        self._consumed.update((s, at) for s, at in self.plan.kill
                              if at <= detected)
        n_after = self.n_shards - len(dead)
        if n_after < self.spec.min_shards:
            raise ElasticError(
                f"shards {list(dead)} died at step {detected}; "
                f"{n_after} survivors < min_shards={self.spec.min_shards} "
                f"— cannot rescale, run must restart from a checkpoint")
        # detection latency in steps: how far past the dead shards' lease
        # grace the fleet ran before the fence tripped
        steps_lost = detected - min(self._leases[s] for s in dead) \
            - self.spec.lease_steps
        # 1. survivors' replicated state + batch source state (any survivor
        #    holds both — data-parallel params are replicated and the source
        #    state is (seed, step))
        source_state = (self.rt.data_iter.state_dict()
                        if hasattr(self.rt.data_iter, "state_dict") else None)
        payload = pack_state(self.rt.state, {"source": source_state})
        # 2. the peer wire: chunked, CRC-verified, bounded retransmission
        wire, stats = transfer_state(
            payload, chunk_bytes=self.spec.chunk_bytes,
            tamper=self.plan.tamper if self.plan is not None else None,
            max_retries=self.spec.max_transfer_retries)
        # 3. rescale to the survivor count from the transferred copy ONLY
        #    (the new runtime's fresh init state is just the unpack template;
        #    every array it trains on came over the wire)
        self.state = RESCALING
        self.history.append(RESCALING)
        from repro.elastic.rescale import install_state, rescale_spec
        from repro.graph.runtime import GraphRuntime
        spec2 = rescale_spec(self.rt.spec, n_after)
        new_rt = GraphRuntime.from_spec(spec2,
                                        graph=(self.rt.adj, self.rt.labels))
        state, extra = unpack_state(wire, new_rt.state)
        install_state(new_rt, state, extra.get("source"))
        self.rt.close()
        self.rt = new_rt
        self.n_shards = n_after
        self._leases = {s: self._done - 1 for s in range(n_after)}
        self.state = HEALTHY
        self.history.append(HEALTHY)
        self.reports.append(RecoveryReport(
            failed_shards=dead, detected_at_step=detected,
            steps_lost=steps_lost, n_before=n_after + len(dead),
            n_after=n_after, payload_bytes=stats.payload_bytes,
            bytes_transferred=stats.bytes_transferred, chunks=stats.chunks,
            retransmits=stats.retransmits))

    # -- driver -----------------------------------------------------------
    def run(self, total_steps: int, on_metrics=None) -> ElasticResult:
        """Train for ``total_steps`` global steps, surviving every planned
        failure.  Returns the concatenated loss curve (failure steps
        included — the simulation computes them; a fleet recomputes them
        post-rescale) and one ``RecoveryReport`` per recovery."""
        total = int(total_steps)
        losses: List[float] = []
        while self._done < total:
            res = self.rt.train(total - self._done, on_metrics=on_metrics,
                                fence=self._fence)
            losses.extend(res.losses)
            if res.interrupted_at is None:
                self._done = total
                break
            self._done += res.interrupted_at
            self._recover()
        return ElasticResult(losses=losses, steps=self._done,
                             reports=self.reports, history=self.history,
                             runtime=self.rt)
