"""Exact RuntimeSpec-driven rescale: N-shard state → M-shard continuation.

Why this can be *exact* (docs/elastic.md has the full argument): the
hashed sampler draws one **global** batch per ``(seed, step)`` — every
neighbour slot is a pure function of ``(seed, step, global position,
path)`` — and shards merely slice it (``graph.sampler.sample_hashed``).
The shard count never enters the draw, so a run rescaled from N to M
shards consumes, step for step, the **same global batch stream** a native
M-shard run would.  The paper's hashing is likewise data-independent
(codes are a pure function of node id), so the owner partition
``node_id % n_shards`` remaps with zero recomputation.  Together: carry
``(seed, step)`` over, rebuild the mesh/owner plan at the new count, and
the continuation is bit-identical to a never-rescaled M-shard run from
the same state.

Requirements enforced here: the *global* ``batch_size`` is fixed across
the rescale and must divide evenly by the new shard count; pinned
owner-exchange caps are re-derived at the new count
(``core.backend.rederive_owner_caps``); ``ckpt_dir`` does NOT carry over
(the old directory holds old-topology checkpoints that would fail the
manifest topology check — pass ``ckpt_dir=`` explicitly to start a new
one).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.train.checkpoint import _flatten, _unflatten_into


def rescale_spec(spec, n_shards: int, ckpt_dir: Optional[str] = None):
    """New ``RuntimeSpec`` for the same run at a different shard count."""
    n = int(n_shards)
    if n < 1:
        raise ValueError(f"n_shards must be >= 1, got {n}")
    if spec.batch_size % n:
        raise ValueError(
            f"cannot rescale to n_shards={n}: global batch_size "
            f"{spec.batch_size} is not divisible by it (the global batch is "
            f"the determinism anchor and never changes across a rescale)")
    from repro.core.backend import rederive_owner_caps
    cap = spec.frontier_cap
    if cap is None and (spec.owner_cap is not None
                        or spec.owner_unique_cap is not None):
        from repro.graph.engine import default_frontier_cap
        cap = default_frontier_cap(spec.batch_size // n, spec.model.fanouts,
                                   spec.pad_to, spec.model.n_nodes)
    oc, ou = rederive_owner_caps(cap if cap is not None else 0, n,
                                 explicit=(spec.owner_cap,
                                           spec.owner_unique_cap))
    return dataclasses.replace(spec, n_shards=n, owner_cap=oc,
                               owner_unique_cap=ou, ckpt_dir=ckpt_dir)


def install_state(rt, state: Any, source_state: Optional[dict] = None) -> None:
    """Install transferred/carried-over train state (and optionally batch
    source state) into a freshly built runtime.

    The state goes through the checkpoint flatten/unflatten pair so it gets
    the same leaf-path and shape validation a restore would; the batch
    source state is remapped onto the runtime's shard count
    (``graph.sampler.remap_shard_state`` — the exactness argument lives
    there) before loading."""
    rt.state = _unflatten_into(rt.state, _flatten(state))
    if source_state is not None:
        from repro.graph.sampler import remap_shard_state
        remapped = remap_shard_state(source_state, rt.spec.n_shards)
        if hasattr(rt.data_iter, "load_state_dict"):
            rt.data_iter.load_state_dict(remapped)
        # miss-planning runs: re-anchor the host cache shadow to the
        # installed device cache (same move GraphRuntime.resume makes)
        src = getattr(rt.data_iter, "source", rt.data_iter)
        if hasattr(src, "sync_shadow") and "cache" in rt.state:
            src.sync_shadow(rt.state["cache"])


def rescale_runtime(rt, n_shards: int, state: Any = None,
                    source_state: Optional[dict] = None,
                    ckpt_dir: Optional[str] = None):
    """Build a new ``GraphRuntime`` at ``n_shards`` continuing ``rt``'s run.

    ``state`` / ``source_state`` default to ``rt``'s current train state and
    batch-source state (the in-process rescale); the elastic manager passes
    the peer-transferred copies instead.  The graph is reused as-is —
    regenerating it would be pure waste since the descriptor is
    deterministic.  The caller owns closing the old runtime."""
    from repro.graph.runtime import GraphRuntime
    spec2 = rescale_spec(rt.spec, n_shards, ckpt_dir=ckpt_dir)
    new_rt = GraphRuntime.from_spec(spec2, graph=(rt.adj, rt.labels))
    if state is None:
        state = rt.state
    if source_state is None and hasattr(rt.data_iter, "state_dict"):
        source_state = rt.data_iter.state_dict()
    install_state(new_rt, state, source_state)
    return new_rt
