"""Checkpointless peer recovery: shard state over a chunked, checksummed wire.

When a shard dies mid-run, the survivors hold everything needed to rebuild
it — data-parallel training replicates params/optimizer state, and the
batch-source state is a handful of integers — so recovery never has to
touch the checkpoint directory (the zeroband ``state_dict_send_recv``
pattern).  This module is that wire:

  ``pack_state``      pytree + JSON sidecar  →  one npz-format byte payload
  ``chunk_payload``   payload  →  fixed-size ``Chunk``s, each CRC-stamped
  ``transfer_state``  simulated send/receive with per-chunk verification
                      and bounded retransmission (fault-injectable via
                      ``FailurePlan.tamper``)
  ``unpack_state``    payload  →  pytree (validated against a template,
                      same shape/leaf checks as checkpoint restore)

The payload reuses the checkpoint leaf layout (``train.checkpoint._flatten``
path-keyed arrays inside an ``np.savez`` container) so the two persistence
paths — durable checkpoint and peer transfer — can never drift apart in
what they capture.  In this CPU container the "wire" is a loop over chunks;
on a fleet the same chunk/CRC/retry framing rides a TCP stream or a NCCL
send/recv, and ``TransferStats`` reports what CI gates on either way:
bytes moved (including retransmits), chunk count, retransmit count.
"""

from __future__ import annotations

import dataclasses
import io
import json
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.train.checkpoint import _flatten, _unflatten_into

# JSON sidecar leaf (batch-source state etc.) inside the npz payload; the
# name cannot collide with pytree path keys, which are "/"-joined.
_EXTRA_KEY = "__extra__"


class ChunkCorruption(RuntimeError):
    """A chunk failed CRC verification on every allowed transmission."""


@dataclasses.dataclass(frozen=True)
class Chunk:
    """One wire unit: ``payload`` plus the CRC32 computed *at the sender*.
    A tampered payload keeps the sender's CRC, so ``verify`` catches it."""

    seq: int
    total: int
    payload: bytes
    crc: int

    def verify(self) -> bool:
        return (zlib.crc32(self.payload) & 0xFFFFFFFF) == self.crc


@dataclasses.dataclass
class TransferStats:
    payload_bytes: int        # logical size of the transferred state
    bytes_transferred: int    # wire bytes including retransmissions
    chunks: int
    retransmits: int


def pack_state(state: Any, extra: Optional[Dict] = None) -> bytes:
    """Serialize a pytree + JSON-able sidecar into one byte payload."""
    flat = _flatten(state)
    if _EXTRA_KEY in flat:
        raise ValueError(f"state pytree path collides with {_EXTRA_KEY!r}")
    blob = json.dumps(extra or {}).encode()
    flat[_EXTRA_KEY] = np.frombuffer(blob, np.uint8)
    bio = io.BytesIO()
    np.savez(bio, **flat)
    return bio.getvalue()


def unpack_state(data: bytes, state_template: Any) -> Tuple[Any, Dict]:
    """Inverse of ``pack_state``; validates every leaf against the template
    (missing-leaf / shape mismatches raise, exactly like checkpoint
    restore).  Returns ``(state, extra)``."""
    with np.load(io.BytesIO(data)) as z:
        flat = {k: z[k] for k in z.files}
    extra = {}
    if _EXTRA_KEY in flat:
        extra = json.loads(bytes(flat.pop(_EXTRA_KEY)).decode())
    return _unflatten_into(state_template, flat), extra


def chunk_payload(data: bytes, chunk_bytes: int) -> List[Chunk]:
    """Split a payload into CRC-stamped ``Chunk``s of at most
    ``chunk_bytes`` (the last one may be short; an empty payload still
    produces one chunk so the receiver can distinguish "empty" from
    "nothing arrived")."""
    if chunk_bytes < 1:
        raise ValueError(f"chunk_bytes must be >= 1, got {chunk_bytes}")
    views = [data[i:i + chunk_bytes] for i in range(0, len(data), chunk_bytes)]
    if not views:
        views = [b""]
    total = len(views)
    return [Chunk(seq=i, total=total, payload=p,
                  crc=zlib.crc32(p) & 0xFFFFFFFF)
            for i, p in enumerate(views)]


def _corrupt(chunk: Chunk) -> Chunk:
    """Flip one payload byte, keeping the sender's CRC — the receiver-side
    ``verify`` must catch this."""
    buf = bytearray(chunk.payload if chunk.payload else b"\x00")
    buf[len(buf) // 2] ^= 0xFF
    return dataclasses.replace(chunk, payload=bytes(buf))


def transfer_state(
    data: bytes,
    chunk_bytes: int = 1 << 20,
    tamper: Optional[Callable[[int, int], bool]] = None,
    max_retries: int = 2,
) -> Tuple[bytes, TransferStats]:
    """Move ``data`` across the (simulated) wire chunk by chunk.

    Each chunk is re-sent until its CRC verifies at the receiver, up to
    ``max_retries`` retransmissions; exhausting the budget raises
    ``ChunkCorruption`` (recovery then falls back to the checkpoint path —
    the manager surfaces this loudly rather than training on garbage).
    ``tamper(seq, attempt)`` is the fault-injection hook
    (``FailurePlan.tamper``).  Returns the reassembled payload — always
    bit-identical to ``data`` when it returns at all — plus the wire
    accounting."""
    chunks = chunk_payload(data, chunk_bytes)
    received: List[bytes] = []
    wire_bytes = 0
    retransmits = 0
    for chunk in chunks:
        for attempt in range(max_retries + 1):
            sent = chunk
            if tamper is not None and tamper(chunk.seq, attempt):
                sent = _corrupt(chunk)
            wire_bytes += len(sent.payload)
            if attempt > 0:
                retransmits += 1
            if sent.verify():
                received.append(sent.payload)
                break
        else:
            raise ChunkCorruption(
                f"chunk {chunk.seq}/{chunk.total} failed CRC on all "
                f"{max_retries + 1} transmissions — peer transfer aborted "
                f"(state NOT installed); recover from the checkpoint dir "
                f"or raise ElasticSpec.max_transfer_retries")
    out = b"".join(received)
    stats = TransferStats(payload_bytes=len(data), bytes_transferred=wire_bytes,
                          chunks=len(chunks), retransmits=retransmits)
    return out, stats
