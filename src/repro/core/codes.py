"""Compositional-code storage layout (paper §3.1 footnote 1, §3.2).

A code vector of length ``m`` with cardinality ``c`` (``c`` a power of two)
is stored as ``n_bit = m * log2(c)`` bits.  Following the paper's example,
each element is written MSB-first: ``[2, 0, 3, 1]`` with ``c=4`` becomes the
bit string ``10 00 11 01``.

TPU adaptation (DESIGN.md §3.2): bits are packed into 32-bit lanes
(``uint32`` words, little-endian within a word: bit ``i`` of the code row
lives in word ``i // 32`` at bit position ``i % 32``).  All conversions are
vectorised shift/mask ops that fuse into the decode prologue.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

WORD_BITS = 32


def bits_per_code(c: int) -> int:
    """log2(c); validates that c is a power of two >= 2."""
    if c < 2 or (c & (c - 1)) != 0:
        raise ValueError(f"code cardinality c must be a power of two >= 2, got {c}")
    return int(c).bit_length() - 1


def n_bits(c: int, m: int) -> int:
    """Total bits per entity: m * log2(c)."""
    if m < 1:
        raise ValueError(f"code length m must be >= 1, got {m}")
    return m * bits_per_code(c)


def n_words(c: int, m: int) -> int:
    """uint32 words per entity."""
    return -(-n_bits(c, m) // WORD_BITS)


def pack_bits(bits) -> jnp.ndarray:
    """(n, n_bit) bool -> (n, n_words) uint32 (little-endian within words)."""
    bits = jnp.asarray(bits, jnp.uint32)
    n, nb = bits.shape
    nw = -(-nb // WORD_BITS)
    pad = nw * WORD_BITS - nb
    if pad:
        bits = jnp.pad(bits, ((0, 0), (0, pad)))
    bits = bits.reshape(n, nw, WORD_BITS)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return (bits << shifts).sum(axis=-1, dtype=jnp.uint32)


def unpack_bits(packed, nb: int) -> jnp.ndarray:
    """(n, n_words) uint32 -> (n, nb) bool."""
    packed = jnp.asarray(packed, jnp.uint32)
    n, nw = packed.shape
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(n, nw * WORD_BITS)[:, :nb].astype(jnp.bool_)


def bits_to_codes(bits, c: int, m: int) -> jnp.ndarray:
    """(n, n_bit) bool -> (n, m) int32, each element in [0, c).  MSB-first."""
    b = bits_per_code(c)
    bits = jnp.asarray(bits, jnp.int32).reshape(bits.shape[0], m, b)
    weights = (1 << jnp.arange(b - 1, -1, -1, dtype=jnp.int32))
    return (bits * weights).sum(-1).astype(jnp.int32)


def codes_to_bits(codes, c: int, m: int) -> jnp.ndarray:
    """(n, m) int -> (n, n_bit) bool.  MSB-first per element."""
    b = bits_per_code(c)
    codes = jnp.asarray(codes, jnp.int32)
    shifts = jnp.arange(b - 1, -1, -1, dtype=jnp.int32)
    bits = (codes[..., None] >> shifts) & 1
    return bits.reshape(codes.shape[0], m * b).astype(jnp.bool_)


def pack_codes(codes, c: int, m: int) -> jnp.ndarray:
    """(n, m) int codes -> (n, n_words) uint32 packed storage."""
    return pack_bits(codes_to_bits(codes, c, m))


def unpack_codes(packed, c: int, m: int) -> jnp.ndarray:
    """(n, n_words) uint32 -> (n, m) int32 codes.

    This is the decode-path prologue: pure shift/mask (VPU friendly), no
    gathers beyond the row fetch itself.
    """
    b = bits_per_code(c)
    packed = jnp.asarray(packed, jnp.uint32)
    lead = packed.shape[:-1]
    # global bit index of the MSB..LSB of each code element
    elem = jnp.arange(m)[:, None]                       # (m, 1)
    off = jnp.arange(b)[None, :]                        # (1, b)
    bit_idx = elem * b + off                            # (m, b) MSB-first order
    word_idx = (bit_idx // WORD_BITS).astype(jnp.int32)
    bit_in_word = (bit_idx % WORD_BITS).astype(jnp.uint32)
    words = jnp.take(packed, word_idx.reshape(-1), axis=-1)
    bits = (words >> bit_in_word.reshape(-1)) & jnp.uint32(1)
    bits = bits.reshape(*lead, m, b).astype(jnp.int32)
    weights = (1 << jnp.arange(b - 1, -1, -1, dtype=jnp.int32))
    return (bits * weights).sum(-1).astype(jnp.int32)


def position_codes(ids, c: int, m: int, seed: int = 0) -> jnp.ndarray:
    """(B,) entity ids -> (B, m) int32 position-hash codes in [0, c).

    The ``hashemb`` compression family's hash functions (arXiv:2109.00101):
    ``m`` independent stateless hashes of the entity id, recomputed at
    lookup time — no per-entity ``codes_buf`` exists, so id-side memory is
    zero and unseen ids hash without retraining.  Each position ``j`` mixes
    ``id`` with a per-position odd key through a splitmix32-style finalizer
    (xor-shift + odd-multiply avalanche, pure uint32 shift/mask/mul — VPU
    friendly and identical on host and device), then keeps the top
    ``log2(c)`` bits (the best-mixed ones).  Deterministic in
    ``(ids, c, m, seed)``.
    """
    b = bits_per_code(c)
    if m < 1:
        raise ValueError(f"code length m must be >= 1, got {m}")
    ids = jnp.asarray(ids, jnp.uint32)[:, None]             # (B, 1)
    # per-position keys: golden-ratio stride, odd so multiplication is a
    # bijection on uint32
    j = jnp.arange(m, dtype=jnp.uint32)[None, :]            # (1, m)
    key = (j * jnp.uint32(0x9E3779B9)
           + jnp.uint32(2 * seed + 1) * jnp.uint32(0x85EBCA6B))
    x = ids ^ key
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return (x >> jnp.uint32(32 - b)).astype(jnp.int32)      # top-b bits


def count_collisions(codes) -> int:
    """Number of entities sharing a code with an earlier entity.

    ``codes`` is any 2D per-entity code representation (packed words or
    integer codes).  Returns ``n - n_unique`` (the paper's Fig. 3 metric).
    Host-side (numpy) — used by benchmarks, not in the training path.
    """
    arr = np.asarray(codes)
    return int(arr.shape[0] - np.unique(arr, axis=0).shape[0])


def code_capacity(c: int, m: int) -> int:
    """Number of distinct representable entities (2**n_bit)."""
    return 1 << n_bits(c, m)
