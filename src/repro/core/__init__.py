"""The paper's primary contribution: hash-based embedding compression.

encode (one-shot, training-free)        -> core.lsh.encode_lsh (Algorithm 1)
store  (packed bit codes)               -> core.codes
decode (trainable, entity-independent)  -> core.decoder
decode backends (gather/onehot/pallas)  -> core.backend (+ hot-node cache)
drop-in layer                           -> core.embedding (init/lookup API)
baselines                               -> lsh.encode_random (ALONE), core.autoencoder
memory model                            -> core.memory (Tables 2/4/6, exact)
"""

from repro.core import codes
from repro.core.backend import (
    CachedDecodeBackend,
    CacheState,
    DecodeBackend,
    available_backends,
    get_backend,
    register_backend,
)
from repro.core.decoder import DecoderConfig, apply_decoder, init_decoder
from repro.core.embedding import (
    EmbeddingConfig,
    embed_lookup,
    init_embedding,
    make_codes,
    decode_all,
)
from repro.core.lsh import encode_lsh, encode_lsh_codes, encode_random
from repro.core.memory import compression_ratio, memory_breakdown

__all__ = [
    "codes",
    "CachedDecodeBackend", "CacheState", "DecodeBackend",
    "available_backends", "get_backend", "register_backend",
    "DecoderConfig", "apply_decoder", "init_decoder",
    "EmbeddingConfig", "embed_lookup", "init_embedding", "make_codes", "decode_all",
    "encode_lsh", "encode_lsh_codes", "encode_random",
    "compression_ratio", "memory_breakdown",
]
