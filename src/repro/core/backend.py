"""Pluggable decode backends for the paper's hot op (codes -> codebook sum).

Every call-site that rebuilds a node/token embedding from its m hash codes —
the embedding layer, the GNN frontier decode, the LM input path and serving —
routes through one ``DecodeBackend``:

    decode(codes (B, m) int32, codebooks (m, c, d_c), w0 (d_c,)?) -> (B, d_c) f32

Four implementations are registered:

  gather   m sequential gathers accumulated in f32 — the paper's GPU
           formulation and the bit-exactness oracle (accumulation order
           matches the Pallas kernel's, so kernel parity is bitwise).
  onehot   one (B, m*c) x (m*c, d_c) matmul with f32 accumulation — the MXU
           formulation XLA fuses well.
  pallas   ``kernels.hash_decode`` fused kernel.  Unaligned ``B``/``d_c`` are
           explicitly zero-padded to tile/block multiples here (a warning is
           emitted once) instead of silently falling back to the reference
           path.
  sharded  data-parallel decode: frontier rows partitioned over the active
           mesh's data axis, decoded shard-local (``shard_map``) by a base
           backend (``"sharded:gather"`` pins it), rows all_gathered forward
           and codebook/W0 cotangents psummed in the custom VJP.
  owner    owner-computes cross-shard dedup: rows hash-partitioned by
           ``node_id % n_shards``, requests ``all_to_all``ed to their owner,
           each distinct owned id decoded exactly once, embeddings
           ``all_to_all``ed back (routing = a host-built static-capacity
           ``graph.sampler.OwnerPlan`` riding on the batch).

Two further entries select alternate *compression families* (ROADMAP item
4) rather than alternate execution strategies — same registry, same
frontier/dedup/cache/owner machinery, different parameterization (see
``family_of`` and docs/decode_backends.md §Compression families):

  hashemb  position-based hash embeddings (arXiv:2109.00101): each id maps
           through m independent hash functions into shared parameter
           pools combined with learned per-position weights.  No per-entity
           ``codes_buf`` exists — codes are recomputed from the id per
           lookup (``core.codes.position_codes``).  The pool gather itself
           is delegated to a base backend (``"hashemb:gather"`` pins it),
           so the decode math rides gather/onehot/pallas unchanged.
  tt       tensor-train factorized codebooks (Nimble GNN, arXiv:2206.10581):
           the (m, c, d_c) codebook tensor is stored as two TT cores
           ``g0 (m, c1, d1, r)`` / ``g1 (m, r, c2, d2)`` with
           ``c = c1*c2``, ``d_c = d1*d2``; the rank-r contraction is fused
           into the decode (gather both cores' rows, one einsum) — the
           full codebook is never materialized.

Selection is by config string (``lookup_impl``): a backend name, or ``auto``
which under a multi-device mesh picks ``owner`` when the measured frontier
duplication beats ``OWNER_DUP_THRESHOLD`` (else ``sharded``), ``pallas`` on
TPU-capable runtimes and ``onehot`` otherwise.  New backends register via
``register_backend`` and become selectable by name everywhere at once.

``CachedDecodeBackend`` layers a device-resident LRU of *decoded embeddings*
keyed by entity id on top of any base decode path: hot (high-degree) nodes
recur in almost every GNN frontier, and their embeddings only drift as fast
as the decoder parameters train.  A ``staleness`` budget (in codebook
versions; the train step bumps the version on every optimizer update) bounds
that drift; at staleness 0 every access re-decodes, reproducing the uncached
computation exactly.

Every backend carries a ``MixedPrecisionPolicy`` (param_dtype /
compute_dtype / reduce_dtype / quantize) and states its dtype contract via
``dtype_contract()``: codebooks may be stored bf16 or absmax-int8 (fused
dequant in the pallas kernel, straight-through dequant in the XLA
backends), but accumulation — the kernel's MXU accumulator, every psum and
every scatter-add on the VJP path — is always ``reduce_dtype`` (f32).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray

# f32 min tile on TPU is (8, 128): sublane multiple for the batch dim, lane
# multiple for the feature dim (pallas guide, "Tiling Constraints").
_SUBLANE = 8
_LANE = 128

_warned: set = set()


def _warn_once(key: str, msg: str) -> None:
    if key not in _warned:
        _warned.add(key)
        warnings.warn(msg, stacklevel=3)


def _round_up(n: int, mult: int) -> int:
    return -(-n // mult) * mult


@dataclasses.dataclass(frozen=True)
class BackendCapabilities:
    """Metadata consumed by selection logic and call-sites."""
    grad: bool = True            # differentiable w.r.t. codebooks / w0
    fused: bool = False          # single fused kernel (no HBM intermediates)
    accelerator: Tuple[str, ...] = ("cpu", "gpu", "tpu")


@dataclasses.dataclass(frozen=True)
class MixedPrecisionPolicy:
    """Dtype contract of a decode path (the zeroband param/compute/reduce
    split, specialised to the decode hot op).

    ``param_dtype``    storage dtype of codebooks/w0 entering the decode
                       (None = use whatever the caller passed — the
                       pre-policy behaviour, bit-exact with old configs)
    ``compute_dtype``  activation dtype the caller works in (informational
                       here — the decode itself always accumulates f32 and
                       returns f32; callers cast the output down)
    ``reduce_dtype``   accumulation dtype: the kernel's MXU accumulator and
                       every psum / scatter-add on the VJP path.  Always
                       float32 — backends hard-code it and tests assert it;
                       the field exists so the contract is stated, not
                       implied.
    ``quantize``       "none" | "int8": absmax per-(codebook, code) int8
                       values + f32 scales.  Fused dequant in the pallas
                       kernel; straight-through dequant-identity in the XLA
                       backends (bitwise-matching values, see
                       kernels.hash_decode.ops).
    """
    param_dtype: Optional[str] = None
    compute_dtype: Optional[str] = None
    reduce_dtype: str = "float32"
    quantize: str = "none"

    def __post_init__(self):
        if self.quantize not in ("none", "int8"):
            raise ValueError(
                f"quantize={self.quantize!r} not supported (expected 'none' "
                f"or 'int8'; int4 packing is a documented future extension)")
        if self.reduce_dtype != "float32":
            raise ValueError(
                "reduce_dtype must be 'float32': every backend accumulates "
                "and reduces in f32 (that is the stated contract)")


DEFAULT_POLICY = MixedPrecisionPolicy()

# Documented decode drift bounds vs the all-f32 path (docs/decode_backends.md
# dtype-contract table): max-abs output error <= bound * max-abs(f32 output)
# per decode, and end-to-end step-0 loss relative drift within the same
# bound, for EVERY backend (incl. owner and cached) — tests/test_precision.py
# asserts both, the CI bench gate asserts the int8 one.
DRIFT_BOUNDS = {"bfloat16": 1.5e-2, "int8": 5e-2}


class DecodeBackend:
    """Protocol: subclasses set ``name``/``capabilities``/``preferred_pad``
    and implement ``decode``.  ``preferred_pad`` is the batch multiple the
    backend runs best at — frontier padding (``pad_to``) should be a multiple
    of it so the hot path never hits the padding fix-up.  ``policy`` is the
    backend's ``MixedPrecisionPolicy``; the default (all-None) is a no-op
    cast-wise, so legacy construction sites keep bit-exact numerics."""

    name: str = "abstract"
    capabilities = BackendCapabilities()
    preferred_pad: int = 1
    policy: MixedPrecisionPolicy = DEFAULT_POLICY

    def decode(self, codes: Array, codebooks: Array,
               w0: Optional[Array] = None) -> Array:
        raise NotImplementedError

    def feature_dim(self, codebooks) -> int:
        """Output feature dim ``d_c`` of ``decode`` given its ``codebooks``
        operand.  The default reads the dense layout ``(m, c, d_c)``;
        family backends whose codebooks are a pytree (``tt``) override it.
        Collective wrappers use this instead of ``codebooks.shape[2]`` so
        they stay layout-agnostic."""
        return int(codebooks.shape[2])

    def _prep(self, codebooks, w0: Optional[Array]):
        """Cast params to the policy's storage dtype (simulating bf16 HBM
        residency); int8 handling is backend-specific — fused scales in
        pallas, straight-through dequant in the XLA backends — so it is NOT
        applied here.  ``codebooks`` may be a pytree (the ``tt`` family's
        core pair); every leaf is cast."""
        p = self.policy
        if p.param_dtype is not None:
            codebooks = jax.tree_util.tree_map(
                lambda x: x.astype(p.param_dtype), codebooks)
            if w0 is not None:
                w0 = w0.astype(p.param_dtype)
        return codebooks, w0

    def dtype_contract(self) -> Dict[str, str]:
        """The backend's stated dtype contract (docs/decode_backends.md)."""
        p = self.policy
        storage = ("int8 values + float32 scales" if p.quantize == "int8"
                   else (p.param_dtype or "caller-provided"))
        return {
            "backend": self.name,
            "storage": storage,
            "compute": p.compute_dtype or "float32",
            "accumulate": p.reduce_dtype,
            "output": "float32",
        }

    def decode_frontier(self, codes: Array, codebooks: Array,
                        w0: Optional[Array] = None, *, plan=None) -> Array:
        """Frontier-decode entry point: like ``decode`` but may exploit a
        host-built ``graph.sampler.OwnerPlan`` riding on the batch.  The
        default ignores the plan (decoding every row is always correct);
        only collective backends (``owner``) override it."""
        return self.decode(codes, codebooks, w0)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<DecodeBackend {self.name}>"


class GatherBackend(DecodeBackend):
    """Oracle: m sequential gathers, f32 accumulation in codebook order j=0..m-1
    (the same order the Pallas kernel accumulates in, so parity is bitwise)."""

    name = "gather"
    capabilities = BackendCapabilities(grad=True, fused=False)
    preferred_pad = 1

    def __init__(self, policy: Optional[MixedPrecisionPolicy] = None):
        self.policy = policy or DEFAULT_POLICY

    def decode(self, codes, codebooks, w0=None):
        codebooks, w0 = self._prep(codebooks, w0)
        if self.policy.quantize == "int8":
            from repro.kernels.hash_decode import ops as hd_ops
            # straight-through dequant: forward sees q·s (element-for-element
            # the same f32 products as the fused kernel), backward is the
            # identity to the float masters
            codebooks = hd_ops.quantize_dequantize(codebooks)
        m = codebooks.shape[0]
        acc = codebooks[0].astype(jnp.float32)[codes[:, 0]]
        for j in range(1, m):
            acc = acc + codebooks[j].astype(jnp.float32)[codes[:, j]]
        if w0 is not None:
            acc = acc * w0.astype(jnp.float32)[None, :]
        return acc


class OnehotBackend(DecodeBackend):
    """One-hot x stacked-codebook matmul; the sum over m is absorbed into a
    single (B, m*c) x (m*c, d_c) contraction the MXU executes natively."""

    name = "onehot"
    capabilities = BackendCapabilities(grad=True, fused=False)
    preferred_pad = _SUBLANE

    def __init__(self, policy: Optional[MixedPrecisionPolicy] = None):
        self.policy = policy or DEFAULT_POLICY

    def decode(self, codes, codebooks, w0=None):
        codebooks, w0 = self._prep(codebooks, w0)
        if self.policy.quantize == "int8":
            from repro.kernels.hash_decode import ops as hd_ops
            codebooks = hd_ops.quantize_dequantize(codebooks)
        m, c, d_c = codebooks.shape
        B = codes.shape[0]
        iota_c = jax.lax.broadcasted_iota(jnp.int32, (1, 1, c), 2)
        onehot = (codes[:, :, None] == iota_c).astype(codebooks.dtype)
        out = jax.lax.dot_general(
            onehot.reshape(B, m * c), codebooks.reshape(m * c, d_c),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        if w0 is not None:
            out = out * w0.astype(jnp.float32)[None, :]
        return out


class PallasBackend(DecodeBackend):
    """Fused Pallas kernel with explicit padding of unaligned shapes.

    ``B`` is padded with zero codes (code 0 is always valid) up to a
    tile/block multiple; ``d_c`` is padded by zero-extending the codebooks
    (and w0) along the feature dim.  Both paths warn once — persistent
    unaligned shapes should fix their config, not eat a copy per call."""

    name = "pallas"
    capabilities = BackendCapabilities(
        grad=True, fused=True, accelerator=("tpu",))

    def __init__(self, block_b: int = 256, block_d: int = 256,
                 interpret: bool = False,
                 policy: Optional[MixedPrecisionPolicy] = None):
        self.block_b = int(block_b)
        self.block_d = int(block_d)
        self.interpret = bool(interpret)
        self.policy = policy or DEFAULT_POLICY
        self.preferred_pad = self.block_b

    def _plan(self, B: int, d_c: int) -> Tuple[int, int, int, int]:
        """Minimal padding to tile multiples, then the largest tileable
        block that divides each padded dim — shrinking the block is free,
        padding (especially the codebook copy along d_c) is not."""
        B_pad = _round_up(B, _SUBLANE)
        bb = min(self.block_b, B_pad)
        while B_pad % bb:
            bb -= _SUBLANE
        d_pad = _round_up(d_c, _LANE)
        bd = min(self.block_d, d_pad)
        while d_pad % bd:
            bd -= _LANE
        return B_pad, bb, d_pad, bd

    def decode(self, codes, codebooks, w0=None):
        from repro.kernels.hash_decode import ops as hd_ops

        codebooks, w0 = self._prep(codebooks, w0)
        B = codes.shape[0]
        d_c = codebooks.shape[2]
        B_pad, block_b, d_pad, block_d = self._plan(B, d_c)
        if B_pad != B:
            _warn_once(
                f"pallas-pad-b-{B}",
                f"pallas decode: padding batch {B} -> {B_pad}; pad frontiers "
                f"to a multiple of preferred_pad={self.preferred_pad} to "
                f"avoid the copy")
            codes = jnp.pad(codes, ((0, B_pad - B), (0, 0)))
        if d_pad != d_c:
            _warn_once(
                f"pallas-pad-d-{d_c}",
                f"pallas decode: padding d_c {d_c} -> {d_pad} (codebook "
                f"copy per call); prefer lane-aligned d_c")
            codebooks = jnp.pad(codebooks, ((0, 0), (0, 0), (0, d_pad - d_c)))
            if w0 is not None:
                w0 = jnp.pad(w0, (0, d_pad - d_c))
        out = hd_ops.hash_decode(
            codes, codebooks, w0,
            block_b=block_b, block_d=block_d, interpret=self.interpret,
            quantize=self.policy.quantize)
        return out[:B, :d_c]


# ---------------------------------------------------------------------------
# sharded (data-parallel) decode
# ---------------------------------------------------------------------------

def _replicated_specs(tree):
    """Per-leaf fully-replicated PartitionSpecs for a (possibly nested)
    codebook pytree — exact-rank ``P(None, ..., None)`` so shard_map sees
    one spec per leaf whatever the family's parameter layout is."""
    from jax.sharding import PartitionSpec as P
    return jax.tree_util.tree_map(lambda x: P(*([None] * x.ndim)), tree)


def _psum_f32(tree, like, axis):
    """reduce_dtype contract: cross-shard accumulation happens in f32 even
    when the params (and so their cotangents) are bf16.  Pytree-wide."""
    return jax.tree_util.tree_map(
        lambda g, p: jax.lax.psum(g.astype(jnp.float32), axis).astype(p.dtype),
        tree, like)


def _sharded_decode(base: DecodeBackend, mesh, axis: str,
                    codes: Array, codebooks, w0: Array) -> Array:
    """Row-partitioned decode under ``shard_map``: each device decodes its
    block of frontier rows against the replicated codebooks, the forward
    ``all_gather``s the decoded rows, and the custom VJP ``psum``s the
    codebook/W0 cotangents so the replicated parameters see the full-batch
    gradient.  (shard_map with ``check_vma=False`` does not insert the
    replicated-input psum itself — spelling the VJP out keeps gradients
    correct by construction.)  ``codebooks`` may be any pytree the base
    backend understands (dense ``(m, c, d_c)``, or the ``tt`` core pair)."""
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import shard_map

    cb_specs = _replicated_specs(codebooks)

    @jax.custom_vjp
    def decode(codes, cb, w0):
        def local(codes_l, cb_, w0_):
            out_l = base.decode(codes_l, cb_, w0_)
            return jax.lax.all_gather(out_l, axis, axis=0, tiled=True)
        return shard_map(
            local, mesh=mesh,
            in_specs=(P(axis, None), cb_specs, P(None)),
            out_specs=P(None, None), check_vma=False)(codes, cb, w0)

    def fwd(codes, cb, w0):
        return decode(codes, cb, w0), (codes, cb, w0)

    def bwd(res, g):
        codes, cb, w0 = res

        def local(codes_l, g_l, cb_, w0_):
            _, vjp = jax.vjp(
                lambda c, s: base.decode(codes_l, c, s), cb_, w0_)
            gcb, gw0 = vjp(g_l)
            gcb = _psum_f32(gcb, cb_, axis)
            gw0 = jax.lax.psum(gw0.astype(jnp.float32), axis).astype(w0_.dtype)
            return gcb, gw0

        gcb, gw0 = shard_map(
            local, mesh=mesh,
            in_specs=(P(axis, None), P(axis, None), cb_specs, P(None)),
            out_specs=(cb_specs, P(None)),
            check_vma=False)(codes, g, cb, w0)
        return None, gcb, gw0      # codes are integers: no gradient

    decode.defvjp(fwd, bwd)
    return decode(codes, codebooks, w0)


def _active_mesh_axis(mesh, axis):
    """Resolve the (mesh, data-axis) pair a collective backend runs over:
    the pinned mesh if any, else the ``use_sharding`` context's at trace
    time; ``(None, None)`` means single-device (degrade to base)."""
    from repro.parallel import sharding as sh
    mesh = mesh if mesh is not None else sh.current_mesh()
    if mesh is None:
        return None, None
    return mesh, (axis or sh.data_axis(mesh))


def _check_collective_base(name: str, base) -> None:
    if isinstance(base, str) and base.split(":")[0] in ("sharded", "owner"):
        raise ValueError(
            f"{name} backend cannot wrap itself or another collective "
            f"backend (got base={base!r})")


class ShardedBackend(DecodeBackend):
    """Data-parallel decode: frontier rows are partitioned across the mesh's
    data axis and decoded shard-local by a wrapped base backend (each shard's
    batch source already groups its rows contiguously, so no resharding
    happens on the hot path).  Codebooks stay replicated — they are ≤ 10 MB,
    which IS the paper's point; what doesn't fit one host at industrial scale
    is the *frontier decode work*, and that is what shards here.

    The mesh is read from the ``use_sharding`` context at trace time (or
    pinned via ``mesh=``); with no mesh or a 1-sized data axis the backend
    degrades to a plain base-backend call, so single-device runs of a
    ``lookup_impl="sharded"`` config are exact no-ops.  The base accumulates
    per row independently, so a row's decoded value is invariant to which
    shard holds it — the 1-shard and N-shard runs agree bitwise.
    """

    name = "sharded"
    capabilities = BackendCapabilities(grad=True, fused=False)

    def __init__(self, base: Optional[object] = None, axis: Optional[str] = None,
                 mesh=None, interpret: bool = False,
                 policy: Optional[MixedPrecisionPolicy] = None):
        if base is None:
            base = "pallas" if jax.default_backend() == "tpu" else "onehot"
        _check_collective_base("sharded", base)
        self.base = get_backend(base, interpret=interpret, policy=policy)
        self.policy = self.base.policy
        self.axis = axis
        self.mesh = mesh
        self.preferred_pad = self.base.preferred_pad

    def dtype_contract(self) -> Dict[str, str]:
        contract = dict(self.base.dtype_contract(), backend=self.name)
        contract["collective_reduce"] = "float32 (psum of codebook/w0 grads)"
        return contract

    def feature_dim(self, codebooks) -> int:
        return self.base.feature_dim(codebooks)

    def _mesh_axis(self):
        return _active_mesh_axis(self.mesh, self.axis)

    def decode(self, codes, codebooks, w0=None):
        mesh, axis = self._mesh_axis()
        k = mesh.shape[axis] if mesh is not None else 1
        if k <= 1:
            return self.base.decode(codes, codebooks, w0)
        B = codes.shape[0]
        B_pad = _round_up(B, k)
        if B_pad != B:
            _warn_once(
                f"sharded-pad-b-{B}-{k}",
                f"sharded decode: padding batch {B} -> {B_pad} to split over "
                f"{k} shards; pad frontiers to a multiple of the shard count "
                f"(e.g. frontier_cap) to avoid the copy")
            codes = jnp.pad(codes, ((0, B_pad - B), (0, 0)))
        if w0 is None:
            # keep one shard_map signature: multiplying by exactly 1.0 is a
            # bitwise no-op, and the dummy's cotangent is simply discarded
            w0 = jnp.ones((self.base.feature_dim(codebooks),), jnp.float32)
        out = _sharded_decode(self.base, mesh, axis, codes, codebooks, w0)
        return out[:B]


# ---------------------------------------------------------------------------
# owner-computes (cross-shard dedup) decode
# ---------------------------------------------------------------------------

def _owner_decode(base: DecodeBackend, mesh, axis: str,
                  codes: Array, codebooks, w0: Array, plan) -> Array:
    """Owner-computes cross-shard frontier decode under ``shard_map``.

    Layout (all static, from the host-built ``OwnerPlan``): each shard's
    local frontier block has ``cap`` rows; requests are bucketed by
    ``owner = id % n`` into ``owner_cap`` slots per (requester, owner) pair.

        requester s: send[o, k]  = codes[req_rows[s, o, k]]      (gather)
                     ── all_to_all ─▶
        owner o:     owned[j]    = recv.flat[owned_src[o, j]]    (dedup)
                     dec         = base.decode(owned)            (ONCE per id)
                     ret[s, k]   = dec[ret_idx[o, s, k]]         (fan back out)
                     ── all_to_all ─▶
        requester s: out[req_rows[s, o, k]] = back[o, k]         (scatter)

    The forward ``all_gather``s the scattered blocks so the post-decode
    combine sees the full batch (same contract as the ``sharded`` backend).
    The custom VJP routes cotangents back through the same permutation:
    each requester slices its block of the (replicated) cotangent, sends it
    through the reverse exchange, and the owner scatter-*adds* the
    per-requester contributions onto its owned rows — so every decoded row's
    cotangent is accumulated exactly once, on its owner, before one
    ``base.decode`` VJP per owner produces disjoint codebook partials (the
    closing ``psum`` only sums those disjoint partials into the replicated
    codebook gradient; no duplicate row is ever double-counted)."""
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import all_to_all, shard_map

    n = int(plan.req_rows.shape[0])
    oc = int(plan.req_rows.shape[2])
    cap = codes.shape[0] // n
    d = base.feature_dim(codebooks)
    ou = int(plan.owned_src.shape[1])
    plan_specs = (P(axis, None, None), P(axis, None), P(axis, None, None))
    cb_specs = _replicated_specs(codebooks)

    def _owned_codes(codes_l, rr, os_l):
        """Requester-side gather + all_to_all + owner-side dedup gather."""
        send = codes_l[jnp.clip(rr, 0, cap - 1)]            # (n, oc, m)
        recv = all_to_all(send, axis)                       # (n, oc, m)
        return recv.reshape(n * oc, -1)[os_l]               # (ou, m)

    @jax.custom_vjp
    def decode(codes, req_rows, owned_src, ret_idx, cb, w0):
        def local(codes_l, rr_l, os_l, ri_l, cb_, w0_):
            rr = rr_l[0]
            dec = base.decode(_owned_codes(codes_l, rr, os_l[0]), cb_, w0_)
            back = all_to_all(dec[ri_l[0]], axis)           # (n, oc, d)
            out_l = jnp.zeros((cap, d), dec.dtype).at[rr.reshape(-1)].set(
                back.reshape(-1, d), mode="drop")           # sentinel cap drops
            return jax.lax.all_gather(out_l, axis, axis=0, tiled=True)
        return shard_map(
            local, mesh=mesh,
            in_specs=(P(axis, None),) + plan_specs + (cb_specs, P(None)),
            out_specs=P(None, None), check_vma=False)(
                codes, req_rows, owned_src, ret_idx, cb, w0)

    def fwd(codes, req_rows, owned_src, ret_idx, cb, w0):
        out = decode(codes, req_rows, owned_src, ret_idx, cb, w0)
        return out, (codes, req_rows, owned_src, ret_idx, cb, w0)

    def bwd(res, g):
        codes, req_rows, owned_src, ret_idx, cb, w0 = res

        def local(codes_l, rr_l, os_l, ri_l, g_full, cb_, w0_):
            rr = rr_l[0]
            owned = _owned_codes(codes_l, rr, os_l[0])
            s = jax.lax.axis_index(axis)
            g_blk = jax.lax.dynamic_slice_in_dim(g_full, s * cap, cap, 0)
            g_send = (g_blk[jnp.clip(rr, 0, cap - 1)]
                      * (rr < cap)[..., None].astype(g_full.dtype))
            g_recv = all_to_all(g_send, axis)               # (n, oc, d)
            # reduce_dtype contract: the per-requester scatter-add onto the
            # owned rows accumulates in f32
            ghat = jnp.zeros((ou, d), jnp.float32).at[
                ri_l[0].reshape(-1)].add(
                    g_recv.reshape(-1, d).astype(jnp.float32))
            _, vjp = jax.vjp(lambda c, sc: base.decode(owned, c, sc), cb_, w0_)
            gcb, gw0 = vjp(ghat.astype(g_full.dtype))
            gcb = _psum_f32(gcb, cb_, axis)
            gw0 = jax.lax.psum(gw0.astype(jnp.float32), axis).astype(w0_.dtype)
            return gcb, gw0

        gcb, gw0 = shard_map(
            local, mesh=mesh,
            in_specs=(P(axis, None),) + plan_specs
            + (P(None, None), cb_specs, P(None)),
            out_specs=(cb_specs, P(None)), check_vma=False)(
                codes, req_rows, owned_src, ret_idx, g, cb, w0)
        return None, None, None, None, gcb, gw0   # ints: no gradient

    decode.defvjp(fwd, bwd)
    return decode(codes, plan.req_rows, plan.owned_src, plan.ret_idx,
                  codebooks, w0)


class OwnerBackend(DecodeBackend):
    """Owner-computes cross-shard frontier decode (ISSUE 5).

    The ``sharded`` backend decodes each shard's frontier block locally, so
    a hub node appearing in k shards' frontiers is decoded k times.  This
    backend hash-partitions rows by ``owner = node_id % n_shards``: each
    shard ``all_to_all``s its requests to the owning shard, the owner
    decodes every distinct id it owns exactly **once** (the cross-shard
    dedup), and a second ``all_to_all`` returns the embeddings.  The
    routing (a static-capacity ``OwnerPlan``) is built host-side in the
    batch source's prefetch thread, so the jitted step sees fixed shapes.

    Without a plan — or without a multi-device mesh, or when the plan's
    shard count doesn't match the mesh — the call degrades to the
    row-partitioned ``sharded`` decode of the same base backend (identical
    values, no dedup), so a ``lookup_impl="owner"`` config runs
    single-device tests unchanged and overflown plans fall back loudly
    upstream without ever truncating rows.
    """

    name = "owner"
    capabilities = BackendCapabilities(grad=True, fused=False)

    def __init__(self, base: Optional[object] = None, axis: Optional[str] = None,
                 mesh=None, interpret: bool = False,
                 policy: Optional[MixedPrecisionPolicy] = None):
        if base is None:
            base = "pallas" if jax.default_backend() == "tpu" else "onehot"
        _check_collective_base("owner", base)
        self.base = get_backend(base, interpret=interpret, policy=policy)
        self.policy = self.base.policy
        self.axis = axis
        self.mesh = mesh
        self.preferred_pad = self.base.preferred_pad
        # plan-less fallback: the row-partitioned sharded decode (values are
        # identical — rows just decode once per holding shard, not per owner)
        self._fallback = ShardedBackend(self.base, axis=axis, mesh=mesh)

    def dtype_contract(self) -> Dict[str, str]:
        contract = dict(self.base.dtype_contract(), backend=self.name)
        contract["collective_reduce"] = (
            "float32 (cotangent scatter-add on owned rows + grad psum)")
        return contract

    def feature_dim(self, codebooks) -> int:
        return self.base.feature_dim(codebooks)

    def decode(self, codes, codebooks, w0=None):
        return self._fallback.decode(codes, codebooks, w0)

    def decode_frontier(self, codes, codebooks, w0=None, *, plan=None):
        mesh, axis = _active_mesh_axis(self.mesh, self.axis)
        k = mesh.shape[axis] if mesh is not None else 1
        if plan is None or k <= 1:
            return self.decode(codes, codebooks, w0)
        n = int(plan.req_rows.shape[0])
        if n != k or codes.shape[0] % n:
            _warn_once(
                f"owner-plan-mismatch-{n}-{k}-{codes.shape[0]}",
                f"owner decode: plan built for {n} shards / "
                f"{codes.shape[0]} rows does not match the {k}-way mesh; "
                f"falling back to the row-partitioned sharded decode")
            return self.decode(codes, codebooks, w0)
        if w0 is None:
            # same trick as ShardedBackend: one shard_map signature, and
            # multiplying by exactly 1.0 is a bitwise no-op
            w0 = jnp.ones((self.base.feature_dim(codebooks),), jnp.float32)
        return _owner_decode(self.base, mesh, axis, codes, codebooks, w0, plan)


# ---------------------------------------------------------------------------
# compression families (ROADMAP item 4)
# ---------------------------------------------------------------------------

# Registry names that select an alternate *compression family* (how the
# embedding table is parameterized) rather than an execution strategy.  A
# ``lookup_impl`` selects at most one; ``family_of`` finds it anywhere in
# the ":"-separated spelling, so "owner:tt" and "hashemb:gather" both work.
FAMILY_BACKENDS: Tuple[str, ...] = ("hashemb", "tt")


def family_of(lookup_impl: Optional[str]) -> str:
    """Compression family selected by a ``lookup_impl`` string: ``"hashemb"``
    / ``"tt"`` when that name appears in any ":"-separated part, else
    ``"paper"`` (the source paper's bit-code hashing — every pre-existing
    spelling, including ``auto`` and the collective wrappers)."""
    for part in (lookup_impl or "auto").split(":"):
        if part in FAMILY_BACKENDS:
            return part
    return "paper"


class HashEmbBackend(DecodeBackend):
    """Position-based hash embeddings (arXiv:2109.00101) as a decode family.

    Parameterization: m shared pools ``(m, c, d_c)`` plus learned
    per-position weights ``wpos (m, d_c)``; entity id ``i`` contributes
    ``sum_j wpos[j] * pools[j, h_j(i)]`` where ``h_j`` are m independent
    hash functions (``core.codes.position_codes`` — recomputed from the id
    at lookup time, so NO per-entity ``codes_buf`` exists and id-side memory
    is zero).  ``apply_decoder`` folds ``wpos`` into the pools before the
    call (``sum_j (wpos[j]*P[j])[h_j(i)] == sum_j wpos[j]*P[j][h_j(i)]``,
    exact in f32 and differentiable to both factors), so what reaches this
    backend is a standard ``(m, c, d_c)`` codebook gather — delegated
    verbatim to a base backend (gather/onehot/pallas, incl. int8/bf16
    policies).  ``"hashemb:gather"`` pins the base; ``"owner:hashemb"`` /
    ``"sharded:hashemb"`` compose with the collectives unchanged."""

    name = "hashemb"
    capabilities = BackendCapabilities(grad=True, fused=False)

    def __init__(self, base: Optional[object] = None, interpret: bool = False,
                 policy: Optional[MixedPrecisionPolicy] = None):
        if base is None:
            base = "pallas" if jax.default_backend() == "tpu" else "onehot"
        _check_collective_base("hashemb", base)
        if isinstance(base, str) and base.split(":")[0] in FAMILY_BACKENDS:
            raise ValueError(
                f"hashemb backend cannot wrap another family (base={base!r})")
        self.base = get_backend(base, interpret=interpret, policy=policy)
        self.policy = self.base.policy
        self.preferred_pad = self.base.preferred_pad

    def dtype_contract(self) -> Dict[str, str]:
        contract = dict(self.base.dtype_contract(), backend=self.name)
        contract["family"] = "hashemb (pools + per-position weights)"
        return contract

    def feature_dim(self, codebooks) -> int:
        return self.base.feature_dim(codebooks)

    def decode(self, codes, codebooks, w0=None):
        return self.base.decode(codes, codebooks, w0)


def tt_factor_pair(n: int) -> Tuple[int, int]:
    """Most-balanced factorization ``n = a * b`` with ``a <= b`` (a scans
    down from isqrt).  Used for both the code split ``c = c1*c2`` and the
    feature split ``d_c = d1*d2`` of the ``tt`` family."""
    if n < 1:
        raise ValueError(f"cannot factor {n}")
    a = int(np.sqrt(n))
    while n % a:
        a -= 1
    return a, n // a


def tt_materialize(g0: Array, g1: Array) -> Array:
    """Contract a TT core pair back into the dense ``(m, c, d_c)`` codebook
    it factorizes — the oracle for parity tests and the ``trainable_params``
    accounting, never used on the decode hot path.

    ``g0 (m, c1, d1, r)``, ``g1 (m, c2, r, d2)`` →
    ``cb[j, x1*c2 + x2, u*d2 + v] = sum_r g0[j, x1, u, r] * g1[j, x2, r, v]``
    """
    m, c1, d1, r = g0.shape
    _, c2, _, d2 = g1.shape
    full = jnp.einsum("jxur,jyrv->jxyuv",
                      g0.astype(jnp.float32), g1.astype(jnp.float32))
    return full.reshape(m, c1 * c2, d1 * d2)


class TTBackend(DecodeBackend):
    """Tensor-train factorized codebooks (Nimble GNN, arXiv:2206.10581).

    The dense ``(m, c, d_c)`` codebook is stored as two TT cores
    ``g0 (m, c1, d1, r)`` / ``g1 (m, c2, r, d2)`` with ``c = c1*c2`` and
    ``d_c = d1*d2`` (balanced splits from ``tt_factor_pair``), cutting
    codebook memory from ``m*c*d_c`` to ``m*(c1*d1 + c2*d2)*r`` floats.
    ``decode`` fuses the rank-r contraction into the lookup: each code
    splits as ``x1 = code // c2``, ``x2 = code % c2``, both cores' rows are
    gathered and ONE f32 einsum sums the position contributions — the dense
    codebook is never materialized (``tt_materialize`` exists only as the
    parity/accounting oracle).  ``codebooks`` is therefore the pytree
    ``(g0, g1)``; the collective wrappers handle that via their pytree
    specs, so ``"owner:tt"`` / ``"sharded:tt"`` compose unchanged."""

    name = "tt"
    capabilities = BackendCapabilities(grad=True, fused=False)
    preferred_pad = _SUBLANE

    def __init__(self, policy: Optional[MixedPrecisionPolicy] = None):
        self.policy = policy or DEFAULT_POLICY

    def dtype_contract(self) -> Dict[str, str]:
        contract = super().dtype_contract()
        contract["family"] = "tt (rank-r core pair, contraction fused)"
        contract["accumulate"] = "float32 (core einsum + position sum)"
        return contract

    def feature_dim(self, codebooks) -> int:
        g0, g1 = codebooks
        return int(g0.shape[2]) * int(g1.shape[3])

    def _quantized(self, g0, g1):
        """absmax-int8 per (codebook, code row), like the dense path: each
        core reshapes its per-code row to one vector, rides the same
        straight-through ``quantize_dequantize``, and reshapes back."""
        from repro.kernels.hash_decode import ops as hd_ops
        m, c1, d1, r = g0.shape
        _, c2, _, d2 = g1.shape
        g0 = hd_ops.quantize_dequantize(
            g0.reshape(m, c1, d1 * r)).reshape(m, c1, d1, r)
        g1 = hd_ops.quantize_dequantize(
            g1.reshape(m, c2, r * d2)).reshape(m, c2, r, d2)
        return g0, g1

    def decode(self, codes, codebooks, w0=None):
        codebooks, w0 = self._prep(codebooks, w0)
        if self.policy.quantize == "int8":
            codebooks = self._quantized(*codebooks)
        g0, g1 = codebooks
        m, c1, d1, r = g0.shape
        _, c2, _, d2 = g1.shape
        x1 = codes // c2                                   # (B, m)
        x2 = codes % c2
        j = jnp.arange(m, dtype=codes.dtype)[None, :]      # (1, m)
        a0 = g0[j, x1].astype(jnp.float32)                 # (B, m, d1, r)
        a1 = g1[j, x2].astype(jnp.float32)                 # (B, m, r, d2)
        # one contraction: rank-r core product AND the sum over the m
        # positions, all accumulated in f32 (the reduce_dtype contract)
        out = jnp.einsum("bjur,bjrv->buv", a0, a1).reshape(-1, d1 * d2)
        if w0 is not None:
            out = out * w0.astype(jnp.float32)[None, :]
        return out


# ---------------------------------------------------------------------------
# registry / selection
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[..., DecodeBackend]] = {}


def register_backend(name: str, factory: Callable[..., DecodeBackend]) -> None:
    """Register a backend factory; ``factory(**opts) -> DecodeBackend``.
    Re-registering a name overrides it (tests swap in instrumented fakes)."""
    _REGISTRY[name] = factory


register_backend("gather", GatherBackend)
register_backend("onehot", OnehotBackend)
register_backend("pallas", PallasBackend)
register_backend("sharded", ShardedBackend)
register_backend("owner", OwnerBackend)
register_backend("hashemb", HashEmbBackend)
register_backend("tt", TTBackend)

# ``auto`` prefers the owner-computes decode over the plain sharded decode
# when the workload's measured duplication (frontier_rows / unique_rows, the
# per-device decode work over the mean per-shard unique count — what
# BENCH_shard.json reports) exceeds this: past 2x, the owner exchange
# reclaims more decode rows than its two all_to_alls cost, and the default
# owner_unique_cap = cap/2 sizing (graph.sampler.default_owner_caps) is
# guaranteed adequate in expectation by the same inequality.
OWNER_DUP_THRESHOLD = 2.0


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def rederive_owner_caps(frontier_cap: int, n_shards: int,
                        explicit: Tuple[Optional[int], Optional[int]] = (None, None),
                        ) -> Tuple[Optional[int], Optional[int]]:
    """Owner-exchange capacities for a (possibly rescaled) shard count.

    The ``(owner_cap, owner_unique_cap)`` sizing depends on ``n_shards``
    (request buckets shrink as shards multiply), so an elastic rescale must
    not carry the old run's caps over verbatim.  Policy: if the caller never
    pinned caps explicitly (both ``None``), keep them derived — return
    ``(None, None)`` and let the runtime size them per-plan; if either was
    pinned, re-derive both from ``default_owner_caps`` at the *new* shard
    count, which preserves the cap/2 adequacy argument documented there."""
    if explicit[0] is None and explicit[1] is None:
        return (None, None)
    from repro.graph.sampler import default_owner_caps
    return default_owner_caps(int(frontier_cap), int(n_shards))


def resolve_auto(duplication: Optional[float] = None) -> str:
    """``auto`` resolution: under a mesh whose data axis is actually split,
    the owner-computes decode when the measured frontier duplication
    justifies the exchange (``duplication > OWNER_DUP_THRESHOLD``) and the
    plain sharded decode otherwise; single-device, the fused kernel on TPU
    runtimes and the MXU-friendly XLA formulation everywhere else."""
    from repro.parallel.sharding import data_axis_size
    if data_axis_size() > 1:
        if duplication is not None and duplication > OWNER_DUP_THRESHOLD:
            return "owner"
        return "sharded"
    return "pallas" if jax.default_backend() == "tpu" else "onehot"


def get_backend(spec, *, interpret: bool = False,
                duplication: Optional[float] = None,
                policy: Optional[MixedPrecisionPolicy] = None) -> DecodeBackend:
    """Resolve a backend from a config string (or pass an instance through).

    ``auto`` picks a collective decode under a multi-device mesh (``owner``
    when the measured ``duplication`` beats ``OWNER_DUP_THRESHOLD``, else
    ``sharded``), the fused kernel on TPU runtimes and the MXU-friendly XLA
    formulation elsewhere.  ``sharded`` / ``owner`` / ``hashemb`` accept an
    optional base-backend suffix — ``"owner:gather"`` decodes owner-local
    through the gather oracle (bitwise-stable row accumulation),
    ``"hashemb:gather"`` pins the pool gather.  ``interpret`` affects
    ``pallas`` (directly or as a collective base).  ``policy`` sets the
    backend's ``MixedPrecisionPolicy``; it is only forwarded when given, so
    test-registered factories without the kwarg keep working."""
    if isinstance(spec, DecodeBackend):
        return spec
    name = spec or "auto"
    if name == "auto":
        name = resolve_auto(duplication)
    name, _, option = name.partition(":")
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown decode backend {name!r}; known: {available_backends()}")
    kwargs = {} if policy is None else {"policy": policy}

    def build(factory, **fixed):
        try:
            return factory(**fixed, **kwargs)
        except TypeError:
            if not kwargs:
                raise
            # legacy factory without the policy kwarg (e.g. a test-registered
            # fake): construct it plain and attach the policy as an attribute
            be = factory(**fixed)
            be.policy = policy
            return be

    if name in ("sharded", "owner", "hashemb"):
        return build(_REGISTRY[name], base=option or None, interpret=interpret)
    if option:
        raise ValueError(
            f"decode backend {name!r} takes no ':{option}' option "
            f"(only 'sharded:<base>' / 'owner:<base>' / 'hashemb:<base>' do)")
    if name == "pallas":
        return build(_REGISTRY[name], interpret=interpret)
    return build(_REGISTRY[name])


# ---------------------------------------------------------------------------
# hot-node cache
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CacheState:
    """Functional state of the hot-node decode cache (a pytree: it lives in
    the train state, flows through jit, and checkpoints like any buffer).

    ``node_ids``   (C,) int32 entity id per slot (-1 = empty)
    ``values``     (C, d) f32 cached decoded embeddings
    ``version``    (C,) int32 codebook version each entry was decoded at
    ``last_used``  (C,) int32 LRU clock of last access
    ``version_counter`` () int32 current codebook version (bumped per
                   optimizer update)
    ``clock``      () int32 access counter driving LRU order
    ``hits`` / ``misses`` () int32 cumulative accounting
    """

    node_ids: Array
    values: Array
    version: Array
    last_used: Array
    version_counter: Array
    clock: Array
    hits: Array
    misses: Array

    def tree_flatten(self):
        return (self.node_ids, self.values, self.version, self.last_used,
                self.version_counter, self.clock, self.hits, self.misses), None

    @classmethod
    def tree_unflatten(cls, _aux, leaves):
        return cls(*leaves)

    @classmethod
    def create(cls, capacity: int, d: int, dtype=jnp.float32) -> "CacheState":
        i32 = jnp.int32
        return cls(
            node_ids=jnp.full((capacity,), -1, i32),
            values=jnp.zeros((capacity, d), dtype),
            version=jnp.full((capacity,), jnp.iinfo(i32).min // 2, i32),
            last_used=jnp.full((capacity,), jnp.iinfo(i32).min // 2, i32),
            version_counter=jnp.zeros((), i32),
            clock=jnp.zeros((), i32),
            hits=jnp.zeros((), i32),
            misses=jnp.zeros((), i32),
        )

    @property
    def capacity(self) -> int:
        return self.node_ids.shape[0]


class CachedDecodeBackend:
    """LRU cache of decoded embeddings keyed by entity id, wrapping any base
    decode path.

    ``lookup(state, ids, decode_fn)`` serves each id from the cache when its
    entry is fresh enough (``version_counter - entry_version <= staleness``)
    and re-decodes otherwise; re-decoded rows are written back (LRU
    eviction), hit rows only refresh their LRU stamp.  Gradients flow
    through ``decode_fn`` for misses only — cached rows are constants from
    an earlier version, which is exactly the staleness trade.

    Ids within one lookup should be unique (the frontier decode guarantees
    it — pass ``valid`` to mask its padding rows); duplicate miss ids burn
    duplicate slots but reads stay correct.  At ``staleness=0`` an entry is
    only fresh within the version it was written at, so with one lookup per
    optimizer step every access re-decodes and training is bit-identical to
    the uncached path.
    """

    def __init__(self, staleness: int = 0):
        self.staleness = int(staleness)

    def init_state(self, capacity: int, d: int, dtype=jnp.float32) -> CacheState:
        return CacheState.create(capacity, d, dtype)

    @staticmethod
    def dtype_contract(base: Optional[DecodeBackend] = None) -> Dict[str, str]:
        """Cache-layer dtype contract: misses inherit the base backend's
        contract end to end; hits are served from ``CacheState.values``
        (stored in the model's compute dtype) — so a cached hit adds one
        compute-dtype round-trip on top of the base drift bound and nothing
        else.  Hit/miss select and all bookkeeping are dtype-free."""
        contract = {
            "backend": "cached",
            "storage": "CacheState.values in compute dtype (hits); "
                       "base backend storage (misses)",
            "compute": "base backend",
            "accumulate": "float32 (base backend)",
            "output": "float32",
        }
        if base is not None:
            contract["base"] = base.dtype_contract()["backend"]
        return contract

    def lookup(self, state: CacheState, ids: Array,
               decode_fn: Callable[[Array], Array],
               valid: Optional[Array] = None):
        """ids (U,) int32 -> ((U, d) embeddings, new CacheState).

        ``valid`` (U,) bool masks rows out of the cache entirely (they still
        decode, but never hit, never write, and don't count in the hit/miss
        accounting) — used for the frontier's jit-shape padding rows, which
        are duplicates of row 0."""
        C = state.capacity
        U = ids.shape[0]
        eq = ids[:, None] == state.node_ids[None, :]          # (U, C)
        found = eq.any(axis=1)
        if valid is not None:
            found = found & valid
        slot = jnp.argmax(eq, axis=1)                         # valid iff found
        age = state.version_counter - state.version[slot]
        hit = found & (age <= self.staleness)

        fresh = decode_fn(ids)                                # (U, d)
        out = jnp.where(hit[:, None], state.values[slot].astype(fresh.dtype),
                        fresh)

        # ---- state update (all scatters masked via index C + mode="drop")
        clock = state.clock + 1
        n_valid = (jnp.int32(U) if valid is None
                   else valid.sum(dtype=jnp.int32))
        n_hit = hit.sum(dtype=jnp.int32)

        # hits only refresh their LRU stamp
        hidx = jnp.where(hit, slot, C)
        last_used = state.last_used.at[hidx].set(clock, mode="drop")

        # misses write back: stale-but-present entries refresh in place,
        # absent ids take the least-recently-used unprotected slots.  Only
        # the first n_free absent misses get a slot — ranks past that would
        # reach into the protected suffix of evict_order and collide with a
        # found row's in-place refresh (two ids scattering to one slot).
        protected = jnp.zeros((C,), bool).at[jnp.where(found, slot, C)].set(
            True, mode="drop")
        n_free = C - protected.sum(dtype=jnp.int32)
        evict_order = jnp.argsort(
            jnp.where(protected, jnp.iinfo(jnp.int32).max, last_used))
        needs_slot = ~found
        if valid is not None:
            needs_slot = needs_slot & valid
        rank = jnp.cumsum(needs_slot.astype(jnp.int32)) - 1   # (U,)
        new_slot = evict_order[jnp.clip(rank, 0, C - 1)]
        write = (~hit) & (found | (needs_slot & (rank < n_free)))
        widx = jnp.where(write, jnp.where(found, slot, new_slot), C)

        wvals = jax.lax.stop_gradient(fresh).astype(state.values.dtype)
        new_state = CacheState(
            node_ids=state.node_ids.at[widx].set(ids, mode="drop"),
            values=state.values.at[widx].set(wvals, mode="drop"),
            version=state.version.at[widx].set(state.version_counter,
                                               mode="drop"),
            last_used=last_used.at[widx].set(clock, mode="drop"),
            version_counter=state.version_counter,
            clock=clock,
            hits=state.hits + n_hit,
            misses=state.misses + (n_valid - n_hit),
        )
        return out, new_state

    # -- miss-only decode (ROADMAP "Next": only misses enter the decoder) --
    @staticmethod
    def plan_missonly(cached_ids, ids, valid=None):
        """Host-side miss partition for ``lookup_missonly``.

        ``cached_ids`` is the host view of the cache's *fresh* entries
        (``np.asarray(state.node_ids)`` when nothing can be stale, e.g. at
        serving time where the version counter never moves; negative ids —
        empty slots — are ignored).  Returns ``(perm, n_miss)``: a stable
        permutation of ``ids`` placing every row that will miss (valid and
        not cached) first, and the count of such rows.  The caller permutes
        the frontier with ``perm`` (and its index maps with the inverse)
        and hands the decoder only a padded prefix."""
        import numpy as np
        ids = np.asarray(ids)
        if valid is None:
            valid = np.ones(ids.shape[0], bool)
        cached_ids = np.asarray(cached_ids)
        cached_ids = cached_ids[cached_ids >= 0]
        miss = np.asarray(valid, bool) & ~np.isin(ids, cached_ids)
        perm = np.argsort(~miss, kind="stable").astype(np.int32)
        return perm, int(miss.sum())

    def lookup_missonly(self, state: CacheState, ids: Array,
                        decode_fn: Callable[[Array], Array],
                        n_decode: int, valid: Optional[Array] = None):
        """Miss-only twin of ``lookup``: ``decode_fn`` runs ONLY on the
        first ``n_decode`` rows (a static int — shape-bucketed jit), so the
        decoder pays for misses instead of the whole frontier.

        Contract (kept by ``plan_missonly``): the caller permuted ``ids``
        miss-first, so every valid row at position >= ``n_decode`` is a
        fresh cache hit.  Prefix rows that turn out to be hits anyway (the
        miss-count padding) are still served from the cache, which keeps
        the output bitwise identical to ``lookup``; a *miss* past the
        prefix would read zeros — that is a planner bug, not a decode
        fallback.  State updates (write-back, LRU, accounting) are
        restricted to the decoded prefix."""
        C = state.capacity
        U = ids.shape[0]
        d = state.values.shape[1]
        eq = ids[:, None] == state.node_ids[None, :]          # (U, C)
        found = eq.any(axis=1)
        if valid is not None:
            found = found & valid
        slot = jnp.argmax(eq, axis=1)
        age = state.version_counter - state.version[slot]
        hit = found & (age <= self.staleness)

        if n_decode > 0:
            fresh_prefix = decode_fn(ids[:n_decode])          # (n_decode, d)
            fresh = jnp.zeros((U, d), fresh_prefix.dtype)
            fresh = fresh.at[:n_decode].set(fresh_prefix)
        else:
            fresh = jnp.zeros((U, d), state.values.dtype)
        out = jnp.where(hit[:, None], state.values[slot].astype(fresh.dtype),
                        fresh)

        # ---- state update: identical to ``lookup`` but writes only rows
        # the decoder actually produced (the prefix)
        decoded = jnp.arange(U, dtype=jnp.int32) < n_decode
        clock = state.clock + 1
        n_valid = (jnp.int32(U) if valid is None
                   else valid.sum(dtype=jnp.int32))
        n_hit = hit.sum(dtype=jnp.int32)

        hidx = jnp.where(hit, slot, C)
        last_used = state.last_used.at[hidx].set(clock, mode="drop")

        protected = jnp.zeros((C,), bool).at[jnp.where(found, slot, C)].set(
            True, mode="drop")
        n_free = C - protected.sum(dtype=jnp.int32)
        evict_order = jnp.argsort(
            jnp.where(protected, jnp.iinfo(jnp.int32).max, last_used))
        needs_slot = ~found & decoded
        if valid is not None:
            needs_slot = needs_slot & valid
        rank = jnp.cumsum(needs_slot.astype(jnp.int32)) - 1
        new_slot = evict_order[jnp.clip(rank, 0, C - 1)]
        write = (~hit) & decoded & (found | (needs_slot & (rank < n_free)))
        widx = jnp.where(write, jnp.where(found, slot, new_slot), C)

        wvals = jax.lax.stop_gradient(fresh).astype(state.values.dtype)
        new_state = CacheState(
            node_ids=state.node_ids.at[widx].set(ids, mode="drop"),
            values=state.values.at[widx].set(wvals, mode="drop"),
            version=state.version.at[widx].set(state.version_counter,
                                               mode="drop"),
            last_used=last_used.at[widx].set(clock, mode="drop"),
            version_counter=state.version_counter,
            clock=clock,
            hits=state.hits + n_hit,
            misses=state.misses + (n_valid - n_hit),
        )
        return out, new_state

    @staticmethod
    def bump_version(state: CacheState) -> CacheState:
        """Codebook/decoder update notification — call once per optimizer
        step that touches decoder parameters."""
        return dataclasses.replace(
            state, version_counter=state.version_counter + 1)


class HostCacheShadow:
    """Host-side numpy replica of the ``CacheState`` *bookkeeping* (never
    the values), used to plan miss-only decode for **training**.

    The training miss partition (``graph.engine.MissPlanningSource``) must
    know, while batch k+1 is still on the producer thread, which frontier
    ids will be fresh cache hits when the jitted step consumes it — i.e.
    after batch k's write-backs and version bump have landed on device.
    The cache bookkeeping (``node_ids`` / ``version`` / ``last_used`` /
    counters) depends only on the ``(ids, valid, n_decode)`` sequence,
    never on decoded values, so a host replica fed the same per-step inputs
    tracks the device cache *exactly*: ``update`` mirrors
    ``CachedDecodeBackend.lookup_missonly``'s state update line for line
    (same stable argsort, same protected / rank < n_free slot assignment)
    followed by the train step's ``bump_version``.

    Prediction safety is one-sided.  A predicted miss that turns out to hit
    is harmless — ``lookup_missonly`` serves prefix hits from the cache; a
    predicted hit that actually misses reads zeros.  ``clear()`` therefore
    resets to the empty shadow (plans *everything* as a miss: slower, never
    wrong), and ``sync_from_cache_state`` re-anchors an out-of-sync shadow
    to a restored device cache on checkpoint resume.
    """

    _EMPTY = np.iinfo(np.int32).min // 2   # matches CacheState.create

    def __init__(self, capacity: int, staleness: int = 0):
        self.capacity = int(capacity)
        self.staleness = int(staleness)
        self.clear()

    def clear(self) -> None:
        C = self.capacity
        self.node_ids = np.full((C,), -1, np.int32)
        self.version = np.full((C,), self._EMPTY, np.int32)
        self.last_used = np.full((C,), self._EMPTY, np.int32)
        self.version_counter = 0
        self.clock = 0

    # -- (de)serialisation ----------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """JSON-friendly copy (checkpointable alongside the source state)."""
        return {
            "capacity": self.capacity, "staleness": self.staleness,
            "node_ids": self.node_ids.tolist(),
            "version": self.version.tolist(),
            "last_used": self.last_used.tolist(),
            "version_counter": int(self.version_counter),
            "clock": int(self.clock),
        }

    def restore(self, snap: Dict[str, Any]) -> None:
        if int(snap["capacity"]) != self.capacity:
            raise ValueError(
                f"shadow snapshot capacity {snap['capacity']} != {self.capacity}")
        self.staleness = int(snap["staleness"])
        self.node_ids = np.asarray(snap["node_ids"], np.int32).copy()
        self.version = np.asarray(snap["version"], np.int32).copy()
        self.last_used = np.asarray(snap["last_used"], np.int32).copy()
        self.version_counter = int(snap["version_counter"])
        self.clock = int(snap["clock"])

    def sync_from_cache_state(self, state: CacheState) -> None:
        """Re-anchor to a device cache (exact: same fields, host copies)."""
        self.node_ids = np.asarray(state.node_ids, np.int32).copy()
        self.version = np.asarray(state.version, np.int32).copy()
        self.last_used = np.asarray(state.last_used, np.int32).copy()
        self.version_counter = int(state.version_counter)
        self.clock = int(state.clock)

    # -- planning --------------------------------------------------------
    def fresh_ids(self) -> np.ndarray:
        """Ids whose cached entry will still be within the staleness budget
        at the next lookup (the shadow is post-bump, like the device)."""
        live = self.node_ids >= 0
        fresh = (self.version_counter - self.version) <= self.staleness
        return self.node_ids[live & fresh]

    def plan(self, ids: np.ndarray, valid: np.ndarray):
        """``(perm, n_miss)`` for the next batch — ``plan_missonly``
        against the *fresh* (not merely present) shadow entries."""
        return CachedDecodeBackend.plan_missonly(self.fresh_ids(), ids, valid)

    # -- state transition ------------------------------------------------
    def update(self, ids: np.ndarray, valid: np.ndarray, n_decode: int) -> None:
        """Replay one training step's cache transition: the bookkeeping of
        ``lookup_missonly(ids, ..., n_decode, valid)`` plus the optimizer
        ``bump_version``.  ``ids``/``valid`` must be the *permuted* arrays
        the device step will see."""
        C = self.capacity
        ids = np.asarray(ids, np.int32)
        valid = np.asarray(valid, bool)
        U = ids.shape[0]
        eq = ids[:, None] == self.node_ids[None, :]            # (U, C)
        found = eq.any(axis=1) & valid
        slot = eq.argmax(axis=1)
        age = self.version_counter - self.version[slot]
        hit = found & (age <= self.staleness)
        decoded = np.arange(U) < int(n_decode)

        self.clock += 1
        last_used = self.last_used.copy()
        last_used[slot[hit]] = self.clock                      # hit refresh

        protected = np.zeros((C,), bool)
        protected[slot[found]] = True
        n_free = C - int(protected.sum())
        # device argsort (jnp) is stable — kind="stable" keeps slot
        # assignment bit-identical through the INT32_MAX / empty-slot ties
        evict_order = np.argsort(
            np.where(protected, np.iinfo(np.int32).max, last_used),
            kind="stable")
        needs_slot = ~found & decoded & valid
        rank = np.cumsum(needs_slot) - 1
        new_slot = evict_order[np.clip(rank, 0, C - 1)]
        write = (~hit) & decoded & (found | (needs_slot & (rank < n_free)))
        widx = np.where(found, slot, new_slot)

        w = widx[write]
        self.node_ids[w] = ids[write]
        self.version[w] = self.version_counter
        last_used[w] = self.clock
        self.last_used = last_used
        self.version_counter += 1                              # bump_version
