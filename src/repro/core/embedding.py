"""Drop-in embedding layer with optional hash compression (paper §4).

``EmbeddingConfig.kind`` selects:
  dense         — conventional trainable table (the paper's NC baseline)
  hash_full     — LSH codes + full decoder (trainable codebooks)
  hash_light    — LSH codes + light decoder (frozen codebooks + W0)
  random_full   — ALONE random codes + full decoder (paper's Rand baseline)
  random_light  — ALONE random codes + light decoder

For compressed kinds the per-entity state is a packed uint32 code row
(non-trainable ``codes_buf``); the decoder parameters are shared by all
entities, so total trainable state is independent of ``n_entities``.

Orthogonally, ``lookup_impl`` may select an alternate *compression family*
(``core.backend.family_of``; see core/decoder.py and
docs/decode_backends.md): ``"hashemb"`` replaces the stored codes with
per-lookup position hashes (``needs_codes`` is False — NO ``codes_buf``
exists, id-side memory is zero) and ``"tt"`` keeps the codes but factorizes
the codebook into a TT core pair.  Switching family is a one-field change;
kind (dense/hash/random) and variant (full/light) compose unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core import codes as codes_lib
from repro.core import lsh
from repro.core.decoder import DecoderConfig, apply_decoder, init_decoder
from repro.nn import module as nn
from repro.parallel import sharding

Array = jnp.ndarray

COMPRESSED_KINDS = ("hash_full", "hash_light", "random_full", "random_light")


@dataclasses.dataclass(frozen=True)
class EmbeddingConfig:
    kind: str                 # dense | hash_full | hash_light | random_full | random_light
    n_entities: int
    d_e: int
    c: int = 256
    m: int = 16
    d_c: int = 512
    d_m: int = 512
    n_layers: int = 3
    lookup_impl: str = "onehot"   # decode backend name or "auto" (core.backend)
    compute_dtype: str = "bfloat16"
    # Decode precision (core.backend.MixedPrecisionPolicy): codebook/w0
    # storage dtype (None = compute_dtype) and absmax-int8 quantization with
    # dequant fused into the decode ("none" | "int8"); compressed kinds only.
    param_dtype: Optional[str] = None
    quantize: str = "none"
    # Algorithm-1 encoding knobs (hash kinds only): "median" is the paper's
    # threshold, "zero" the Charikar-LSH baseline (Fig. 3); hops>1 pushes the
    # projection through the graph k times (§6.1 higher-order adjacency).
    threshold: str = "median"
    hops: int = 1
    # Hot-node decode cache (CachedDecodeBackend): capacity 0 disables it;
    # staleness is the number of codebook versions a cached embedding may
    # lag behind (0 = always re-decode, bit-identical to uncached).
    cache_capacity: int = 0
    cache_staleness: int = 0
    # TT rank r of the "tt" compression family (ignored by the others).
    tt_rank: int = 8
    # Codes placement: "device" stores the packed ``codes_buf`` in params
    # (replicated in HBM); "host" keeps it off-device — ``init_embedding``
    # creates no ``codes_buf`` and every lookup must be handed the frontier's
    # packed rows via ``embed_lookup(..., codes=...)`` (gathered on the host
    # by the batch source / prefetch producer).  Same numerics either way.
    codes_placement: str = "device"

    @property
    def is_compressed(self) -> bool:
        return self.kind in COMPRESSED_KINDS

    @property
    def family(self) -> str:
        """Compression family selected by ``lookup_impl`` (core.backend):
        "paper" (stored bit codes), "hashemb", or "tt"."""
        from repro.core.backend import family_of
        return family_of(self.lookup_impl)

    @property
    def needs_codes(self) -> bool:
        """Whether this config stores a per-entity ``codes_buf``.  The
        ``hashemb`` family recomputes position hashes from the id at lookup
        time, so it needs none — call-sites that build/checkpoint codes
        (graph runtime, LM init) gate on this, not ``is_compressed``."""
        return self.is_compressed and self.family != "hashemb"

    @property
    def codes_on_host(self) -> bool:
        """True when the codes exist but live in host RAM (no device
        ``codes_buf``): lookups consume batch-carried packed rows."""
        return self.needs_codes and self.codes_placement == "host"

    def decoder_config(self) -> DecoderConfig:
        variant = "light" if self.kind.endswith("light") else "full"
        return DecoderConfig(
            c=self.c, m=self.m, d_c=self.d_c, d_m=self.d_m, d_e=self.d_e,
            n_layers=self.n_layers, variant=variant,
            lookup_impl=self.lookup_impl, compute_dtype=self.compute_dtype,
            param_dtype=self.param_dtype, quantize=self.quantize,
            tt_rank=self.tt_rank,
        )


def make_codes(
    key: jax.Array,
    cfg: EmbeddingConfig,
    aux: Optional[Union[Array, "object"]] = None,
) -> Array:
    """Encoding stage.  ``aux`` is the auxiliary matrix A (dense or CSR) for
    hash kinds; ignored for random kinds."""
    if cfg.kind.startswith("hash"):
        if aux is None:
            raise ValueError(
                "hash embedding kinds need auxiliary information (adjacency, "
                "co-occurrence or pre-trained embeddings); got aux=None"
            )
        if aux.shape[0] != cfg.n_entities:
            raise ValueError(f"aux rows {aux.shape[0]} != n_entities {cfg.n_entities}")
        return lsh.encode_lsh(key, aux, cfg.c, cfg.m,
                              threshold=cfg.threshold, hops=cfg.hops)
    return lsh.encode_random(key, cfg.n_entities, cfg.c, cfg.m)


def init_embedding(
    key: jax.Array,
    cfg: EmbeddingConfig,
    codes: Optional[Array] = None,
    aux=None,
) -> nn.Params:
    if cfg.codes_placement not in ("device", "host"):
        raise ValueError(
            f"unknown codes_placement {cfg.codes_placement!r} "
            f"(expected 'device' or 'host')")
    if cfg.kind == "dense":
        return {"table": nn.embed_init(key, (cfg.n_entities, cfg.d_e))}
    if not cfg.is_compressed:
        raise ValueError(f"unknown embedding kind {cfg.kind!r}")
    k_code, k_dec = jax.random.split(key)
    if not cfg.needs_codes or cfg.codes_on_host:
        # hashemb family: codes are position hashes recomputed per lookup —
        # the only per-entity state would be the ids themselves.
        # codes_placement="host": the full buffer stays in host RAM (owned by
        # the runtime / batch source), so params carry only the decoder.
        return {"decoder": init_decoder(k_dec, cfg.decoder_config())}
    if codes is None:
        codes = make_codes(k_code, cfg, aux)
    expected = (cfg.n_entities, codes_lib.n_words(cfg.c, cfg.m))
    if tuple(codes.shape) != expected:
        raise ValueError(f"codes shape {tuple(codes.shape)} != {expected}")
    codes_buf = sharding.logical(jnp.asarray(codes, jnp.uint32), "entities", None)
    return {
        "codes_buf": codes_buf,
        "decoder": init_decoder(k_dec, cfg.decoder_config()),
    }


def embed_lookup(
    params: nn.Params,
    ids: Array,
    cfg: EmbeddingConfig,
    *,
    interpret: bool = False,
    backend=None,
    plan=None,
    codes: Optional[Array] = None,
) -> Array:
    """ids (...,) int32 -> embeddings (..., d_e).  ``backend`` is an optional
    resolved ``DecodeBackend`` overriding ``cfg.lookup_impl``; ``plan`` an
    optional ``graph.sampler.OwnerPlan`` for the owner-computes cross-shard
    decode (only meaningful for flat frontier ids on a collective backend).

    ``codes`` is the pre-gathered packed rows for ``ids`` — shape
    ``ids.shape + (n_words,)`` uint32, the ``codes_buf[ids]`` gather done on
    the host.  Required when ``cfg.codes_on_host`` (params then carry no
    ``codes_buf``); when provided it substitutes the device-side
    ``jnp.take`` bit-for-bit, so both placements decode identically."""
    if cfg.kind == "dense":
        table = params["table"].astype(jnp.dtype(cfg.compute_dtype))
        return table[ids]
    if not cfg.needs_codes:        # hashemb: hash the ids, no stored codes
        flat = jnp.reshape(ids, (-1,))
        unpacked = codes_lib.position_codes(flat, cfg.c, cfg.m).reshape(
            *jnp.shape(ids), cfg.m)
    else:
        if codes is not None:
            packed = codes                                    # (..., n_words)
        elif "codes_buf" in params:
            packed = jnp.take(params["codes_buf"], ids, axis=0)
        else:
            raise ValueError(
                "embed_lookup: params carry no codes_buf and no batch codes "
                "were passed — with codes_placement='host' every lookup must "
                "receive the frontier's packed rows via codes=...")
        unpacked = codes_lib.unpack_codes(packed, cfg.c, cfg.m)   # (..., m)
    return apply_decoder(params["decoder"], unpacked, cfg.decoder_config(),
                         interpret=interpret, backend=backend, plan=plan)


def decode_all(params: nn.Params, cfg: EmbeddingConfig, block: int = 8192,
               host_codes: Optional[Array] = None) -> Array:
    """Materialise the full reconstructed table (used by reconstruction
    benchmarks and full-graph GNNs).  Blocked to bound peak memory.
    ``host_codes`` is the full packed buffer when ``cfg.codes_on_host``
    (each block's rows are staged to the device on demand)."""
    if cfg.kind == "dense":
        return params["table"]
    n = cfg.n_entities
    outs = []
    if cfg.codes_on_host:
        if host_codes is None:
            raise ValueError("decode_all: codes_placement='host' needs "
                             "host_codes (the full packed buffer)")
        fn = jax.jit(lambda p, i, c: embed_lookup(p, i, cfg, codes=c))
        for s in range(0, n, block):
            e = min(s + block, n)
            ids = jnp.arange(s, e, dtype=jnp.int32)
            rows = jnp.asarray(host_codes[s:e], jnp.uint32)
            outs.append(fn(params, ids, rows))
        return jnp.concatenate(outs, axis=0)
    fn = jax.jit(lambda p, i: embed_lookup(p, i, cfg))
    for s in range(0, n, block):
        ids = jnp.arange(s, min(s + block, n), dtype=jnp.int32)
        outs.append(fn(params, ids))
    return jnp.concatenate(outs, axis=0)
