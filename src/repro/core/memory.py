"""Closed-form memory / compression-ratio calculators (paper Tables 2, 4, 6).

Validation discovery (recorded in EXPERIMENTS.md §Faithfulness): the paper's
*reported* numbers in Tables 2/4/6 correspond to a decoder whose MLP has two
linear layers (d_c→d_m→d_e), i.e. the §3.2 formula with the ``(l−2)·d_m²``
term equal to zero, while §B.2/§C.1 state l=3.  Both conventions are
implemented; ``paper_table_convention=True`` reproduces every published
number exactly (verified in tests/test_memory.py to ±0.01):

  Table 4 GloVe@5000 → 2.65        Table 4 GloVe@200000 → 44.55
  Table 6 GloVe c=256,m=16@5000 → 0.59
  Table 2 binary code 28.55 MiB, light decoder 1.13 MiB, full 9.13 MiB,
          GPU-only ratio 43.75.

Role in the system (docs/architecture.md): the closed-form side of every
memory claim — ``benchmarks/table2_4_6_memory.py`` prints these exactly,
and the per-family decode-stage accounting used by the quality-vs-memory
sweep (``benchmarks/compression_sweep.py``, ``BENCH_compression.json``)
lives on ``DecoderConfig.trainable_params()`` next door in ``decoder.py``
(docs/decode_backends.md §Compression families).
"""

from __future__ import annotations

import dataclasses

MiB = float(1 << 20)
F32 = 4  # bytes


@dataclasses.dataclass(frozen=True)
class MemoryBreakdown:
    binary_code_bytes: float
    frozen_decoder_bytes: float     # light codebooks (CPU-resident in Table 2)
    trainable_decoder_bytes: float  # GPU-resident decoder params
    raw_table_bytes: float

    @property
    def compressed_total(self) -> float:
        return self.binary_code_bytes + self.frozen_decoder_bytes + self.trainable_decoder_bytes

    @property
    def ratio_total(self) -> float:
        return self.raw_table_bytes / self.compressed_total

    @property
    def ratio_gpu(self) -> float:
        """Table 2's 'GPU only' ratio: raw table vs trainable decoder."""
        return self.raw_table_bytes / self.trainable_decoder_bytes


def decoder_param_counts(
    c: int, m: int, d_c: int, d_m: int, d_e: int, l: int,
    variant: str = "full",
    paper_table_convention: bool = False,
):
    """(trainable, frozen) parameter counts.

    paper_table_convention drops the (l-2)*d_m^2 hidden-hidden term —
    matching every number published in Tables 2/4/6."""
    hidden = 0 if paper_table_convention else max(l - 2, 0) * d_m * d_m
    mlp = d_c * d_e if l == 1 else d_c * d_m + hidden + d_m * d_e
    if variant == "light":
        return d_c + mlp, m * c * d_c
    if variant == "full":
        return m * c * d_c + mlp, 0
    raise ValueError(variant)


def memory_breakdown(
    n: int, d_e: int, c: int, m: int, d_c: int, d_m: int, l: int,
    variant: str = "full",
    paper_table_convention: bool = True,
) -> MemoryBreakdown:
    from repro.core.codes import n_bits

    code_bytes = n * n_bits(c, m) / 8.0
    trainable, frozen = decoder_param_counts(
        c, m, d_c, d_m, d_e, l, variant, paper_table_convention
    )
    return MemoryBreakdown(
        binary_code_bytes=code_bytes,
        frozen_decoder_bytes=frozen * F32,
        trainable_decoder_bytes=trainable * F32,
        raw_table_bytes=float(n) * d_e * F32,
    )


def compression_ratio(
    n: int, d_e: int, c: int, m: int,
    d_c: int = 512, d_m: int = 512, l: int = 3,
    paper_table_convention: bool = True,
) -> float:
    """Tables 4/5/6 ratio: raw / (codes + full decoder)."""
    b = memory_breakdown(n, d_e, c, m, d_c, d_m, l, "full", paper_table_convention)
    return b.ratio_total


# ---- published reference values (used by tests + benchmarks) -------------

PAPER_TABLE4_GLOVE = {5000: 2.65, 10000: 5.11, 25000: 11.60, 50000: 20.09,
                      100000: 31.69, 200000: 44.55}
PAPER_TABLE4_M2V = {5000: 1.34, 10000: 2.57, 25000: 5.73, 50000: 9.72,
                    100000: 14.91, 200000: 20.34}
# Table 6: (c, m) -> {n: ratio}
PAPER_TABLE6_GLOVE = {
    (2, 128): {5000: 2.65, 10000: 5.11, 50000: 20.09, 200000: 44.55},
    (4, 64): {5000: 2.65, 10000: 5.11, 50000: 20.09, 200000: 44.55},
    (16, 32): {5000: 2.15, 10000: 4.18, 50000: 17.09, 200000: 40.60},
    (256, 16): {5000: 0.59, 10000: 1.18, 50000: 5.53, 200000: 18.11},
}
PAPER_TABLE6_M2V = {
    (2, 128): {5000: 1.34, 10000: 2.57, 50000: 9.72, 200000: 20.34},
    (4, 64): {5000: 1.34, 10000: 2.57, 50000: 9.72, 200000: 20.34},
    (16, 32): {5000: 1.05, 10000: 2.03, 50000: 8.10, 200000: 18.42},
    (256, 16): {5000: 0.26, 10000: 0.52, 50000: 2.44, 200000: 7.94},
}
# Table 2 (ogbn-products, n=1,871,031, d_e=64, c=256, m=16, d_c=d_m=512):
PAPER_TABLE2 = {
    "n": 1_871_031, "d_e": 64,
    "raw_gpu_mib": 456.79,
    "binary_code_mib": 28.55,
    "light_decoder_gpu_mib": 1.13,
    "full_decoder_gpu_mib": 9.13,
    "light_codebooks_cpu_mib": 8.00,
    "full_ratio_gpu": 43.75,   # (456.79 + 1.35 GNN) / (9.13 + 1.35 GNN)
    "gnn_mib": 1.35,
}
