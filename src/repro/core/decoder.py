"""Decoder model (paper §3.2, Figure 2).

codes (B, m) ints in [0, c)
  -> retrieve one vector per codebook (m codebooks, each (c, d_c))
  -> sum the m vectors
  -> light variant: elementwise-rescale by trainable W0 (codebooks frozen)
     full  variant: no W0 (codebooks trainable)
  -> l-layer MLP with ReLU between linear layers: d_c -> d_m -> ... -> d_e

TPU adaptation (DESIGN.md §3): the codebook retrieval + W0 scale is a
``repro.core.backend.DecodeBackend`` selected by ``lookup_impl`` ("gather" |
"onehot" | "pallas" | "auto"); see that module for the implementations and
the registration hook for new ones.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.backend import DecodeBackend, get_backend
from repro.nn import module as nn
from repro.parallel import sharding

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class DecoderConfig:
    c: int = 256           # code cardinality
    m: int = 16            # code length
    d_c: int = 512         # codebook vector dim
    d_m: int = 512         # MLP hidden dim
    d_e: int = 64          # output embedding dim
    n_layers: int = 3      # number of linear layers (paper's l)
    variant: str = "full"  # "full" (trainable codebooks) | "light" (frozen + W0)
    lookup_impl: str = "onehot"  # "gather" | "onehot" | "pallas" | "auto"
    compute_dtype: str = "bfloat16"
    # Decode precision knobs (core.backend.MixedPrecisionPolicy): storage
    # dtype of codebooks/w0 entering the decode (None = compute_dtype) and
    # optional absmax-int8 codebook quantization with fused dequant.
    param_dtype: Optional[str] = None
    quantize: str = "none"     # "none" | "int8"

    def precision_policy(self) -> "MixedPrecisionPolicy":
        from repro.core.backend import MixedPrecisionPolicy
        return MixedPrecisionPolicy(
            param_dtype=self.param_dtype or self.compute_dtype,
            compute_dtype=self.compute_dtype,
            reduce_dtype="float32",
            quantize=self.quantize,
        )

    def trainable_params(self) -> int:
        """Paper §3.2 closed-form trainable-parameter count."""
        mlp = self.d_c * self.d_m + max(self.n_layers - 2, 0) * self.d_m**2 + self.d_m * self.d_e
        if self.n_layers == 1:
            mlp = self.d_c * self.d_e
        if self.variant == "light":
            return self.d_c + mlp
        return self.m * self.c * self.d_c + mlp

    def frozen_params(self) -> int:
        return self.m * self.c * self.d_c if self.variant == "light" else 0


def _mlp_dims(cfg: DecoderConfig):
    if cfg.n_layers == 1:
        return [(cfg.d_c, cfg.d_e)]
    dims = [(cfg.d_c, cfg.d_m)]
    dims += [(cfg.d_m, cfg.d_m)] * (cfg.n_layers - 2)
    dims += [(cfg.d_m, cfg.d_e)]
    return dims


def init_decoder(key: jax.Array, cfg: DecoderConfig) -> nn.Params:
    ks = nn.split_keys(key, ["codebooks", "w0", "mlp"])
    params: nn.Params = {}
    cb = nn.dense_init(ks["codebooks"], (cfg.m, cfg.c, cfg.d_c), scale=1.0 / jnp.sqrt(cfg.m))
    cb = sharding.logical(cb, None, None, "codebook")
    if cfg.variant == "light":
        params["codebooks_buf"] = cb           # frozen (stored off-accelerator in Table 2)
        params["w0"] = jnp.ones((cfg.d_c,), jnp.float32)
    elif cfg.variant == "full":
        params["codebooks"] = cb
    else:
        raise ValueError(f"unknown decoder variant {cfg.variant!r}")
    mlp_keys = jax.random.split(ks["mlp"], cfg.n_layers)
    params["mlp"] = {
        f"w{i}": nn.dense_init(mlp_keys[i], dims)
        for i, dims in enumerate(_mlp_dims(cfg))
    }
    params["mlp"].update(
        {f"b{i}": jnp.zeros((dims[1],), jnp.float32) for i, dims in enumerate(_mlp_dims(cfg))}
    )
    return params


def apply_decoder(
    params: nn.Params,
    codes: Array,
    cfg: DecoderConfig,
    *,
    interpret: bool = False,
    backend: Optional[DecodeBackend] = None,
    plan=None,
) -> Array:
    """codes (..., m) int32 -> embeddings (..., d_e).

    ``backend`` overrides the config's ``lookup_impl`` (call-sites that hold
    a resolved backend — the graph engine, benchmarks — pass it straight
    through instead of re-resolving per call).  ``plan`` is an optional
    ``graph.sampler.OwnerPlan`` for the owner-computes cross-shard decode;
    backends that can't exploit it ignore it (decoding every row is always
    correct)."""
    lead = codes.shape[:-1]
    codes2d = codes.reshape(-1, cfg.m)
    dtype = jnp.dtype(cfg.compute_dtype)
    policy = cfg.precision_policy()
    pdtype = jnp.dtype(policy.param_dtype)

    cb = params["codebooks_buf"] if cfg.variant == "light" else params["codebooks"]
    cb = cb.astype(pdtype)
    w0 = params["w0"].astype(pdtype) if cfg.variant == "light" else None

    be = backend if backend is not None else get_backend(
        cfg.lookup_impl, interpret=interpret, policy=policy)
    if plan is not None and hasattr(be, "decode_frontier"):
        h = be.decode_frontier(codes2d, cb, w0, plan=plan).astype(dtype)
    else:
        h = be.decode(codes2d, cb, w0).astype(dtype)

    mlp = params["mlp"]
    for i in range(cfg.n_layers):
        h = h @ mlp[f"w{i}"].astype(dtype) + mlp[f"b{i}"].astype(dtype)
        if i < cfg.n_layers - 1:
            h = jax.nn.relu(h)
    return h.reshape(*lead, cfg.d_e)
