"""Decoder model (paper §3.2, Figure 2).

codes (B, m) ints in [0, c)
  -> retrieve one vector per codebook (m codebooks, each (c, d_c))
  -> sum the m vectors
  -> light variant: elementwise-rescale by trainable W0 (codebooks frozen)
     full  variant: no W0 (codebooks trainable)
  -> l-layer MLP with ReLU between linear layers: d_c -> d_m -> ... -> d_e

TPU adaptation (DESIGN.md §3): the codebook retrieval + W0 scale is a
``repro.core.backend.DecodeBackend`` selected by ``lookup_impl`` ("gather" |
"onehot" | "pallas" | "auto"); see that module for the implementations and
the registration hook for new ones.

``lookup_impl`` also selects the *compression family* — how the decode-stage
parameters are laid out (``core.backend.family_of``, docs/decode_backends.md
§Compression families):

  paper    (default) m dense codebooks ``(m, c, d_c)``, the scheme above.
  hashemb  shared pools ``(m, c, d_c)`` + per-position weights ``wpos
           (m, d_c)`` (arXiv:2109.00101).  ``apply_decoder`` folds ``wpos``
           into the pools before the decode (exact:
           ``sum_j (wpos[j]*P[j])[h_j] == sum_j wpos[j]*P[j][h_j]``), so any
           base backend serves the gather.  light = frozen ``pools_buf`` +
           trainable ``wpos``.
  tt       TT core pair ``tt_g0 (m, c1, d1, r)`` / ``tt_g1 (m, c2, r, d2)``
           with ``c = c1*c2``, ``d_c = d1*d2`` (Nimble GNN,
           arXiv:2206.10581); the rank-``tt_rank`` contraction is fused into
           ``TTBackend.decode``.  light = frozen ``tt_g0_buf``/``tt_g1_buf``
           + trainable ``w0``.

Codes placement is invisible here: every backend consumes *unpacked* codes
``(B, m)``, and whether those came from a device-resident ``codes_buf``
gather or from batch-carried rows (``codes_placement="host"``, see
``core.embedding.embed_lookup``) the bit pattern entering ``apply_decoder``
is identical — which is why host offload is bitwise-exact on every backend.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.backend import DecodeBackend, family_of, get_backend, \
    tt_factor_pair
from repro.nn import module as nn
from repro.parallel import sharding

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class DecoderConfig:
    c: int = 256           # code cardinality
    m: int = 16            # code length
    d_c: int = 512         # codebook vector dim
    d_m: int = 512         # MLP hidden dim
    d_e: int = 64          # output embedding dim
    n_layers: int = 3      # number of linear layers (paper's l)
    variant: str = "full"  # "full" (trainable codebooks) | "light" (frozen + W0)
    lookup_impl: str = "onehot"  # backend name, may select a family (see above)
    compute_dtype: str = "bfloat16"
    # Decode precision knobs (core.backend.MixedPrecisionPolicy): storage
    # dtype of codebooks/w0 entering the decode (None = compute_dtype) and
    # optional absmax-int8 codebook quantization with fused dequant.
    param_dtype: Optional[str] = None
    quantize: str = "none"     # "none" | "int8"
    tt_rank: int = 8           # TT rank r ("tt" family only)

    @property
    def family(self) -> str:
        return family_of(self.lookup_impl)

    def tt_dims(self) -> Tuple[int, int, int, int]:
        """(c1, c2, d1, d2): the balanced code/feature splits of the ``tt``
        family's core pair."""
        c1, c2 = tt_factor_pair(self.c)
        d1, d2 = tt_factor_pair(self.d_c)
        return c1, c2, d1, d2

    def precision_policy(self) -> "MixedPrecisionPolicy":
        from repro.core.backend import MixedPrecisionPolicy
        return MixedPrecisionPolicy(
            param_dtype=self.param_dtype or self.compute_dtype,
            compute_dtype=self.compute_dtype,
            reduce_dtype="float32",
            quantize=self.quantize,
        )

    def _decode_stage_params(self) -> int:
        """Parameter count of the decode-stage table (family-dependent)."""
        if self.family == "tt":
            c1, c2, d1, d2 = self.tt_dims()
            return self.m * self.tt_rank * (c1 * d1 + c2 * d2)
        return self.m * self.c * self.d_c    # paper codebooks / hashemb pools

    def trainable_params(self) -> int:
        """Closed-form trainable-parameter count (paper §3.2, extended to
        the alternate families); matches ``nn.param_count(params, True)``."""
        mlp = self.d_c * self.d_m + max(self.n_layers - 2, 0) * self.d_m**2 + self.d_m * self.d_e
        if self.n_layers == 1:
            mlp = self.d_c * self.d_e
        fam = self.family
        if fam == "hashemb":
            wpos = self.m * self.d_c
            if self.variant == "light":
                return wpos + mlp
            return self._decode_stage_params() + wpos + mlp
        if self.variant == "light":
            return self.d_c + mlp
        return self._decode_stage_params() + mlp

    def frozen_params(self) -> int:
        return self._decode_stage_params() if self.variant == "light" else 0


def _mlp_dims(cfg: DecoderConfig):
    if cfg.n_layers == 1:
        return [(cfg.d_c, cfg.d_e)]
    dims = [(cfg.d_c, cfg.d_m)]
    dims += [(cfg.d_m, cfg.d_m)] * (cfg.n_layers - 2)
    dims += [(cfg.d_m, cfg.d_e)]
    return dims


def _init_decode_stage(ks, cfg: DecoderConfig) -> nn.Params:
    """Family-dependent decode-stage parameters (the ``light`` variant
    freezes the table via the ``_buf`` key convention and trains only the
    small rescale: ``w0`` / ``wpos``)."""
    if cfg.variant not in ("light", "full"):
        raise ValueError(f"unknown decoder variant {cfg.variant!r}")
    light = cfg.variant == "light"
    params: nn.Params = {}
    if cfg.family == "hashemb":
        pools = nn.dense_init(ks["codebooks"], (cfg.m, cfg.c, cfg.d_c),
                              scale=1.0 / jnp.sqrt(cfg.m))
        params["pools_buf" if light else "pools"] = sharding.logical(
            pools, None, None, "codebook")
        # wpos = 1 makes the init decode the plain pool sum (same
        # distribution as the paper codebooks); always trainable — in the
        # light variant it IS the per-position W0 analogue
        params["wpos"] = jnp.ones((cfg.m, cfg.d_c), jnp.float32)
        return params
    if cfg.family == "tt":
        c1, c2, d1, d2 = cfg.tt_dims()
        r = cfg.tt_rank
        # materialized entries are sums of r products of two core factors;
        # factor std s gives entry var ~ r*s^4, so s = (m*r)^(-1/4) matches
        # the paper codebooks' 1/sqrt(m) entry scale
        s = float((cfg.m * r) ** -0.25)
        k0, k1 = jax.random.split(ks["codebooks"])
        g0 = nn.dense_init(k0, (cfg.m, c1, d1, r), scale=s)
        g1 = nn.dense_init(k1, (cfg.m, c2, r, d2), scale=s)
        params["tt_g0_buf" if light else "tt_g0"] = sharding.logical(
            g0, None, None, "codebook", None)
        params["tt_g1_buf" if light else "tt_g1"] = sharding.logical(
            g1, None, None, None, "codebook")
        if light:
            params["w0"] = jnp.ones((cfg.d_c,), jnp.float32)
        return params
    cb = nn.dense_init(ks["codebooks"], (cfg.m, cfg.c, cfg.d_c), scale=1.0 / jnp.sqrt(cfg.m))
    cb = sharding.logical(cb, None, None, "codebook")
    if light:
        params["codebooks_buf"] = cb           # frozen (stored off-accelerator in Table 2)
        params["w0"] = jnp.ones((cfg.d_c,), jnp.float32)
    else:
        params["codebooks"] = cb
    return params


def _decode_stage_operands(params: nn.Params, cfg: DecoderConfig, pdtype):
    """Extract the backend's ``(codebooks, w0)`` operands from the params,
    cast to the policy's storage dtype.  hashemb folds ``wpos`` into the
    pools here (exact in f32, differentiable to both factors), so every
    backend sees the standard dense layout; tt hands the core pair through
    as a pytree."""
    light = cfg.variant == "light"
    if cfg.family == "hashemb":
        pools = params["pools_buf" if light else "pools"]
        cb = (pools.astype(jnp.float32)
              * params["wpos"].astype(jnp.float32)[:, None, :]).astype(pdtype)
        return cb, None
    if cfg.family == "tt":
        cb = (params["tt_g0_buf" if light else "tt_g0"].astype(pdtype),
              params["tt_g1_buf" if light else "tt_g1"].astype(pdtype))
        w0 = params["w0"].astype(pdtype) if light else None
        return cb, w0
    cb = params["codebooks_buf" if light else "codebooks"].astype(pdtype)
    w0 = params["w0"].astype(pdtype) if light else None
    return cb, w0


def init_decoder(key: jax.Array, cfg: DecoderConfig) -> nn.Params:
    ks = nn.split_keys(key, ["codebooks", "w0", "mlp"])
    params = _init_decode_stage(ks, cfg)
    mlp_keys = jax.random.split(ks["mlp"], cfg.n_layers)
    params["mlp"] = {
        f"w{i}": nn.dense_init(mlp_keys[i], dims)
        for i, dims in enumerate(_mlp_dims(cfg))
    }
    params["mlp"].update(
        {f"b{i}": jnp.zeros((dims[1],), jnp.float32) for i, dims in enumerate(_mlp_dims(cfg))}
    )
    return params


def apply_decoder(
    params: nn.Params,
    codes: Array,
    cfg: DecoderConfig,
    *,
    interpret: bool = False,
    backend: Optional[DecodeBackend] = None,
    plan=None,
) -> Array:
    """codes (..., m) int32 -> embeddings (..., d_e).

    ``backend`` overrides the config's ``lookup_impl`` (call-sites that hold
    a resolved backend — the graph engine, benchmarks — pass it straight
    through instead of re-resolving per call).  ``plan`` is an optional
    ``graph.sampler.OwnerPlan`` for the owner-computes cross-shard decode;
    backends that can't exploit it ignore it (decoding every row is always
    correct)."""
    lead = codes.shape[:-1]
    codes2d = codes.reshape(-1, cfg.m)
    dtype = jnp.dtype(cfg.compute_dtype)
    policy = cfg.precision_policy()
    pdtype = jnp.dtype(policy.param_dtype)

    cb, w0 = _decode_stage_operands(params, cfg, pdtype)

    be = backend if backend is not None else get_backend(
        cfg.lookup_impl, interpret=interpret, policy=policy)
    if plan is not None and hasattr(be, "decode_frontier"):
        h = be.decode_frontier(codes2d, cb, w0, plan=plan).astype(dtype)
    else:
        h = be.decode(codes2d, cb, w0).astype(dtype)

    mlp = params["mlp"]
    for i in range(cfg.n_layers):
        h = h @ mlp[f"w{i}"].astype(dtype) + mlp[f"b{i}"].astype(dtype)
        if i < cfg.n_layers - 1:
            h = jax.nn.relu(h)
    return h.reshape(*lead, cfg.d_e)
