"""Algorithm 1 — Encode with Random Projection (the paper's coding scheme).

For each output bit: draw a random Gaussian direction ``V ∈ R^d``, project
every entity's auxiliary row (``U = A·V``), binarise at the **median** of
``U`` (paper §3.1: the median threshold provably halves the mass per bucket
and empirically reduces collisions vs. the conventional zero threshold of
Charikar's LSH — reproduced in benchmarks/fig3_collisions.py).

Memory behaviour mirrors the paper: bits are produced word-by-word (32 bits
at a time) so only a ``(d, 32)`` projection block and one ``(n, 32)``
projection result are alive at once; ``A`` itself can be consumed in row
blocks (``row_block``) exactly as the paper's "load a few rows of A" note
suggests.  Auxiliary input may be dense ``(n, d)`` or a sparse CSR matrix
(adjacency), which is the paper's preferred representation.

Role in the system (docs/architecture.md): this is step 2 of the train
path — ``GraphRuntime`` calls ``encode_lsh`` on the adjacency to build the
``codes_buf`` the ``paper`` and ``tt`` compression families decode through
(the ``hashemb`` family recomputes position hashes instead and skips this
module entirely; see docs/decode_backends.md §Compression families).  The
``threshold`` / ``hops`` knobs ride ``EmbeddingSpec`` (docs/runtime_api.md).
"""

from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codes as codes_lib
from repro.graph.csr import CSRMatrix

Array = jnp.ndarray


def _project_dense_block(A: Array, V: Array, row_block: Optional[int]) -> Array:
    """U = A @ V computed in row blocks to bound live memory."""
    if row_block is None or A.shape[0] <= row_block:
        return A @ V

    n = A.shape[0]
    nblocks = -(-n // row_block)
    pad = nblocks * row_block - n
    Ap = jnp.pad(A, ((0, pad), (0, 0))) if pad else A

    def body(_, ab):
        return None, ab @ V

    _, U = jax.lax.scan(body, None, Ap.reshape(nblocks, row_block, A.shape[1]))
    U = U.reshape(nblocks * row_block, V.shape[1])
    return U[:n]


def _project_csr(A: CSRMatrix, V: Array) -> Array:
    """U = A @ V for CSR A via gather + segment-sum (row-wise op, as paper)."""
    contrib = A.data[:, None] * V[A.indices]            # (nnz, w)
    return jax.ops.segment_sum(contrib, A.row_ids(), num_segments=A.shape[0])


@functools.partial(jax.jit, static_argnames=("threshold",))
def _binarize_word(U: Array, threshold: str) -> Array:
    """(n, w) projections -> (n,) uint32 packed word."""
    if threshold == "median":
        t = jnp.median(U, axis=0)
    elif threshold == "zero":
        t = jnp.zeros((U.shape[1],), U.dtype)
    else:
        raise ValueError(f"unknown threshold {threshold!r}")
    bits = (U > t).astype(jnp.uint32)
    shifts = jnp.arange(U.shape[1], dtype=jnp.uint32)
    return (bits << shifts).sum(axis=-1, dtype=jnp.uint32)


def encode_lsh(
    key: jax.Array,
    A: Union[Array, CSRMatrix],
    c: int,
    m: int,
    *,
    threshold: str = "median",
    row_block: Optional[int] = 65536,
    hops: int = 1,
    dtype=jnp.float32,
) -> Array:
    """Algorithm 1.  Returns packed codes, shape ``(n, n_words)`` uint32.

    Deviations from the paper's listing (documented):
      * bits are generated 32 at a time instead of 1 at a time — identical
        semantics (independent Gaussians; per-bit median), 32x fewer passes
        over ``A``; the live-memory bound becomes O(32·(d + n)) which still
        satisfies the paper's O(n·m·log2 c) overall bound.
      * ``threshold='zero'`` reproduces the Charikar-LSH baseline the paper
        compares against in Fig. 3.
      * ``hops>1`` implements the paper's §6.1 future-work suggestion —
        higher-order adjacency as auxiliary information — WITHOUT forming
        Aᵏ: the random vector is pushed through the graph k times
        (U = Aᵏ·V as k sparse matvecs), so memory stays O(n·32).  Requires
        square A (adjacency).  Benchmarked in fig1 as ``hashing_graph2``.
    """
    nb = codes_lib.n_bits(c, m)
    nw = codes_lib.n_words(c, m)
    n = A.shape[0]
    d = A.shape[1]
    if hops > 1 and n != d:
        raise ValueError("hops>1 needs a square (adjacency) auxiliary matrix")

    words = []
    for w in range(nw):
        key, sub = jax.random.split(key)
        wbits = min(codes_lib.WORD_BITS, nb - w * codes_lib.WORD_BITS)
        V = jax.random.normal(sub, (d, wbits), dtype)
        U = V
        for _ in range(hops):
            if isinstance(A, CSRMatrix):
                U = _project_csr(A, U)
            else:
                U = _project_dense_block(jnp.asarray(A, dtype), U, row_block)
        words.append(_binarize_word(U, threshold))
    packed = jnp.stack(words, axis=1)
    assert packed.shape == (n, nw)
    return packed


def encode_lsh_codes(key, A, c: int, m: int, **kw) -> Array:
    """Algorithm 1, returning integer codes ``(n, m)`` in [0, c)."""
    return codes_lib.unpack_codes(encode_lsh(key, A, c, m, **kw), c, m)


def encode_random(key: jax.Array, n: int, c: int, m: int) -> Array:
    """ALONE's random coding scheme (Takase & Kobayashi 2020) — the paper's
    baseline.  Uniform i.i.d. codes, packed in the same storage layout."""
    codes = jax.random.randint(key, (n, m), 0, c, dtype=jnp.int32)
    return codes_lib.pack_codes(codes, c, m)


def collision_experiment(
    key: jax.Array, A, c: int, m: int, n_trials: int, threshold: str
) -> np.ndarray:
    """Paper Fig. 3 / Appendix A: repeat the encoding ``n_trials`` times with
    fresh seeds, count code collisions each time.  The same trial index uses
    the same projection basis across thresholds (paper: '100 seeds ... same
    basis ... only difference should be the threshold')."""
    out = []
    for trial in range(n_trials):
        sub = jax.random.fold_in(key, trial)
        packed = encode_lsh(sub, A, c, m, threshold=threshold)
        out.append(codes_lib.count_collisions(packed))
    return np.asarray(out)
