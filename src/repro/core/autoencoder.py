"""Learning-based coding baseline (paper Fig. 1 "learn"; Shu & Nakayama 2018).

An encoder MLP maps a pre-trained embedding to ``m`` categorical
distributions over ``c`` codes; discrete codes are taken by Gumbel-softmax
with straight-through argmax; the shared decoder (core/decoder.py)
reconstructs the embedding.  After training, codes are frozen with a final
argmax pass and only the decoder is kept — the paper's point is that this
needs a pre-training stage over the *full* embedding table, which is exactly
what makes it inapplicable at industrial scale (§2), but it is the strongest
reconstruction baseline so we implement it for Fig. 1.

Role in the system (docs/architecture.md): a *code-learning* baseline only —
it produces codes for ``benchmarks/fig1_reconstruction.py`` but is not a
``DecodeBackend`` and not selectable via ``lookup_impl``; the trainable
alternatives to the paper's scheme that ARE wired end to end are the
``hashemb`` / ``tt`` compression families (docs/decode_backends.md
§Compression families).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.decoder import DecoderConfig, apply_decoder, init_decoder
from repro.core import codes as codes_lib
from repro.nn import module as nn


@dataclasses.dataclass(frozen=True)
class AutoencoderConfig:
    d_in: int
    c: int = 256
    m: int = 16
    d_h: int = 512
    decoder: DecoderConfig = dataclasses.field(default_factory=DecoderConfig)
    tau: float = 1.0  # Gumbel-softmax temperature


def init_autoencoder(key, cfg: AutoencoderConfig) -> nn.Params:
    ks = nn.split_keys(key, ["enc1", "enc2", "dec"])
    return {
        "enc": {
            "w1": nn.dense_init(ks["enc1"], (cfg.d_in, cfg.d_h)),
            "b1": jnp.zeros((cfg.d_h,), jnp.float32),
            "w2": nn.dense_init(ks["enc2"], (cfg.d_h, cfg.m * cfg.c)),
            "b2": jnp.zeros((cfg.m * cfg.c,), jnp.float32),
        },
        "decoder": init_decoder(ks["dec"], cfg.decoder),
    }


def encode_logits(params, x, cfg: AutoencoderConfig) -> jnp.ndarray:
    h = jax.nn.relu(x @ params["enc"]["w1"] + params["enc"]["b1"])
    logits = h @ params["enc"]["w2"] + params["enc"]["b2"]
    return logits.reshape(*x.shape[:-1], cfg.m, cfg.c)


def _straight_through_onehot(key, logits, tau: float) -> jnp.ndarray:
    g = jax.random.gumbel(key, logits.shape, logits.dtype)
    y_soft = jax.nn.softmax((logits + g) / tau, axis=-1)
    idx = jnp.argmax(y_soft, axis=-1)
    y_hard = jax.nn.one_hot(idx, logits.shape[-1], dtype=logits.dtype)
    return y_hard + y_soft - jax.lax.stop_gradient(y_soft)


def reconstruct(params, x, key, cfg: AutoencoderConfig) -> jnp.ndarray:
    """Differentiable forward: x -> codes (ST-gumbel) -> decoder -> x_hat."""
    logits = encode_logits(params, x, cfg)
    onehot = _straight_through_onehot(key, logits, cfg.tau)     # (B, m, c)
    dec = cfg.decoder
    cb = params["decoder"].get("codebooks", params["decoder"].get("codebooks_buf"))
    h = jnp.einsum("bmc,mcd->bd", onehot, cb)
    if dec.variant == "light":
        h = h * params["decoder"]["w0"][None, :]
    mlp = params["decoder"]["mlp"]
    for i in range(dec.n_layers):
        h = h @ mlp[f"w{i}"] + mlp[f"b{i}"]
        if i < dec.n_layers - 1:
            h = jax.nn.relu(h)
    return h


def extract_codes(params, x, cfg: AutoencoderConfig) -> jnp.ndarray:
    """Post-training hard codes, packed storage layout."""
    logits = encode_logits(params, x, cfg)
    codes = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return codes_lib.pack_codes(codes, cfg.c, cfg.m)


def train_autoencoder(
    key, emb: jnp.ndarray, cfg: AutoencoderConfig,
    steps: int = 300, batch: int = 512, lr: float = 1e-3,
) -> Tuple[nn.Params, float]:
    """Small self-contained AdamW loop (reconstruction MSE, paper §5.1.2)."""
    from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

    k_init, k_loop = jax.random.split(key)
    params = init_autoencoder(k_init, cfg)
    ocfg = AdamWConfig(lr=lr, weight_decay=0.01)
    ostate = adamw_init(params)

    def loss_fn(p, xb, k):
        return jnp.mean((reconstruct(p, xb, k, cfg) - xb) ** 2)

    @jax.jit
    def step(p, s, xb, k):
        loss, grads = jax.value_and_grad(loss_fn)(p, xb, k)
        p, s = adamw_update(p, grads, s, ocfg)
        return p, s, loss

    n = emb.shape[0]
    loss = jnp.inf
    for i in range(steps):
        k_it = jax.random.fold_in(k_loop, i)
        idx = jax.random.randint(jax.random.fold_in(k_it, 1), (batch,), 0, n)
        params, ostate, loss = step(params, ostate, emb[idx], jax.random.fold_in(k_it, 2))
    return params, float(loss)
