from repro.train.checkpoint import CheckpointManager, TopologyMismatch
from repro.train.loop import FenceInterrupt, LoopConfig, LoopResult, run_training
from repro.train.step import (
    TrainHyper, init_gnn_train_state, init_train_state, make_gnn_train_step,
    make_prefill_step, make_serve_step, make_train_step,
)

__all__ = [
    "CheckpointManager", "TopologyMismatch", "FenceInterrupt",
    "LoopConfig", "LoopResult", "run_training",
    "TrainHyper", "init_gnn_train_state", "init_train_state",
    "make_gnn_train_step", "make_prefill_step", "make_serve_step",
    "make_train_step",
]
