"""Train / prefill / serve step factories.

``make_train_step(cfg, ocfg)`` returns a donated-state pjit-able function
  (state, batch) -> (state, metrics)
with: bf16 activations, f32 master params + Adam moments, allow_int grads
(packed code buffers ride along untouched), optional global-norm clip, and
LR schedule by step counter.

``make_prefill_step`` / ``make_serve_step`` cover the inference shapes:
prefill lowers the full-sequence forward that builds a cache; serve decodes
one token against the cache (the dry-run's decode_* / long_* cells).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig, LMConfig
from repro.models.lm import LMCache, init_cache, lm_forward, lm_loss
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import linear_warmup_cosine


@dataclasses.dataclass(frozen=True)
class TrainHyper:
    optimizer: AdamWConfig = dataclasses.field(default_factory=lambda: AdamWConfig(
        lr=1e-3, weight_decay=0.01, clip_norm=1.0))
    warmup_steps: int = 100
    total_steps: int = 10_000
    microbatches: int = 1      # gradient accumulation (activation-memory knob)


def init_train_state(key, cfg: LMConfig, codes=None, aux=None,
                     moments_dtype=jnp.float32) -> Dict[str, Any]:
    from repro.models.lm import init_lm
    params = init_lm(key, cfg, codes=codes, aux=aux)
    return {"params": params, "opt": adamw_init(params, moments_dtype),
            "step": jnp.zeros((), jnp.int32)}


def _grad_zeros(params):
    from repro.nn.module import trainable_mask
    mask = trainable_mask(params)
    return jax.tree.map(
        lambda p, m: jnp.zeros_like(p, dtype=jnp.float32) if m else p, params, mask)


def _grad_add(acc, g, params):
    from repro.nn.module import trainable_mask
    mask = trainable_mask(params)
    return jax.tree.map(
        lambda a, b, m: a + b.astype(jnp.float32) if m else a, acc, g, mask)


def _grad_scale(g, s, params):
    from repro.nn.module import trainable_mask
    mask = trainable_mask(params)
    return jax.tree.map(lambda x, m: x * s if m else x, g, mask)


def make_train_step(cfg: LMConfig, hyper: Optional[TrainHyper] = None) -> Callable:
    hyper = hyper or TrainHyper()
    k = max(1, hyper.microbatches)

    def train_step(state, batch):
        params = state["params"]
        if k == 1:
            loss, grads = jax.value_and_grad(
                lambda p: lm_loss(p, batch, cfg), allow_int=True)(params)
        else:
            # gradient accumulation over k microbatches (scan keeps one
            # microbatch's activations alive at a time)
            def to_mb(path, x):
                is_positions = any(getattr(p, "key", None) == "positions" for p in path)
                if is_positions:  # (3, B, S) -> (k, 3, B/k, S)
                    return x.reshape((x.shape[0], k, x.shape[1] // k) + x.shape[2:]).swapaxes(0, 1)
                return x.reshape((k, x.shape[0] // k) + x.shape[1:])
            mb = jax.tree_util.tree_map_with_path(to_mb, batch)

            def body(carry, mbatch):
                acc, loss_sum = carry
                loss, g = jax.value_and_grad(
                    lambda p: lm_loss(p, mbatch, cfg), allow_int=True)(params)
                return (_grad_add(acc, g, params), loss_sum + loss), None

            (gsum, loss_sum), _ = jax.lax.scan(
                body, (_grad_zeros(params), jnp.zeros((), jnp.float32)), mb,
                unroll=True if cfg.unroll_scan else 1)
            grads = _grad_scale(gsum, 1.0 / k, params)
            loss = loss_sum / k
        lr_scale = linear_warmup_cosine(
            state["step"], hyper.warmup_steps, hyper.total_steps)
        params, opt = adamw_update(params, grads, state["opt"],
                                   hyper.optimizer, lr_scale=lr_scale)
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        metrics = {"loss": loss, "lr_scale": lr_scale}
        return new_state, metrics

    return train_step


def init_gnn_train_state(key, cfg: GNNConfig, codes=None, aux=None) -> Dict[str, Any]:
    """Train state for the graph engine (same layout as the LM state).

    When the embedding config enables the hot-node decode cache
    (``cache_capacity > 0`` on a compressed kind) the state carries a
    ``"cache"`` entry (a ``core.backend.CacheState`` pytree) that the train
    step threads through and version-bumps after each optimizer update."""
    from repro.graph.engine import GNNModel
    params = GNNModel(cfg).init(key, codes=codes, aux=aux)
    state = {"params": params, "opt": adamw_init(params),
             "step": jnp.zeros((), jnp.int32)}
    ecfg = cfg.embedding_config()
    if ecfg.is_compressed and ecfg.cache_capacity > 0:
        from repro.core.backend import CacheState
        state["cache"] = CacheState.create(
            ecfg.cache_capacity, cfg.d_e, jnp.dtype(cfg.compute_dtype))
    return state


def make_gnn_train_step(cfg: GNNConfig,
                        opt: Optional[AdamWConfig] = None,
                        interpret: bool = False,
                        mesh=None,
                        duplication: Optional[float] = None) -> Callable:
    """Node-classification train step over the unified ``GNNModel`` API.

    The batch is a dict from an engine batch source: either
    {"frontier": FrontierBatch, "labels": y} (dedup-decode path) or
    {"levels": tuple, "labels": y} (naive reference path) — the model
    dispatches on the batch view, so the step function is family-agnostic.

    The embedding decode runs on the backend named by the config's
    ``lookup_impl`` and gradients flow through that backend's (custom) VJP —
    for ``pallas`` the fused kernel forward pairs with the XLA scatter-add
    backward in ``kernels.hash_decode.ops``.  If the state carries a
    ``"cache"`` entry, the frontier decode is served through the hot-node
    cache, the updated cache rides along in the state, and its version is
    bumped after the optimizer touches the decoder parameters (that bump is
    what invalidates cached embeddings once they exceed the staleness
    budget).

    ``mesh`` makes the step trace under that sharding context: with
    ``lookup_impl="sharded"`` (or ``"auto"``) the frontier decode of a
    ``ShardedSageBatchSource`` batch runs shard-local on the mesh's data
    axis — the whole N-shard switch is this argument plus the batch source's
    ``n_shards``.  ``duplication`` (measured frontier_rows/unique_rows, from
    ``ShardedSageBatchSource.measure_duplication``) lets ``lookup_impl=
    "auto"`` prefer the owner-computes decode past the duplication
    threshold; batches carrying an ``OwnerPlan`` then dedup hub rows across
    shards.
    """
    from contextlib import nullcontext

    from repro.core.backend import CachedDecodeBackend
    from repro.graph.engine import GNNModel, batch_view
    from repro.models import gnn
    from repro.parallel.sharding import use_sharding
    _ctx = (lambda: use_sharding(mesh)) if mesh is not None else nullcontext
    with _ctx():
        model = GNNModel(cfg, interpret=interpret, duplication=duplication)
    ocfg = opt or AdamWConfig(lr=1e-2, weight_decay=0.0)

    def train_step(state, batch):
        with _ctx():
            return _train_step(state, batch)

    def _train_step(state, batch):
        view = batch_view(batch)
        cached = "cache" in state

        def _logits(p, h):
            # full-graph batches carry the training-node ids: the model
            # returns hidden for ALL nodes and the loss reads the subset
            logits = model.logits(p, h)
            if "ids" in batch:
                logits = logits[batch["ids"]]
            return logits

        if cached:
            def loss_fn(p, c):
                h, new_c = model.apply_cached(p, view, c)
                return gnn.node_loss(_logits(p, h), batch["labels"]), new_c
            (loss, new_cache), g = jax.value_and_grad(
                loss_fn, has_aux=True, allow_int=True)(
                    state["params"], state["cache"])
        else:
            def loss_fn(p):
                h = model.apply(p, view)
                return gnn.node_loss(_logits(p, h), batch["labels"])
            loss, g = jax.value_and_grad(loss_fn, allow_int=True)(state["params"])

        params, opt_state = adamw_update(state["params"], g, state["opt"], ocfg)
        new_state = {"params": params, "opt": opt_state, "step": state["step"] + 1}
        metrics = {"loss": loss}
        if cached:
            new_cache = CachedDecodeBackend.bump_version(new_cache)
            new_state["cache"] = new_cache
            metrics["cache_hits"] = new_cache.hits
            metrics["cache_misses"] = new_cache.misses
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: LMConfig, s_max: int) -> Callable:
    """(params, tokens[, positions]) -> (last_logits, cache)."""
    def prefill_step(params, batch):
        B = batch["tokens"].shape[0]
        cache = init_cache(cfg, B, s_max, jnp.dtype(cfg.compute_dtype))
        logits, cache = lm_forward(params, batch["tokens"], cfg, cache=cache,
                                   positions=batch.get("positions"))
        return logits[:, -1], cache
    return prefill_step


def make_serve_step(cfg: LMConfig) -> Callable:
    """(params, cache, tokens (B,1[,nq])) -> (logits, cache) — one decode step."""
    def serve_step(params, cache: LMCache, batch):
        logits, cache = lm_forward(params, batch["tokens"], cfg, cache=cache,
                                   positions=batch.get("positions"))
        return logits[:, -1], cache
    return serve_step
