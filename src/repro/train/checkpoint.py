"""Checkpointing for fault tolerance + elastic restarts (DESIGN.md §6).

Design points (1000+-node posture):
  * **atomic**: write to ``step_XXXX.tmp`` then ``os.replace`` — a crash
    mid-write can never corrupt the latest checkpoint.
  * **mesh-shape agnostic**: every param/optimizer leaf is saved as a full
    (unsharded) array keyed by its pytree path; on load the launcher
    re-applies the current mesh's shardings, so restart on a different
    data-parallel extent works (elastic scaling).  On a real fleet the same
    layout is written per-shard with a process-0 manifest; the gather is
    the CPU-container simplification and is isolated in ``_to_host``.
  * **self-describing**: a JSON manifest stores step, data-pipeline state,
    config fingerprint, leaf dtypes/shapes for validation, and the shard
    **topology** the run trained under — restoring onto a different
    topology raises ``TopologyMismatch`` pointing at the sanctioned path
    (``GraphRuntime.rescale`` / ``rescale_checkpoint``) instead of failing
    deep in shape or batch-source mismatches (docs/elastic.md).
  * **crash-safe open**: stale ``step_*.tmp`` directories left by a write
    interrupted mid-flight are swept on open; ``list_steps`` additionally
    requires a manifest, so a half-written checkpoint is never resumable.
  * **async**: `save` can hand off to a background thread (double-buffered;
    at most one outstanding write) so the step loop is not blocked.
  * **retention**: keep the newest ``keep`` checkpoints, always retaining
    step-aligned "anchors" (every ``anchor_every`` steps).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(tree, flat: Dict[str, np.ndarray]):
    def rebuild(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs model {leaf.shape}")
        return arr
    return jax.tree_util.tree_map_with_path(rebuild, tree)


class TopologyMismatch(ValueError):
    """A checkpoint written under one shard topology was asked to restore
    under a different one.  Raised loudly at restore time — the fix is the
    sanctioned exact-rescale path, never a silent reinterpretation."""


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, anchor_every: int = 0,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.anchor_every = anchor_every
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)
        # crash-safe open: a write interrupted mid-flight leaves a step_*.tmp
        # directory behind; it is dead weight (never listed, never restored)
        # and would shadow a later write of the same step, so sweep it now
        for name in os.listdir(directory):
            if name.startswith("step_") and name.endswith(".tmp"):
                shutil.rmtree(os.path.join(directory, name), ignore_errors=True)

    # -- save -----------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[Dict] = None,
             topology: Optional[Dict] = None) -> str:
        """state: any pytree (params + optimizer + rng); extra: JSON-able
        (data-pipeline state, config fingerprint); topology: JSON-able shard
        layout descriptor (e.g. ``{"n_shards": 4, "batch_size": 64}``) that
        ``restore(expect_topology=...)`` validates before touching arrays."""
        flat = _flatten(state)   # device_get on the step thread: cheap on CPU,
                                 # on TPU this is the D2H copy we double-buffer
        if self._thread is not None:
            self._thread.join()  # at most one outstanding write
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, extra or {}, topology),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, flat, extra or {}, topology)
        return self._path(step)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def _write(self, step: int, flat: Dict[str, np.ndarray], extra: Dict,
               topology: Optional[Dict] = None):
        final = self._path(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
            np.savez(f, **flat)
            f.flush()
            os.fsync(f.fileno())
        manifest = {
            "step": step,
            "extra": extra,
            "topology": topology,
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)   # atomic publish
        self._gc()

    def _gc(self):
        steps = self.list_steps()
        if len(steps) <= self.keep:
            return
        doomed = steps[: -self.keep]
        for s in doomed:
            if self.anchor_every and s % self.anchor_every == 0:
                continue
            shutil.rmtree(self._path(s), ignore_errors=True)

    # -- load -----------------------------------------------------------
    def list_steps(self):
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, state_template: Any,
                expect_topology: Optional[Dict] = None) -> Tuple[Any, Dict]:
        """Returns (state, extra).  ``state_template`` supplies the pytree
        structure + shapes (abstract or concrete); arrays are loaded and may
        be re-sharded by the caller (device_put with current shardings).

        ``expect_topology`` (when given) is compared against the manifest's
        recorded topology *before* any array is touched; a mismatch raises
        ``TopologyMismatch``.  Manifests written before topology stamping
        (no ``topology`` key / ``None``) pass unconditionally."""
        path = self._path(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        saved = manifest.get("topology")
        if expect_topology is not None and saved is not None and saved != expect_topology:
            raise TopologyMismatch(
                f"checkpoint at step {step} was written under topology {saved} "
                f"but the current run expects {expect_topology}.  Resuming "
                f"across shard topologies silently is never correct — use the "
                f"exact-rescale path (GraphRuntime.rescale / "
                f"GraphRuntime.rescale_checkpoint, see docs/elastic.md) to "
                f"remap the owner partition and sampler state first.")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        state = _unflatten_into(state_template, flat)
        return state, manifest["extra"]

    def read_extra(self, step: Optional[int] = None) -> Optional[Dict]:
        """Read just the ``extra`` manifest of a checkpoint (latest by
        default) without touching the arrays — enough to recover e.g. the
        runtime spec before any state template exists.  None if the
        directory holds no checkpoint."""
        step = self.latest_step() if step is None else step
        if step is None:
            return None
        with open(os.path.join(self._path(step), "manifest.json")) as f:
            return json.load(f)["extra"]

    def restore_latest(self, state_template: Any,
                       expect_topology: Optional[Dict] = None,
                       ) -> Optional[Tuple[int, Any, Dict]]:
        step = self.latest_step()
        if step is None:
            return None
        state, extra = self.restore(step, state_template,
                                    expect_topology=expect_topology)
        return step, state, extra
