"""Checkpointing for fault tolerance + elastic restarts (DESIGN.md §6).

Design points (1000+-node posture):
  * **atomic**: write to ``step_XXXX.tmp`` then ``os.replace`` — a crash
    mid-write can never corrupt the latest checkpoint.
  * **mesh-shape agnostic**: every param/optimizer leaf is saved as a full
    (unsharded) array keyed by its pytree path; on load the launcher
    re-applies the current mesh's shardings, so restart on a different
    data-parallel extent works (elastic scaling).  On a real fleet the same
    layout is written per-shard with a process-0 manifest; the gather is
    the CPU-container simplification and is isolated in ``_to_host``.
  * **self-describing**: a JSON manifest stores step, data-pipeline state,
    config fingerprint, and leaf dtypes/shapes for validation.
  * **async**: `save` can hand off to a background thread (double-buffered;
    at most one outstanding write) so the step loop is not blocked.
  * **retention**: keep the newest ``keep`` checkpoints, always retaining
    step-aligned "anchors" (every ``anchor_every`` steps).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(tree, flat: Dict[str, np.ndarray]):
    def rebuild(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs model {leaf.shape}")
        return arr
    return jax.tree_util.tree_map_with_path(rebuild, tree)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, anchor_every: int = 0,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.anchor_every = anchor_every
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[Dict] = None) -> str:
        """state: any pytree (params + optimizer + rng); extra: JSON-able
        (data-pipeline state, config fingerprint)."""
        flat = _flatten(state)   # device_get on the step thread: cheap on CPU,
                                 # on TPU this is the D2H copy we double-buffer
        if self._thread is not None:
            self._thread.join()  # at most one outstanding write
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, extra or {}), daemon=True)
            self._thread.start()
        else:
            self._write(step, flat, extra or {})
        return self._path(step)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def _write(self, step: int, flat: Dict[str, np.ndarray], extra: Dict):
        final = self._path(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "extra": extra,
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)   # atomic publish
        self._gc()

    def _gc(self):
        steps = self.list_steps()
        if len(steps) <= self.keep:
            return
        doomed = steps[: -self.keep]
        for s in doomed:
            if self.anchor_every and s % self.anchor_every == 0:
                continue
            shutil.rmtree(self._path(s), ignore_errors=True)

    # -- load -----------------------------------------------------------
    def list_steps(self):
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, state_template: Any) -> Tuple[Any, Dict]:
        """Returns (state, extra).  ``state_template`` supplies the pytree
        structure + shapes (abstract or concrete); arrays are loaded and may
        be re-sharded by the caller (device_put with current shardings)."""
        path = self._path(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        state = _unflatten_into(state_template, flat)
        return state, manifest["extra"]

    def read_extra(self, step: Optional[int] = None) -> Optional[Dict]:
        """Read just the ``extra`` manifest of a checkpoint (latest by
        default) without touching the arrays — enough to recover e.g. the
        runtime spec before any state template exists.  None if the
        directory holds no checkpoint."""
        step = self.latest_step() if step is None else step
        if step is None:
            return None
        with open(os.path.join(self._path(step), "manifest.json")) as f:
            return json.load(f)["extra"]

    def restore_latest(self, state_template: Any) -> Optional[Tuple[int, Any, Dict]]:
        step = self.latest_step()
        if step is None:
            return None
        state, extra = self.restore(step, state_template)
        return step, state, extra
