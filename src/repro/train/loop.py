"""Training loop with fault tolerance + straggler monitoring.

Responsibilities (DESIGN.md §6):
  * auto-resume: on start, restore the newest valid checkpoint (params,
    optimizer, step counter, data-pipeline state) and continue — the
    restart path after a node failure.
  * periodic + final checkpointing (async, atomic).
  * straggler monitor: per-step wall-time EWMA; steps slower than
    ``straggler_factor``× the EWMA are logged and counted (on a fleet this
    signal feeds the backup-worker / re-slice policy; here it is the hook +
    test surface).
  * simple metrics log (CSV) for the examples/benchmarks.
  * step fences for elastic training: an optional ``fence`` callback runs
    every ``fence_every`` completed steps; raising ``FenceInterrupt`` from
    it stops the loop cleanly at a step boundary (state is consistent, no
    final checkpoint is written) — the hook ``repro.elastic.manager`` uses
    to detect dead shards and hand control to the rescale path.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, Optional

import jax
import numpy as np

from repro.train.checkpoint import CheckpointManager


class FenceInterrupt(Exception):
    """Raised by a step-fence callback to stop the loop at a step boundary.

    The loop returns normally with ``LoopResult.interrupted_at`` set to the
    number of completed steps; no final checkpoint is written, because the
    interrupting party (e.g. ``repro.elastic.ElasticManager``) owns what
    happens next — peer transfer, rescale, or abort."""


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 200
    log_every: int = 20
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.1
    fence_every: int = 1   # steps between fence-callback invocations


@dataclasses.dataclass
class LoopResult:
    state: Any
    losses: list
    step_times: list
    stragglers: int
    resumed_from: Optional[int]
    interrupted_at: Optional[int] = None   # completed steps at FenceInterrupt


def run_training(
    train_step: Callable,
    state: Any,
    data_iter,
    loop_cfg: LoopConfig,
    ckpt: Optional[CheckpointManager] = None,
    to_device: Callable = lambda b: b,
    on_metrics: Optional[Callable[[int, Dict], None]] = None,
    extra_base: Optional[Dict] = None,
    prejitted: bool = False,
    fence: Optional[Callable[[int], None]] = None,
    topology: Optional[Dict] = None,
) -> LoopResult:
    """``extra_base``: JSON-able dict merged into every checkpoint's
    ``extra`` manifest (e.g. the GraphRuntime spec, so a checkpoint is
    self-describing enough to rebuild its whole pipeline).

    ``prejitted``: ``train_step`` is already a donated-state jitted
    callable — use it as-is so repeat ``run_training`` calls (chunked
    training) reuse its compile cache instead of re-tracing.

    ``fence(step)``: called after every ``fence_every``-th completed step
    (``step`` is the 0-based index just finished) and may raise
    ``FenceInterrupt`` to stop the loop at that boundary.

    ``topology``: JSON-able shard-layout descriptor stamped into every
    checkpoint manifest and validated on auto-resume (a mismatched resume
    raises ``repro.train.TopologyMismatch``)."""
    resumed_from = None
    start_step = 0
    if ckpt is not None:
        restored = ckpt.restore_latest(state, expect_topology=topology)
        if restored is not None:
            start_step, state, extra = restored
            resumed_from = start_step
            if hasattr(data_iter, "load_state_dict") and "data" in extra:
                data_iter.load_state_dict(extra["data"])

    losses, step_times = [], []
    stragglers = 0
    interrupted_at = None
    ewma = None
    jitted = train_step if prejitted else jax.jit(train_step,
                                                  donate_argnums=(0,))

    try:
        for step in range(start_step, loop_cfg.total_steps):
            batch = to_device(data_iter.next_batch())
            t0 = time.perf_counter()
            state, metrics = jitted(state, batch)
            loss = float(metrics["loss"])   # blocks: device sync = honest timing
            dt = time.perf_counter() - t0
            step_times.append(dt)
            losses.append(loss)

            if ewma is None:
                ewma = dt
            else:
                if dt > loop_cfg.straggler_factor * ewma:
                    stragglers += 1
                ewma = (1 - loop_cfg.ewma_alpha) * ewma + loop_cfg.ewma_alpha * dt

            if on_metrics and step % loop_cfg.log_every == 0:
                on_metrics(step, {"loss": loss, "step_time": dt, "ewma": ewma})

            if fence is not None and (step + 1) % loop_cfg.fence_every == 0:
                try:
                    fence(step)
                except FenceInterrupt:
                    interrupted_at = step + 1
                    break

            if ckpt is not None and (step + 1) % loop_cfg.ckpt_every == 0:
                extra = dict(extra_base or {})
                if hasattr(data_iter, "state_dict"):
                    extra["data"] = data_iter.state_dict()
                ckpt.save(step + 1, state, extra, topology=topology)

        if ckpt is not None and interrupted_at is None:
            extra = dict(extra_base or {})
            if hasattr(data_iter, "state_dict"):
                extra["data"] = data_iter.state_dict()
            ckpt.save(loop_cfg.total_steps, state, extra, topology=topology)
            ckpt.wait()
    finally:
        # async prefetch iterators (repro.graph.engine.PrefetchIterator) own a
        # producer thread; stop it whether the loop finished or raised
        if hasattr(data_iter, "close"):
            data_iter.close()

    return LoopResult(state=state, losses=losses, step_times=step_times,
                      stragglers=stragglers, resumed_from=resumed_from,
                      interrupted_at=interrupted_at)
