"""Decoder-LM family covering all assigned architectures via LMConfig:

  dense / audio / vlm : [RMSNorm→GQA-attn] + [RMSNorm→MLP]        (scan)
  moe                 : [RMSNorm→GQA-attn] + [RMSNorm→MoE]        (scan)
  ssm                 : [RMSNorm→Mamba2-SSD]                      (scan)
  hybrid (zamba2)     : groups of `attn_every` mamba layers, each group
                        followed by ONE SHARED transformer block (weights
                        re-used at every call site, per-site KV caches)

The input embedding is the paper's compressed embedding whenever
``cfg.embedding.kind != 'dense'`` — the framework's first-class feature.
Homogeneous stacks are `lax.scan`s over stacked params (compile-time + remat
control); decode threads per-layer KV/SSM caches through the scans.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import embedding as emb_lib
from repro.nn import module as nn
from repro.nn.attention import AttentionConfig, attention, init_attention
from repro.nn.kvcache import KVCache
from repro.nn.layers import init_mlp, init_norm, mlp, norm
from repro.nn.moe import MoEConfig, init_moe, moe_dense_ffn, moe_ffn_ep
from repro.nn.rope import default_positions, rope_cos_sin
from repro.nn.ssm import SSMConfig, init_ssm, ssm_forward
from repro.nn.kvcache import SSMCache
from repro.configs.base import LMConfig
from repro.parallel.sharding import logical

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# config adapters
# ---------------------------------------------------------------------------

def attn_config(cfg: LMConfig) -> AttentionConfig:
    return AttentionConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        d_head=cfg.head_dim, qkv_bias=cfg.qkv_bias, impl=cfg.attn_impl,
    )


def moe_config(cfg: LMConfig) -> MoEConfig:
    return MoEConfig(
        d_model=cfg.d_model, d_ff=cfg.d_ff, n_experts=cfg.n_experts,
        top_k=cfg.moe_top_k, n_experts_padded=cfg.n_experts_padded,
        capacity_factor=cfg.moe_capacity_factor, act=cfg.act,
        impl=cfg.moe_impl,
    )


def ssm_config(cfg: LMConfig) -> SSMConfig:
    return SSMConfig(
        d_model=cfg.d_model, d_state=cfg.ssm_state, headdim=cfg.ssm_headdim,
        expand=cfg.ssm_expand, chunk=cfg.ssm_chunk,
    )


def _n_attn_sites(cfg: LMConfig) -> int:
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        return cfg.n_layers
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    return 0


def _n_ssm_layers(cfg: LMConfig) -> int:
    return cfg.n_layers if cfg.family in ("ssm", "hybrid") else 0


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LMCache:
    pos: Array                       # scalar int32
    kv_k: Optional[Array] = None     # (sites, B, S_max, K, Dh)
    kv_v: Optional[Array] = None
    ssm_state: Optional[Array] = None  # (ssm_layers, B, H, N, P)
    conv: Optional[Array] = None       # (ssm_layers, B, W-1, C)

    def tree_flatten(self):
        return (self.pos, self.kv_k, self.kv_v, self.ssm_state, self.conv), None

    @classmethod
    def tree_unflatten(cls, _aux, leaves):
        return cls(*leaves)


def init_cache(cfg: LMConfig, batch: int, s_max: int,
               dtype=jnp.bfloat16) -> LMCache:
    sites = _n_attn_sites(cfg)
    nssm = _n_ssm_layers(cfg)
    kv_k = kv_v = ssm_state = conv = None
    if sites:
        shape = (sites, batch, s_max, cfg.n_kv_heads, cfg.head_dim)
        kv_k = jnp.zeros(shape, dtype)
        kv_v = jnp.zeros(shape, dtype)
    if nssm:
        scfg = ssm_config(cfg)
        ssm_state = jnp.zeros(
            (nssm, batch, scfg.n_heads, scfg.d_state, scfg.headdim), jnp.float32)
        conv = jnp.zeros(
            (nssm, batch, scfg.conv_width - 1, scfg.conv_channels), dtype)
    return LMCache(pos=jnp.zeros((), jnp.int32), kv_k=kv_k, kv_v=kv_v,
                   ssm_state=ssm_state, conv=conv)


def cache_shardings(cfg: LMConfig, batch: int, s_max: int, dtype=jnp.bfloat16):
    """Logical shardings for every cache leaf (used by dryrun in/out specs)."""
    from repro.parallel.sharding import logical_sharding
    cache = jax.eval_shape(lambda: init_cache(cfg, batch, s_max, dtype))
    names = {
        "kv": (None, "batch", "kv_seq", "kv_heads", "head_dim"),
        "ssm": (None, "batch", "ssm_heads", "ssm_state", None),
        "conv": (None, "batch", None, "d_ff"),
    }
    def shard_of(leaf, kind):
        return logical_sharding(leaf.shape, *names[kind])
    return LMCache(
        pos=None,
        kv_k=shard_of(cache.kv_k, "kv") if cache.kv_k is not None else None,
        kv_v=shard_of(cache.kv_v, "kv") if cache.kv_v is not None else None,
        ssm_state=shard_of(cache.ssm_state, "ssm") if cache.ssm_state is not None else None,
        conv=shard_of(cache.conv, "conv") if cache.conv is not None else None,
    )


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def init_attn_block(key, cfg: LMConfig) -> nn.Params:
    ks = nn.split_keys(key, ["n1", "attn", "n2", "ffn"])
    p = {
        "norm1": init_norm(ks["n1"], cfg.d_model, cfg.norm),
        "attn": init_attention(ks["attn"], attn_config(cfg)),
        "norm2": init_norm(ks["n2"], cfg.d_model, cfg.norm),
    }
    if cfg.family == "moe":
        p["moe"] = init_moe(ks["ffn"], moe_config(cfg))
    else:
        p["mlp"] = init_mlp(ks["ffn"], cfg.d_model, cfg.d_ff, cfg.act)
    return p


def attn_block(p, x: Array, cfg: LMConfig, cos, sin,
               kv: Optional[KVCache] = None) -> Tuple[Array, Optional[KVCache]]:
    h, kv = attention(p["attn"], norm(p["norm1"], x, cfg.norm), attn_config(cfg),
                      cos=cos, sin=sin, cache=kv)
    x = x + h
    x = logical(x, "batch", "seq", "embed")
    h2 = norm(p["norm2"], x, cfg.norm)
    if cfg.family == "moe":
        B, S, D = h2.shape
        mcfg = moe_config(cfg)
        fn = moe_dense_ffn if mcfg.impl == "dense" else moe_ffn_ep
        y = fn(p["moe"], h2.reshape(B * S, D), mcfg)
        y = y.reshape(B, S, D)
    else:
        y = mlp(p["mlp"], h2, cfg.act)
    x = x + y
    return logical(x, "batch", "seq", "embed"), kv


def init_ssm_block(key, cfg: LMConfig) -> nn.Params:
    ks = nn.split_keys(key, ["n1", "ssm"])
    return {
        "norm1": init_norm(ks["n1"], cfg.d_model, cfg.norm),
        "ssm": init_ssm(ks["ssm"], ssm_config(cfg)),
    }


def ssm_block(p, x: Array, cfg: LMConfig,
              cache: Optional[SSMCache] = None) -> Tuple[Array, Optional[SSMCache]]:
    h, cache = ssm_forward(p["ssm"], norm(p["norm1"], x, cfg.norm),
                           ssm_config(cfg), cache=cache)
    return logical(x + h, "batch", "seq", "embed"), cache


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------

def init_lm(key, cfg: LMConfig, codes: Optional[Array] = None,
            aux=None) -> nn.Params:
    """codes: precomputed packed compositional codes for the vocabulary
    (from the data pipeline's co-occurrence pass); aux: auxiliary matrix to
    encode from if codes is None.  Falls back to random codes (≡ ALONE) when
    neither is given — the launcher wires the real encode."""
    ks = nn.split_keys(key, ["embed", "blocks", "shared", "tail", "fnorm", "head", "pos"])
    ecfg = cfg.embedding_config()
    if ecfg.needs_codes and codes is None and aux is None:
        # (the hashemb family skips this: its position hashes are recomputed
        # per lookup, so there are no codes to build or store)
        codes = emb_lib.make_codes(
            jax.random.fold_in(ks["embed"], 1),
            dataclasses.replace(ecfg, kind="random_full"), None)
    n_emb_entities = ecfg.n_entities * (cfg.n_codebooks if cfg.input_mode == "audio_tokens" else 1)
    ecfg_n = dataclasses.replace(ecfg, n_entities=n_emb_entities)
    if codes is not None and ecfg.needs_codes and codes.shape[0] != n_emb_entities:
        reps = -(-n_emb_entities // codes.shape[0])
        codes = jnp.tile(codes, (reps, 1))[:n_emb_entities]
    params: nn.Params = {
        "embed": emb_lib.init_embedding(ks["embed"], ecfg_n, codes=codes, aux=aux),
        "final_norm": init_norm(ks["fnorm"], cfg.d_model, cfg.norm),
    }
    head_out = cfg.vocab_padded * (cfg.n_codebooks if cfg.input_mode == "audio_tokens" else 1)
    params["head"] = nn.dense_init(ks["head"], (cfg.d_model, head_out))

    if cfg.family in ("dense", "moe", "audio", "vlm"):
        keys = jax.random.split(ks["blocks"], cfg.n_layers)
        params["blocks"] = jax.vmap(lambda k: init_attn_block(k, cfg))(keys)
    elif cfg.family == "ssm":
        keys = jax.random.split(ks["blocks"], cfg.n_layers)
        params["blocks"] = jax.vmap(lambda k: init_ssm_block(k, cfg))(keys)
    elif cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        rem = cfg.n_layers % cfg.attn_every
        gkeys = jax.random.split(ks["blocks"], groups * cfg.attn_every)
        gkeys = gkeys.reshape(groups, cfg.attn_every, 2)
        params["blocks"] = jax.vmap(jax.vmap(lambda k: init_ssm_block(k, cfg)))(gkeys)
        params["shared"] = init_attn_block(ks["shared"], cfg)   # ONE shared block
        if rem:
            tkeys = jax.random.split(ks["tail"], rem)
            params["tail"] = jax.vmap(lambda k: init_ssm_block(k, cfg))(tkeys)
    else:
        raise ValueError(cfg.family)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _sinusoidal_pe(positions: Array, d: int, dtype) -> Array:
    half = d // 2
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / half))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _embed_tokens(params, tokens: Array, cfg: LMConfig, positions) -> Array:
    ecfg = cfg.embedding_config()
    dtype = jnp.dtype(cfg.compute_dtype)
    if cfg.input_mode == "audio_tokens":
        B, S, nq = tokens.shape
        flat_ids = tokens + (jnp.arange(nq, dtype=tokens.dtype) * cfg.vocab_padded)
        ecfg_n = dataclasses.replace(ecfg, n_entities=cfg.vocab_padded * nq)
        x = emb_lib.embed_lookup(params["embed"], flat_ids, ecfg_n).sum(axis=2)
    else:
        x = emb_lib.embed_lookup(params["embed"], tokens, ecfg)
    x = x.astype(dtype)
    if cfg.rope_variant == "none":
        pos = positions if positions.ndim == 2 else positions[0]
        x = x + _sinusoidal_pe(pos, cfg.d_model, dtype)
    return logical(x, "batch", "seq", "embed")


def _rope(cfg: LMConfig, positions) -> Tuple[Optional[Array], Optional[Array]]:
    if cfg.rope_variant == "none" or not cfg.n_heads:
        return None, None
    frac = 0.5 if cfg.rope_variant == "half" else 1.0
    sections = cfg.mrope_sections if cfg.rope_variant == "mrope" else None
    return rope_cos_sin(positions, cfg.head_dim, theta=cfg.rope_theta,
                        fraction=frac, mrope_sections=sections)


def _maybe_ckpt(fn, cfg: LMConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


def _scan(body, init, xs, cfg: LMConfig):
    """lax.scan with optional full unroll (dry-run cost-analysis mode:
    XLA's HloCostAnalysis does not weight while-loop bodies by trip count,
    so roofline lowering unrolls the homogeneous stacks)."""
    return jax.lax.scan(body, init, xs, unroll=True if cfg.unroll_scan else 1)


def lm_forward(
    params: nn.Params,
    tokens: Array,
    cfg: LMConfig,
    cache: Optional[LMCache] = None,
    positions: Optional[Array] = None,
    return_hidden: bool = False,
) -> Tuple[Array, Optional[LMCache]]:
    """tokens (B,S[,nq]) int32 -> logits (B,S,Vpad[,nq]) f32.

    cache=None: train/prefill-from-zero (causal over S).
    cache!=None: decode/chunked-prefill at offset cache.pos."""
    B, S = tokens.shape[:2]
    offset = cache.pos if cache is not None else 0
    if positions is None:
        positions = default_positions(B, S, cfg.rope_variant)
        positions = positions + offset
    cos, sin = _rope(cfg, positions)

    x = _embed_tokens(params, tokens, cfg, positions)

    new_cache = None
    if cfg.family in ("dense", "moe", "audio", "vlm"):
        if cache is None:
            def body(h, lp):
                h, _ = attn_block(lp, h, cfg, cos, sin)
                return h, None
            x, _ = _scan(_maybe_ckpt(body, cfg), x, params["blocks"], cfg)
        else:
            def body(h, inp):
                lp, k_sl, v_sl = inp
                kv = KVCache(k_sl, v_sl, cache.pos)
                h, kv = attn_block(lp, h, cfg, cos, sin, kv=kv)
                return h, (kv.k, kv.v)
            x, (nk, nv) = _scan(body, x, (params["blocks"], cache.kv_k, cache.kv_v), cfg)
            new_cache = LMCache(pos=cache.pos + S, kv_k=nk, kv_v=nv)

    elif cfg.family == "ssm":
        if cache is None:
            def body(h, lp):
                h, _ = ssm_block(lp, h, cfg)
                return h, None
            x, _ = _scan(_maybe_ckpt(body, cfg), x, params["blocks"], cfg)
        else:
            def body(h, inp):
                lp, st, cv = inp
                sc = SSMCache(st, cv)
                h, sc = ssm_block(lp, h, cfg, cache=sc)
                return h, (sc.state, sc.conv)
            x, (ns, ncv) = _scan(body, x, (params["blocks"], cache.ssm_state, cache.conv), cfg)
            new_cache = LMCache(pos=cache.pos + S, ssm_state=ns, conv=ncv)

    elif cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        rem = cfg.n_layers % cfg.attn_every
        shared = params["shared"]
        if cache is None:
            def inner(h, lp):
                h, _ = ssm_block(lp, h, cfg)
                return h, None
            # nested remat: the outer checkpoint alone keeps a whole
            # 6-layer group's SSD internals live during its backward
            # (~6 GB/chip at zamba2 train_4k); per-layer checkpointing
            # inside the group bounds live internals to one layer.
            def outer(h, gp):
                h, _ = _scan(_maybe_ckpt(inner, cfg), h, gp, cfg)
                h, _ = attn_block(shared, h, cfg, cos, sin)   # shared weights
                return h, None
            x, _ = _scan(_maybe_ckpt(outer, cfg), x, params["blocks"], cfg)
            if rem:
                x, _ = _scan(_maybe_ckpt(inner, cfg), x, params["tail"], cfg)
        else:
            g_ssm = cache.ssm_state[: groups * cfg.attn_every].reshape(
                (groups, cfg.attn_every) + cache.ssm_state.shape[1:])
            g_conv = cache.conv[: groups * cfg.attn_every].reshape(
                (groups, cfg.attn_every) + cache.conv.shape[1:])
            def inner(h, inp):
                lp, st, cv = inp
                sc = SSMCache(st, cv)
                h, sc = ssm_block(lp, h, cfg, cache=sc)
                return h, (sc.state, sc.conv)
            def outer(h, inp):
                gp, st_g, cv_g, k_sl, v_sl = inp
                h, (ns, ncv) = _scan(inner, h, (gp, st_g, cv_g), cfg)
                kv = KVCache(k_sl, v_sl, cache.pos)
                h, kv = attn_block(shared, h, cfg, cos, sin, kv=kv)
                return h, (ns, ncv, kv.k, kv.v)
            x, (ns_g, ncv_g, nk, nv) = _scan(
                outer, x, (params["blocks"], g_ssm, g_conv, cache.kv_k, cache.kv_v), cfg)
            ns = ns_g.reshape((groups * cfg.attn_every,) + ns_g.shape[2:])
            ncv = ncv_g.reshape((groups * cfg.attn_every,) + ncv_g.shape[2:])
            if rem:
                x, (ns_t, ncv_t) = _scan(
                    inner, x,
                    (params["tail"], cache.ssm_state[-rem:], cache.conv[-rem:]), cfg)
                ns = jnp.concatenate([ns, ns_t], axis=0)
                ncv = jnp.concatenate([ncv, ncv_t], axis=0)
            new_cache = LMCache(pos=cache.pos + S, kv_k=nk, kv_v=nv,
                                ssm_state=ns, conv=ncv)
    else:
        raise ValueError(cfg.family)

    x = norm(params["final_norm"], x, cfg.norm)
    if return_hidden:
        return x, new_cache
    head = params["head"].astype(x.dtype)
    logits = (x @ head).astype(jnp.float32)
    logits = logical(logits, "batch", "seq", "vocab")
    if cfg.input_mode == "audio_tokens":
        logits = logits.reshape(B, S, cfg.n_codebooks, cfg.vocab_padded)
    return logits, new_cache


def _chunked_ce(x: Array, head: Array, labels: Array, cfg: LMConfig) -> Array:
    """Cross-entropy without materialising (B,S,Vpad) logits.

    Streams the head matmul in vocab chunks, carrying running (max,
    sum-exp, gold-logit) — the production memory trick for large-vocab
    models (yi/qwen2-vl/internlm2 save 2-3 GiB/chip at train_4k; §Perf G9).
    The pad columns fall in the final chunk and are masked there.
    """
    chunk = cfg.loss_vocab_chunk
    vpad = cfg.vocab_padded
    assert vpad % chunk == 0
    nch = vpad // chunk
    B, S, D = x.shape
    xf = x.reshape(B * S, D)
    lab = labels.reshape(B * S)
    head_c = head.reshape(D, nch, chunk)   # chunk view (no copy under XLA)

    def body(carry, i):
        m_prev, s_prev, gold_prev = carry
        hc = jax.lax.dynamic_index_in_dim(head_c, i, axis=1, keepdims=False)
        logits = (xf @ hc.astype(xf.dtype)).astype(jnp.float32)   # (T, chunk)
        col = i * chunk + jnp.arange(chunk)
        logits = jnp.where(col[None, :] >= cfg.vocab_size, -1e30, logits)
        m_c = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_prev, m_c)
        s_new = (s_prev * jnp.exp(m_prev - m_new)
                 + jnp.sum(jnp.exp(logits - m_new[:, None]), axis=-1))
        in_chunk = (lab >= i * chunk) & (lab < (i + 1) * chunk)
        local = jnp.clip(lab - i * chunk, 0, chunk - 1)
        gold_c = jnp.take_along_axis(logits, local[:, None], axis=1)[:, 0]
        gold_new = jnp.where(in_chunk, gold_c, gold_prev)
        return (m_new, s_new, gold_new), None

    init = (jnp.full((B * S,), -1e30, jnp.float32),
            jnp.zeros((B * S,), jnp.float32),
            jnp.zeros((B * S,), jnp.float32))
    (m, s_sum, gold), _ = jax.lax.scan(
        jax.checkpoint(body), init, jnp.arange(nch))
    logz = m + jnp.log(s_sum)
    return jnp.mean(logz - gold)


def lm_loss(params, batch: Dict[str, Array], cfg: LMConfig) -> Array:
    """Next-token cross-entropy; vocab padding masked out of the softmax."""
    if cfg.loss_vocab_chunk and cfg.input_mode != "audio_tokens" \
            and cfg.vocab_padded % cfg.loss_vocab_chunk == 0:
        x, _ = lm_forward(params, batch["tokens"], cfg,
                          positions=batch.get("positions"), return_hidden=True)
        return _chunked_ce(x, params["head"], batch["labels"], cfg)
    logits, _ = lm_forward(params, batch["tokens"], cfg,
                           positions=batch.get("positions"))
    labels = batch["labels"]
    vpad = cfg.vocab_padded
    if cfg.vocab_size != vpad:
        pad_mask = jnp.arange(vpad) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits) if cfg.input_mode != "audio_tokens" \
            else jnp.where(pad_mask[None, None, None], -1e30, logits)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
