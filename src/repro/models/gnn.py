"""The paper's GNN stack (§4/§5.2): GraphSAGE, GCN, SGC, GIN with the
compressed-embedding layer as the input features.

GraphSAGE follows Figure 4 exactly: sample -> code lookup -> decode ->
mean-aggregate -> concat -> linear(+ReLU), two layers, minibatched via
NeighborSampler.  GCN / SGC / GIN are full-graph (paper §C.1 trains them
without minibatches) over the normalised CSR adjacency; their input feature
matrix is the decoder output for ALL nodes (blocked decode), which is the
memory trade the paper makes for these models too.

Link prediction (§5.2): dot-product scores on final representations with
uniform negative sampling, BCE loss, hits@K evaluation.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.core import embedding as emb_lib
from repro.graph.csr import CSRMatrix
from repro.graph.sampler import FrontierBatch
from repro.nn import module as nn
from repro.parallel import sharding

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_gnn(key, cfg: GNNConfig, codes: Optional[Array] = None, aux=None) -> nn.Params:
    ks = nn.split_keys(key, ["embed", "l1", "l2", "out", "eps"])
    ecfg = cfg.embedding_config()
    params: nn.Params = {
        "embed": emb_lib.init_embedding(ks["embed"], ecfg, codes=codes, aux=aux),
    }
    d_e, H = cfg.d_e, cfg.hidden
    if cfg.model == "sage":
        params["w1"] = nn.dense_init(ks["l1"], (2 * d_e, H))
        params["b1"] = jnp.zeros((H,), jnp.float32)
        params["w2"] = nn.dense_init(ks["l2"], (2 * H, H))
        params["b2"] = jnp.zeros((H,), jnp.float32)
    elif cfg.model == "gcn":
        params["w1"] = nn.dense_init(ks["l1"], (d_e, H))
        params["b1"] = jnp.zeros((H,), jnp.float32)
        params["w2"] = nn.dense_init(ks["l2"], (H, H))
        params["b2"] = jnp.zeros((H,), jnp.float32)
    elif cfg.model == "sgc":
        params["w1"] = nn.dense_init(ks["l1"], (d_e, H))
        params["b1"] = jnp.zeros((H,), jnp.float32)
    elif cfg.model == "gin":
        params["eps1"] = jnp.zeros((), jnp.float32)
        params["eps2"] = jnp.zeros((), jnp.float32)
        params["mlp1"] = {
            "w1": nn.dense_init(ks["l1"], (d_e, H)), "b1": jnp.zeros((H,), jnp.float32),
            "w2": nn.dense_init(jax.random.fold_in(ks["l1"], 1), (H, H)),
            "b2": jnp.zeros((H,), jnp.float32),
        }
        params["mlp2"] = {
            "w1": nn.dense_init(ks["l2"], (H, H)), "b1": jnp.zeros((H,), jnp.float32),
            "w2": nn.dense_init(jax.random.fold_in(ks["l2"], 1), (H, H)),
            "b2": jnp.zeros((H,), jnp.float32),
        }
    else:
        raise ValueError(cfg.model)
    if cfg.task == "node":
        params["w_out"] = nn.dense_init(ks["out"], (H, cfg.n_classes))
        params["b_out"] = jnp.zeros((cfg.n_classes,), jnp.float32)
    return params


# ---------------------------------------------------------------------------
# GraphSAGE (minibatched, Figure 4)
# ---------------------------------------------------------------------------

def _sage_combine(params, h0: Array, h1: Array, h2: Array) -> Array:
    """Figure-4 aggregate/concat/linear stack on decoded level features
    h0 (B, de), h1 (B, f1, de), h2 (B, f1, f2, de)."""
    # layer 1 (applied to targets and first neighbours)
    agg0 = h1.mean(axis=1)                                          # (B, de)
    z0 = jax.nn.relu(jnp.concatenate([agg0, h0], -1) @ params["w1"] + params["b1"])
    agg1 = h2.mean(axis=2)                                          # (B, f1, de)
    z1 = jax.nn.relu(jnp.concatenate([agg1, h1], -1) @ params["w1"] + params["b1"])

    # layer 2 (targets only)
    aggz = z1.mean(axis=1)                                          # (B, H)
    z = jax.nn.relu(jnp.concatenate([aggz, z0], -1) @ params["w2"] + params["b2"])
    return z


def sage_forward(params, levels: List[Array], cfg: GNNConfig,
                 backend=None) -> Array:
    """Naive path — levels: [targets (B,), l1 (B,f1), l2 (B,f1,f2)] node ids,
    each decoded independently (B + B·f1 + B·f1·f2 decoder rows)."""
    ecfg = cfg.embedding_config()
    lk = lambda ids: emb_lib.embed_lookup(params["embed"], ids, ecfg,
                                          backend=backend)
    h0 = lk(levels[0])                                              # (B, de)
    h1 = lk(levels[1])                                              # (B, f1, de)
    h2 = lk(levels[2])                                              # (B, f1, f2, de)
    return _sage_combine(params, h0, h1, h2)


def sage_forward_frontier(params, fb: FrontierBatch, cfg: GNNConfig,
                          backend=None) -> Array:
    """Dedup-decode path: ONE batched decode-backend call over the unique
    frontier (exactly the (U, m) shape the Pallas kernel wants), then cheap
    gathers rebuild the per-level tensors.  Decoder rows per batch drop from
    B + B·f1 + B·f1·f2 to the (padded) unique-frontier count — the batch's
    duplication factor in decode throughput."""
    ecfg = cfg.embedding_config()
    ids = sharding.logical(fb.unique, "frontier")
    # batch-carried packed code rows (codes_placement="host"): row-aligned
    # with the frontier, so they shard on the same axis as the ids
    codes = (None if fb.codes is None
             else sharding.logical(fb.codes, "frontier", None))
    hu = emb_lib.embed_lookup(params["embed"], ids, ecfg,
                              backend=backend, plan=fb.plan,
                              codes=codes)                          # (U, de)
    hu = sharding.logical(hu, "frontier", None)
    h0 = hu[fb.index_maps[0]]                                       # (B, de)
    h1 = hu[fb.index_maps[1]]                                       # (B, f1, de)
    h2 = hu[fb.index_maps[2]]                                       # (B, f1, f2, de)
    return _sage_combine(params, h0, h1, h2)


def sage_forward_frontier_cached(params, fb: FrontierBatch, cfg: GNNConfig,
                                 cache_state, backend=None):
    """Hot-node-cached twin of ``sage_forward_frontier``.

    The unique-frontier decode goes through a ``CachedDecodeBackend`` keyed
    by node id: ids whose cached embedding is within the staleness budget are
    served from the cache (no gradient — they are constants from an earlier
    codebook version); the rest decode fresh through the backend and are
    written back.  Returns ``(hidden, new_cache_state)``."""
    from repro.core.backend import CachedDecodeBackend

    ecfg = cfg.embedding_config()
    cache = CachedDecodeBackend(staleness=ecfg.cache_staleness)
    ids = sharding.logical(fb.unique, "frontier")
    # frontier padding rows repeat unique[0] — mask them out of the cache so
    # they don't burn LRU slots or skew the hit/miss accounting (sharded
    # stacked frontiers carry an explicit mask: padding is per shard block,
    # not a global suffix)
    valid = fb.valid_mask()
    codes = (None if fb.codes is None
             else sharding.logical(fb.codes, "frontier", None))
    # the cache lookup wraps the whole owner exchange: decode_fn sees the
    # full (unpermuted) frontier ids, so the batch's OwnerPlan (and the
    # row-aligned batch codes) stay valid
    hu, new_state = cache.lookup(
        cache_state, ids,
        lambda i: emb_lib.embed_lookup(params["embed"], i, ecfg,
                                       backend=backend, plan=fb.plan,
                                       codes=codes),
        valid=valid)
    hu = sharding.logical(hu, "frontier", None)
    h0 = hu[fb.index_maps[0]]
    h1 = hu[fb.index_maps[1]]
    h2 = hu[fb.index_maps[2]]
    return _sage_combine(params, h0, h1, h2), new_state


def sage_forward_frontier_missonly(params, fb: FrontierBatch, cfg: GNNConfig,
                                   cache_state, n_decode: int, backend=None):
    """Serving twin of ``sage_forward_frontier_cached``: the frontier has
    been permuted miss-first host-side (``CachedDecodeBackend.
    plan_missonly``) so only the first ``n_decode`` rows — a static,
    shape-bucketed count — enter the decoder; every other valid row is
    served from the hot-node cache.  Returns ``(hidden, new_cache_state)``,
    bitwise identical to the uncached frontier forward."""
    from repro.core.backend import CachedDecodeBackend

    ecfg = cfg.embedding_config()
    cache = CachedDecodeBackend(staleness=ecfg.cache_staleness)
    ids = sharding.logical(fb.unique, "frontier")
    # decode_fn only sees the miss prefix ids[:n_decode]; the row-aligned
    # batch codes are sliced to match
    hu, new_state = cache.lookup_missonly(
        cache_state, ids,
        lambda i: emb_lib.embed_lookup(
            params["embed"], i, ecfg, backend=backend,
            codes=None if fb.codes is None else fb.codes[:i.shape[0]]),
        n_decode, valid=fb.valid_mask())
    hu = sharding.logical(hu, "frontier", None)
    h0 = hu[fb.index_maps[0]]
    h1 = hu[fb.index_maps[1]]
    h2 = hu[fb.index_maps[2]]
    return _sage_combine(params, h0, h1, h2), new_state


# ---------------------------------------------------------------------------
# full-graph models
# ---------------------------------------------------------------------------

def _all_features(params, cfg: GNNConfig) -> Array:
    ecfg = cfg.embedding_config()
    if ecfg.kind == "dense":
        return params["embed"]["table"]
    ids = jnp.arange(cfg.n_nodes, dtype=jnp.int32)
    return emb_lib.embed_lookup(params["embed"], ids, ecfg)


def fullgraph_forward(params, adj_norm: CSRMatrix, cfg: GNNConfig) -> Array:
    """Returns final hidden for all nodes (n, H)."""
    X = _all_features(params, cfg)
    if cfg.model == "gcn":
        h = jax.nn.relu(adj_norm.matmat(X) @ params["w1"] + params["b1"])
        h = adj_norm.matmat(h) @ params["w2"] + params["b2"]
        return h
    if cfg.model == "sgc":
        h = adj_norm.matmat(adj_norm.matmat(X))
        return h @ params["w1"] + params["b1"]
    if cfg.model == "gin":
        def gmlp(m, h):
            return jax.nn.relu(h @ m["w1"] + m["b1"]) @ m["w2"] + m["b2"]
        h = gmlp(params["mlp1"], (1 + params["eps1"]) * X + adj_norm.matmat(X))
        h = jax.nn.relu(h)
        h = gmlp(params["mlp2"], (1 + params["eps2"]) * h + adj_norm.matmat(h))
        return h
    raise ValueError(cfg.model)


# ---------------------------------------------------------------------------
# losses / metrics
# ---------------------------------------------------------------------------

def node_logits(params, hidden: Array, cfg: GNNConfig) -> Array:
    return hidden @ params["w_out"] + params["b_out"]


def node_loss(logits: Array, labels: Array) -> Array:
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def link_scores(hidden: Array, edges: Array) -> Array:
    """edges (E, 2) -> dot-product scores (E,)."""
    return jnp.sum(hidden[edges[:, 0]] * hidden[edges[:, 1]], axis=-1)


def link_loss(hidden: Array, pos_edges: Array, neg_edges: Array) -> Array:
    pos = link_scores(hidden, pos_edges)
    neg = link_scores(hidden, neg_edges)
    return (jnp.mean(jax.nn.softplus(-pos)) + jnp.mean(jax.nn.softplus(neg)))


def hits_at_k(pos_scores, neg_scores, k: int) -> float:
    """OGB hits@K: fraction of positives ranked above the K-th negative."""
    import numpy as np
    neg = np.sort(np.asarray(neg_scores))[::-1]
    thresh = neg[min(k, len(neg)) - 1]
    return float((np.asarray(pos_scores) > thresh).mean())


def accuracy(logits, labels) -> float:
    import numpy as np
    return float((np.asarray(jnp.argmax(logits, -1)) == np.asarray(labels)).mean())


def hit_rate_at_k(logits, labels, k: int) -> float:
    """§5.3 hit@k: label within top-k predicted categories."""
    import numpy as np
    topk = np.asarray(jax.lax.top_k(logits, k)[1])
    return float((topk == np.asarray(labels)[:, None]).any(axis=1).mean())
