from repro.models import lm
from repro.models.lm import LMCache, init_cache, init_lm, lm_forward, lm_loss

__all__ = ["lm", "LMCache", "init_cache", "init_lm", "lm_forward", "lm_loss"]
