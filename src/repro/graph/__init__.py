from repro.graph.csr import CSRMatrix
from repro.graph.generate import (
    powerlaw_graph,
    sbm_graph,
    bipartite_transaction_graph,
    clustered_embeddings,
)
from repro.graph.sampler import FrontierBatch, NeighborSampler

__all__ = [
    "CSRMatrix",
    "powerlaw_graph",
    "sbm_graph",
    "bipartite_transaction_graph",
    "clustered_embeddings",
    "FrontierBatch",
    "NeighborSampler",
]
