from repro.graph.csr import CSRMatrix
from repro.graph.generate import (
    powerlaw_graph,
    sbm_graph,
    bipartite_transaction_graph,
    clustered_embeddings,
)
from repro.graph.sampler import FrontierBatch, NeighborSampler

# runtime names resolve lazily: repro.graph.runtime pulls in the train/
# serving layers, which must not load just because someone imported the
# sampler (and would otherwise risk partially-initialised import cycles)
_RUNTIME_EXPORTS = ("GraphRuntime", "RuntimeSpec", "GraphSource",
                    "FullGraphSource")

__all__ = [
    "CSRMatrix",
    "powerlaw_graph",
    "sbm_graph",
    "bipartite_transaction_graph",
    "clustered_embeddings",
    "FrontierBatch",
    "NeighborSampler",
    *_RUNTIME_EXPORTS,
]


def __getattr__(name):
    if name in _RUNTIME_EXPORTS:
        from repro.graph import runtime as _runtime
        return getattr(_runtime, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
