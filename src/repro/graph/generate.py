"""Synthetic graph / embedding generators (DESIGN.md §7).

The container is offline, so OGB / GloVe / metapath2vec / transaction data
are replaced with generators matching the statistics the paper's claims
depend on:

* ``powerlaw_graph``     — preferential-attachment graph (heavy-tailed degree,
                           like ogbn-products) with planted community labels.
* ``sbm_graph``          — stochastic-block-model graph (clean community
                           signal, like ogbn-arxiv's citation clusters).
* ``bipartite_transaction_graph`` — consumer×merchant bipartite graph with
                           category-dependent attachment (the §5.3 stand-in).
* ``clustered_embeddings`` — Gaussian-mixture "pre-trained embeddings" with
                           planted cluster labels (metapath2vec stand-in for
                           the Fig. 1 reconstruction proxies).

All generators are numpy-based (host side, one-shot) and deterministic in
their seed.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.graph.csr import CSRMatrix


def powerlaw_graph(
    seed: int,
    n_nodes: int,
    avg_degree: int = 8,
    n_classes: int = 16,
    homophily: float = 0.8,
) -> Tuple[CSRMatrix, np.ndarray]:
    """Barabási–Albert-style preferential attachment with community-biased
    attachment; returns (symmetric CSR adjacency, node labels).

    ``homophily`` is the probability that a new edge attaches within the
    node's own community (label signal strength).
    """
    rng = np.random.default_rng(seed)
    k = max(1, avg_degree // 2)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)

    src = np.empty(n_nodes * k, np.int64)
    dst = np.empty(n_nodes * k, np.int64)
    # seed clique
    n0 = k + 1
    e = 0
    for i in range(1, n0):
        for j in range(i):
            if e < src.shape[0]:
                src[e], dst[e] = i, j
                e += 1
    # target pool for preferential attachment (endpoint repetition = degree bias)
    pool = np.concatenate([src[:e], dst[:e]])
    pool_by_class = [np.where(labels == cl)[0] for cl in range(n_classes)]
    for i in range(n0, n_nodes):
        same = rng.random(k) < homophily
        # preferential targets: sample from current endpoint pool
        t_pref = pool[rng.integers(0, max(len(pool), 1), k)] if len(pool) else rng.integers(0, i, k)
        # homophilous targets: uniform within the same community (among existing nodes)
        cls_pool = pool_by_class[labels[i]]
        cls_pool = cls_pool[cls_pool < i]
        if cls_pool.size:
            t_homo = cls_pool[rng.integers(0, cls_pool.size, k)]
        else:
            t_homo = rng.integers(0, i, k)
        targets = np.where(same, t_homo, t_pref)
        targets = np.minimum(targets, i - 1)
        src[e: e + k] = i
        dst[e: e + k] = targets
        e += k
        if i % 512 == 0:  # grow the pool occasionally (amortised)
            pool = np.concatenate([src[:e], dst[:e]])
    pool = None
    return CSRMatrix.from_edges(src[:e], dst[:e], n_nodes, symmetric=True), labels


def sbm_graph(
    seed: int,
    n_nodes: int,
    n_classes: int = 8,
    p_in: float = 0.02,
    p_out: float = 0.002,
    labels: "np.ndarray" = None,
) -> Tuple[CSRMatrix, np.ndarray]:
    """Sparse stochastic block model via per-node expected-degree sampling.
    ``labels`` pins the community assignment (e.g. to match a clustered
    embedding set — the Fig. 1 proxy needs BOTH auxiliaries to encode the
    same latent structure)."""
    rng = np.random.default_rng(seed)
    if labels is None:
        labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    labels = np.asarray(labels, np.int32)
    per_cls = [np.where(labels == cl)[0] for cl in range(n_classes)]
    exp_in = p_in * n_nodes / n_classes
    exp_out = p_out * n_nodes * (n_classes - 1) / n_classes
    srcs, dsts = [], []
    for i in range(n_nodes):
        k_in = rng.poisson(exp_in)
        k_out = rng.poisson(exp_out)
        cp = per_cls[labels[i]]
        if k_in and cp.size:
            srcs.append(np.full(k_in, i))
            dsts.append(cp[rng.integers(0, cp.size, k_in)])
        if k_out:
            srcs.append(np.full(k_out, i))
            dsts.append(rng.integers(0, n_nodes, k_out))
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    keep = src != dst
    return CSRMatrix.from_edges(src[keep], dst[keep], n_nodes, symmetric=True), labels


def bipartite_transaction_graph(
    seed: int,
    n_consumers: int,
    n_merchants: int,
    n_categories: int = 64,
    avg_tx_per_consumer: int = 12,
    consumer_affinity: int = 3,
) -> Tuple[CSRMatrix, np.ndarray, int]:
    """Consumer–merchant bipartite graph (paper §5.3 stand-in).

    Nodes [0, n_consumers) are consumers, [n_consumers, n) merchants.
    Each consumer has ``consumer_affinity`` preferred categories; transaction
    targets are drawn from preferred categories with popularity bias (Zipf),
    producing both the category signal and the extreme degree imbalance the
    paper describes.  Returns (adjacency, merchant_labels, n_consumers).
    """
    rng = np.random.default_rng(seed)
    n = n_consumers + n_merchants
    merchant_cat = rng.integers(0, n_categories, n_merchants).astype(np.int32)
    merchants_by_cat = [np.where(merchant_cat == cl)[0] for cl in range(n_categories)]
    # Zipf popularity within category
    pop = {}
    for cl in range(n_categories):
        sz = merchants_by_cat[cl].size
        if sz:
            w = 1.0 / np.arange(1, sz + 1) ** 1.1
            pop[cl] = w / w.sum()
    srcs, dsts = [], []
    aff = rng.integers(0, n_categories, (n_consumers, consumer_affinity))
    for i in range(n_consumers):
        k = max(1, rng.poisson(avg_tx_per_consumer))
        cats = aff[i, rng.integers(0, consumer_affinity, k)]
        tgt = np.empty(k, np.int64)
        for j, cl in enumerate(cats):
            mbc = merchants_by_cat[cl]
            if mbc.size:
                tgt[j] = mbc[rng.choice(mbc.size, p=pop[cl])]
            else:
                tgt[j] = rng.integers(0, n_merchants)
        srcs.append(np.full(k, i))
        dsts.append(tgt + n_consumers)
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    adj = CSRMatrix.from_edges(src, dst, n, symmetric=True)
    return adj, merchant_cat, n_consumers


def clustered_embeddings(
    seed: int,
    n: int,
    dim: int,
    n_clusters: int = 8,
    noise: float = 0.35,
) -> Tuple[np.ndarray, np.ndarray]:
    """Gaussian-mixture 'pre-trained embeddings' + planted labels.

    Cluster centres are random orthogonal-ish directions; ``noise`` controls
    intra-cluster spread (≈ metapath2vec's NMI-recoverable structure)."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((n_clusters, dim)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    labels = rng.integers(0, n_clusters, n).astype(np.int32)
    emb = centers[labels] + noise * rng.standard_normal((n, dim)).astype(np.float32)
    return emb.astype(np.float32), labels


def train_val_test_split(seed: int, n: int, frac=(0.7, 0.1, 0.2)):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    n_tr = int(frac[0] * n)
    n_va = int(frac[1] * n)
    return perm[:n_tr], perm[n_tr: n_tr + n_va], perm[n_tr + n_va:]


def holdout_edges(seed: int, adj: CSRMatrix, frac: float = 0.1):
    """Link-prediction split: returns (train_adj, pos_eval_edges (E,2)).

    Held-out edges are removed from the training adjacency (both directions).
    """
    rng = np.random.default_rng(seed)
    rid = np.asarray(adj.row_ids())
    cid = np.asarray(adj.indices)
    upper = rid < cid
    er, ec = rid[upper], cid[upper]
    n_hold = int(frac * er.shape[0])
    hold = rng.choice(er.shape[0], n_hold, replace=False)
    mask = np.zeros(er.shape[0], bool)
    mask[hold] = True
    keep_r = np.concatenate([er[~mask], ec[~mask]])
    keep_c = np.concatenate([ec[~mask], er[~mask]])
    train = CSRMatrix.from_coo(keep_r, keep_c, np.ones_like(keep_r, np.float32), adj.shape)
    return train, np.stack([er[mask], ec[mask]], axis=1)
