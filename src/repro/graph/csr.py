"""Compressed-row-storage adjacency (paper §3.1: "it is preferred to store A
as a sparse matrix in CRS format as all the operations on A are row-wise").

A minimal immutable CSR matrix registered as a JAX pytree so it can flow
through jit boundaries.  Row-wise ops used by the framework:
  * ``matvec``/``matmat`` (random projection in Algorithm 1)
  * ``row_ids`` (segment ids for scatter-style SpMM in GNNs)
  * ``degree-normalised`` variants for GCN/SGC propagation
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSRMatrix:
    data: jnp.ndarray      # (nnz,) float
    indices: jnp.ndarray   # (nnz,) int32 column ids
    indptr: jnp.ndarray    # (n_rows + 1,) int32
    shape: Tuple[int, int] # static

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return (self.data, self.indices, self.indptr), self.shape

    @classmethod
    def tree_unflatten(cls, shape, leaves):
        return cls(*leaves, shape=shape)

    # -- constructors ----------------------------------------------------
    @classmethod
    def from_coo(cls, rows, cols, vals, shape) -> "CSRMatrix":
        rows = np.asarray(rows, np.int64)
        cols = np.asarray(cols, np.int32)
        vals = np.asarray(vals, np.float32)
        order = np.argsort(rows, kind="stable")
        rows, cols, vals = rows[order], cols[order], vals[order]
        indptr = np.zeros(shape[0] + 1, np.int32)
        np.add.at(indptr, rows + 1, 1)
        indptr = np.cumsum(indptr, dtype=np.int32)
        return cls(jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(indptr), tuple(shape))

    @classmethod
    def from_edges(cls, src, dst, n_nodes: int, symmetric: bool = True) -> "CSRMatrix":
        """Unweighted adjacency from an edge list; optionally symmetrised
        (the paper converts directed graphs to undirected)."""
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        if symmetric:
            s = np.concatenate([src, dst])
            d = np.concatenate([dst, src])
        else:
            s, d = src, dst
        # dedupe parallel edges
        key = s * n_nodes + d
        key = np.unique(key)
        s, d = key // n_nodes, key % n_nodes
        return cls.from_coo(s, d, np.ones_like(s, np.float32), (n_nodes, n_nodes))

    # -- row-wise operations ----------------------------------------------
    @property
    def nnz(self) -> int:
        return int(self.data.shape[0])

    def row_ids(self) -> jnp.ndarray:
        """(nnz,) row index of every stored element."""
        return jnp.searchsorted(
            self.indptr, jnp.arange(self.nnz, dtype=self.indptr.dtype), side="right"
        ).astype(jnp.int32) - 1

    def degrees(self) -> jnp.ndarray:
        return (self.indptr[1:] - self.indptr[:-1]).astype(jnp.float32)

    def matmat(self, X: jnp.ndarray) -> jnp.ndarray:
        """A @ X for dense X, via gather + segment-sum (row-wise)."""
        contrib = self.data[:, None] * X[self.indices]
        return jax.ops.segment_sum(contrib, self.row_ids(), num_segments=self.shape[0])

    def normalized(self, kind: str = "sym") -> "CSRMatrix":
        """GCN-style D^-1/2 (A+I) D^-1/2 requires adding self loops first;
        here we normalise the existing pattern: 'sym' -> d_i^-1/2 d_j^-1/2,
        'row' -> d_i^-1."""
        deg = np.asarray(jax.device_get(self.degrees()))
        deg = np.maximum(deg, 1.0)
        rid = np.asarray(jax.device_get(self.row_ids()))
        cid = np.asarray(jax.device_get(self.indices))
        dat = np.asarray(jax.device_get(self.data))
        if kind == "sym":
            vals = dat / np.sqrt(deg[rid] * deg[cid])
        elif kind == "row":
            vals = dat / deg[rid]
        else:
            raise ValueError(kind)
        return CSRMatrix(jnp.asarray(vals), self.indices, self.indptr, self.shape)

    def with_self_loops(self) -> "CSRMatrix":
        rid = np.asarray(jax.device_get(self.row_ids()))
        cid = np.asarray(jax.device_get(self.indices))
        dat = np.asarray(jax.device_get(self.data))
        n = self.shape[0]
        rows = np.concatenate([rid, np.arange(n)])
        cols = np.concatenate([cid, np.arange(n)])
        vals = np.concatenate([dat, np.ones(n, np.float32)])
        return CSRMatrix.from_coo(rows, cols, vals, self.shape)

    def to_dense(self) -> jnp.ndarray:
        out = jnp.zeros(self.shape, self.data.dtype)
        return out.at[self.row_ids(), self.indices].add(self.data)

    def neighbor_padded(self, max_deg: int) -> Tuple[np.ndarray, np.ndarray]:
        """Host-side: (n, max_deg) neighbour table padded with -1 + (n,) true
        degree.  Used by the uniform neighbour sampler."""
        indptr = np.asarray(jax.device_get(self.indptr))
        indices = np.asarray(jax.device_get(self.indices))
        n = self.shape[0]
        table = np.full((n, max_deg), -1, np.int32)
        deg = (indptr[1:] - indptr[:-1]).astype(np.int32)
        rid = np.repeat(np.arange(n, dtype=np.int64), deg)
        pos = np.arange(indices.shape[0], dtype=np.int64) - indptr[rid]
        keep = pos < max_deg
        table[rid[keep], pos[keep]] = indices[keep]
        return table, deg
