"""Uniform neighbour sampling (GraphSAGE, paper §4 / Fig. 4).

Sampling happens host-side (numpy) against the padded neighbour table and
yields fixed-shape device batches:

  step 0: batch of target nodes                     (B,)
  step 1: fanout[0] first neighbours per target     (B, f1)
  step 2: fanout[1] second neighbours per first     (B, f1, f2)

Isolated nodes self-sample (pad with the node itself), matching the common
GraphSAGE implementation behaviour.

Dedup-decode frontier (``sample_frontier``): minibatches of a real graph
contain massive node overlap across levels — hubs appear hundreds of times
in one ``(B, f1, f2)`` tensor.  ``FrontierBatch`` carries the *unique* node
frontier plus int32 index maps per level, so the embedding decoder runs once
per unique node and the per-level tensors are rebuilt with cheap gathers
(``unique[index_maps[i]] == levels[i]``).  The frontier is padded to a
multiple of ``pad_to`` so jit sees a small, bounded set of shapes.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.graph.csr import CSRMatrix


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FrontierBatch:
    """Deduplicated sampled minibatch.

    ``unique``     (U_pad,) int32 — unique node ids, padded by repeating
                   ``unique[0]`` (padding rows decode to valid embeddings
                   that no index map points at).
    ``index_maps`` per level, int32 indices into ``unique`` with the naive
                   level shapes: (B,), (B, f1), (B, f1, f2), ...
    ``n_unique``   () int32 — true unique count before padding (a leaf, not
                   static metadata, so varying it never retriggers jit).
    """

    unique: np.ndarray
    index_maps: Tuple[np.ndarray, ...]
    n_unique: np.ndarray

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return (self.unique, self.n_unique) + tuple(self.index_maps), None

    @classmethod
    def tree_unflatten(cls, _aux, leaves):
        return cls(leaves[0], tuple(leaves[2:]), leaves[1])

    # -- construction ----------------------------------------------------
    @classmethod
    def from_levels(cls, levels: Sequence[np.ndarray], pad_to: int = 256) -> "FrontierBatch":
        """Dedup a naive level list into a frontier + per-level index maps."""
        levels = [np.asarray(l) for l in levels]
        flat = np.concatenate([l.ravel() for l in levels])
        uniq, inv = np.unique(flat, return_inverse=True)
        n_unique = uniq.shape[0]
        cap = -(-n_unique // max(pad_to, 1)) * max(pad_to, 1)
        if cap > n_unique:
            uniq = np.concatenate(
                [uniq, np.full(cap - n_unique, uniq[0], uniq.dtype)])
        maps, off = [], 0
        for l in levels:
            maps.append(inv[off:off + l.size].reshape(l.shape).astype(np.int32))
            off += l.size
        return cls(uniq.astype(np.int32), tuple(maps), np.int32(n_unique))

    @property
    def targets(self):
        """Level-0 (target) node ids, reconstructed from the frontier."""
        return self.unique[self.index_maps[0]]

    def levels(self) -> List[np.ndarray]:
        """Rebuild the naive level list (testing / fallback path)."""
        return [self.unique[m] for m in self.index_maps]


class NeighborSampler:
    def __init__(self, adj: CSRMatrix, fanouts: Sequence[int], max_deg: int = 64, seed: int = 0):
        self.fanouts = tuple(fanouts)
        self.table, self.deg = adj.neighbor_padded(max_deg)
        self.max_deg = max_deg
        self.rng = np.random.default_rng(seed)

    def _sample_level(self, nodes: np.ndarray, fanout: int,
                      rng: Optional[np.random.Generator] = None) -> np.ndarray:
        """nodes: (...,) -> (..., fanout) sampled neighbour ids."""
        rng = rng if rng is not None else self.rng
        flat = nodes.reshape(-1)
        deg = np.minimum(self.deg[flat], self.max_deg)
        idx = rng.integers(0, np.maximum(deg, 1)[:, None], (flat.shape[0], fanout))
        nbr = self.table[flat[:, None], idx]
        # isolated nodes (-1 entries): fall back to self
        nbr = np.where(nbr < 0, flat[:, None], nbr)
        return nbr.reshape(*nodes.shape, fanout).astype(np.int32)

    def sample(self, batch_nodes: np.ndarray,
               rng: Optional[np.random.Generator] = None) -> List[np.ndarray]:
        """Returns [targets (B,), level1 (B,f1), level2 (B,f1,f2), ...].

        ``rng`` overrides the sampler's stateful generator — pass a per-step
        seeded generator to make the batch a pure function of the step index
        (restart-safe resume, prefetch == sync determinism).
        """
        levels = [batch_nodes.astype(np.int32)]
        cur = batch_nodes
        for f in self.fanouts:
            cur = self._sample_level(cur, f, rng=rng)
            levels.append(cur)
        return levels

    def sample_frontier(self, batch_nodes: np.ndarray, pad_to: int = 256,
                        rng: Optional[np.random.Generator] = None) -> FrontierBatch:
        """Sample and dedup in one call (the engine's fast path)."""
        return FrontierBatch.from_levels(self.sample(batch_nodes, rng=rng), pad_to=pad_to)

    def minibatches(self, nodes: np.ndarray, batch_size: int, shuffle: bool = True):
        """Yield (levels, batch_node_ids); final short batch is wrapped (padded
        by resampling from the start) so shapes stay static for jit."""
        for batch in self._batch_ids(nodes, batch_size, shuffle):
            yield self.sample(batch), batch

    def frontier_minibatches(self, nodes: np.ndarray, batch_size: int,
                             shuffle: bool = True, pad_to: int = 256):
        """Dedup-decode twin of ``minibatches``: yields (FrontierBatch, ids)."""
        for batch in self._batch_ids(nodes, batch_size, shuffle):
            yield self.sample_frontier(batch, pad_to=pad_to), batch

    def _batch_ids(self, nodes: np.ndarray, batch_size: int, shuffle: bool):
        order = self.rng.permutation(nodes) if shuffle else np.asarray(nodes)
        n = order.shape[0]
        for s in range(0, n, batch_size):
            batch = order[s: s + batch_size]
            if batch.shape[0] < batch_size:
                pad = order[: batch_size - batch.shape[0]]
                batch = np.concatenate([batch, pad])
            yield batch
