"""Uniform neighbour sampling (GraphSAGE, paper §4 / Fig. 4).

Sampling happens host-side (numpy) against the padded neighbour table and
yields fixed-shape device batches:

  step 0: batch of target nodes                     (B,)
  step 1: fanout[0] first neighbours per target     (B, f1)
  step 2: fanout[1] second neighbours per first     (B, f1, f2)

Isolated nodes self-sample (pad with the node itself), matching the common
GraphSAGE implementation behaviour.

Dedup-decode frontier (``sample_frontier``): minibatches of a real graph
contain massive node overlap across levels — hubs appear hundreds of times
in one ``(B, f1, f2)`` tensor.  ``FrontierBatch`` carries the *unique* node
frontier plus int32 index maps per level, so the embedding decoder runs once
per unique node and the per-level tensors are rebuilt with cheap gathers
(``unique[index_maps[i]] == levels[i]``).  The frontier is padded to a
multiple of ``pad_to`` so jit sees a small, bounded set of shapes (or to an
exact ``cap`` so sharded runs can stack equal-size per-shard frontiers).

Sharded sampling (``sample_hashed``): multi-host data parallelism slices one
*global* batch across shards, and every target's neighbour subtree must be
reproducible no matter which shard draws it.  Stateful generators can't give
that (the draw for position i depends on how many positions preceded it), so
neighbour slots are counter-based: slot k under the subtree node at path
``p`` is ``mix64(level_key ^ (p * PATH_STRIDE + k + 1)) % degree``, where
``level_key`` folds the tree level into ``stream_key(seed, step)``.  Path
counters are unique *within* a level by construction (children of distinct
parents get distinct counter ranges); the per-level key makes cross-level
counter reuse harmless — without it, a global batch larger than the path
stride would correlate a deep target's draws with a shallow child's.  The
draw is a pure function of ``(seed, step, global position, path)`` —
slicing the batch by shard cannot change any subtree, which is what makes
the N-shard union bit-identical to the 1-shard batch.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.graph.csr import CSRMatrix

# Counter layout for hashed sampling: a subtree node at path id p draws its
# k-th neighbour from counter p*_PATH_STRIDE + k + 1 (the +1 keeps child path
# ids distinct from their parent).  Fanouts must stay below the stride.
_PATH_STRIDE = np.uint64(1024)


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finaliser — a bijective avalanche mix on uint64."""
    with np.errstate(over="ignore"):
        x = np.asarray(x, np.uint64)
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return x ^ (x >> np.uint64(31))


def stream_key(seed: int, step: int) -> np.uint64:
    """Per-(seed, step) key for counter-based sampling — shard-independent,
    so every shard of one step draws from the same keyed hash function."""
    with np.errstate(over="ignore"):
        k = np.uint64(seed) * np.uint64(0x9E3779B97F4A7C15) + np.uint64(step)
    return np.uint64(_mix64(k))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class OwnerPlan:
    """Host-built routing plan for the owner-computes cross-shard decode
    (``lookup_impl="owner"``, see ``core.backend.OwnerBackend``).

    Frontier rows are hash-partitioned by ``owner = node_id % n_shards``;
    every array below is **stacked along the shard axis** (leading dim
    ``n_shards``) so the same data-axis placement that shards the frontier
    rows puts each shard's slice of the plan on its device.  All shapes are
    static (``owner_cap`` request slots per (requester, owner) pair,
    ``owner_unique_cap`` decode rows per owner), so jit sees one shape per
    source configuration no matter how the per-step buckets fill.

    ``req_rows``   (n, n, owner_cap) int32 — [requester s][owner o][slot] =
                   row index into s's local ``cap`` frontier block, or the
                   sentinel ``cap`` for unused slots (dropped on scatter).
    ``owned_src``  (n, owner_unique_cap) int32 — [owner o][j] = position in
                   o's received flat (n·owner_cap,) request buffer of the
                   representative occurrence of its j-th owned-unique id
                   (0-padded past ``n_owned[o]``).
    ``ret_idx``    (n, n, owner_cap) int32 — [owner o][requester s][slot] =
                   index into o's decoded (owner_unique_cap,) rows answering
                   that request slot (0-padded).
    ``n_owned``    (n,) int32 — true owned-unique count per owner: the rows
                   each device actually decodes (the dedup accounting the
                   benchmarks report as ``rows_decoded_per_device``).
    """

    req_rows: np.ndarray
    owned_src: np.ndarray
    ret_idx: np.ndarray
    n_owned: np.ndarray

    def tree_flatten(self):
        return (self.req_rows, self.owned_src, self.ret_idx,
                self.n_owned), None

    @classmethod
    def tree_unflatten(cls, _aux, leaves):
        return cls(*leaves)

    @property
    def n_shards(self) -> int:
        return self.req_rows.shape[0]

    @property
    def owner_cap(self) -> int:
        return self.req_rows.shape[2]

    @property
    def owner_unique_cap(self) -> int:
        return self.owned_src.shape[1]


# Default safety factor for the per-(requester, owner) request buckets: a
# bucket's expected fill is n_unique_s / n_shards ≤ cap / n_shards, so the
# 1.25 headroom absorbs hash skew across the id residue classes (asserted
# never to overflow on splitmix64-drawn frontiers in tests/test_sharded.py).
OWNER_SAFETY = 1.25


def default_owner_caps(cap: int, n_shards: int,
                       safety: float = OWNER_SAFETY) -> Tuple[int, int]:
    """Static capacities ``(owner_cap, owner_unique_cap)`` for the owner
    exchange, sized from the per-shard frontier ``cap``.

    ``owner_cap`` (request slots per (requester, owner) pair) is the
    expected bucket fill ``cap / n_shards`` with ``safety`` headroom.
    ``owner_unique_cap`` (decode rows per owner) is ``cap / 2``: the owner
    decode is only selected when measured duplication
    ``frontier_rows / unique_rows`` exceeds ``OWNER_DUP_THRESHOLD`` (= 2, see
    ``core.backend``), and duplication > 2 *implies* per-owner unique
    ``global_unique / n ≤ (Σ_s n_unique_s) / n < cap / 2`` — the capacity
    rule and the selection threshold are the same inequality.  Both are
    rounded up to the sublane multiple (8); overflow at runtime falls back
    loudly (``build_owner_plan`` returns None), never truncates."""
    def up8(x: int) -> int:
        return -(-int(x) // 8) * 8
    oc = min(up8(-(-cap * safety // n_shards)), cap)
    ou = min(up8(-(-cap // 2)), n_shards * oc)
    return int(oc), int(ou)


def remap_shard_state(state: dict, n_shards: int, shard: int = 0) -> dict:
    """Remap a batch-source ``state_dict`` onto a different shard count.

    This is the sampler-state half of an exact rescale
    (``repro.elastic.rescale``).  It is *exact* because the hashed draw is a
    pure function of ``(seed, step, global position, path)``: the global
    batch at a given ``(seed, step)`` does not depend on ``n_shards`` at all
    — shards merely slice it — so carrying ``(seed, step)`` over and
    stamping the new layout reproduces, bit for bit, the stream a run at
    the new shard count would have drawn from scratch.  The only
    requirement (checked by ``rescale_spec``) is that the *global* batch
    size stays fixed and divides evenly by the new shard count.

    ``miss_shadow`` (the single-shard cache-miss replay state, see
    ``engine.MissPlanningSource``) is layout-dependent and is deliberately
    dropped: the rescaled run replans misses against its own cache.
    """
    return {
        "step": int(state["step"]),
        "seed": int(state["seed"]),
        "shard": int(shard),
        "n_shards": int(n_shards),
    }


def build_owner_plan(uniques: Sequence[np.ndarray], n_uniques: Sequence[int],
                     n_shards: int, owner_cap: int,
                     owner_unique_cap: int) -> Optional[OwnerPlan]:
    """Build the owner-computes exchange plan for one stacked frontier.

    ``uniques``: the n_shards per-shard frontier blocks (each (cap,) int32,
    valid prefix of length ``n_uniques[s]``).  Rows are bucketed by
    ``id % n_shards``; each owner dedups the requests it receives across all
    requesters so every distinct owned id is decoded exactly once.  Returns
    ``None`` when any (requester, owner) bucket exceeds ``owner_cap`` or any
    owner's unique set exceeds ``owner_unique_cap`` — the caller must fall
    back loudly (emit the batch without a plan), NEVER truncate: a dropped
    row would silently decode to zeros."""
    n = int(n_shards)
    cap = int(np.asarray(uniques[0]).shape[0])
    req_rows = np.full((n, n, owner_cap), cap, np.int32)
    requests = [[None] * n for _ in range(n)]
    for s in range(n):
        ids = np.asarray(uniques[s])[:int(n_uniques[s])]
        own = ids % n
        for o in range(n):
            rows = np.nonzero(own == o)[0]
            if rows.shape[0] > owner_cap:
                return None                     # bucket overflow: loud fallback
            req_rows[s, o, :rows.shape[0]] = rows
            requests[s][o] = ids[rows]
    owned_src = np.zeros((n, owner_unique_cap), np.int32)
    ret_idx = np.zeros((n, n, owner_cap), np.int32)
    n_owned = np.zeros((n,), np.int32)
    for o in range(n):
        # owner o's received buffer: requester s's segment at offset s*owner_cap
        flat = np.full((n * owner_cap,), -1, np.int64)
        for s in range(n):
            k = requests[s][o].shape[0]
            flat[s * owner_cap:s * owner_cap + k] = requests[s][o]
        pos = np.nonzero(flat >= 0)[0]
        uniq, first, inv = np.unique(flat[pos], return_index=True,
                                     return_inverse=True)
        if uniq.shape[0] > owner_unique_cap:
            return None                         # owned overflow: loud fallback
        owned_src[o, :uniq.shape[0]] = pos[first]
        n_owned[o] = uniq.shape[0]
        ridx = np.zeros((n * owner_cap,), np.int32)
        ridx[pos] = inv.astype(np.int32)
        ret_idx[o] = ridx.reshape(n, owner_cap)
    return OwnerPlan(req_rows, owned_src, ret_idx, n_owned)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FrontierBatch:
    """Deduplicated sampled minibatch.

    ``unique``     (U_pad,) int32 — unique node ids, padded by repeating
                   ``unique[0]`` (padding rows decode to valid embeddings
                   that no index map points at).
    ``index_maps`` per level, int32 indices into ``unique`` with the naive
                   level shapes: (B,), (B, f1), (B, f1, f2), ...
    ``n_unique``   () int32 — true unique count before padding (a leaf, not
                   static metadata, so varying it never retriggers jit).
    ``valid``      optional (U_pad,) bool — explicit non-padding-row mask.
                   ``None`` (the single-frontier case) means the prefix mask
                   ``arange(U_pad) < n_unique``; sharded *stacked* batches
                   (``ShardedSageBatchSource``) carry per-shard segments
                   whose padding is interleaved, so they set it explicitly.
    ``plan``       optional ``OwnerPlan`` — host-built routing for the
                   owner-computes cross-shard decode; only stacked sharded
                   batches whose source enables it carry one.  Padding rows
                   of a planned batch are decoded to zeros instead of
                   duplicate embeddings (no index map points at them).
    ``n_decode``   optional int — static miss-first decode count.  Set by
                   ``graph.engine.MissPlanningSource``: the frontier has been
                   permuted so rows [0, n_decode) are the planned cache
                   misses and every valid row past it is a predicted cache
                   hit (``CachedDecodeBackend.lookup_missonly`` semantics).
                   Static (pytree aux, not a leaf): each bucketed value
                   retraces jit once, exactly like the serving engine's
                   miss buckets.
    ``codes``      optional (U_pad, n_words) uint32 — the frontier rows of
                   the packed code buffer (``codes_buf[unique]``), gathered
                   host-side when ``codes_placement="host"`` so the device
                   never holds the full O(#nodes) buffer.  Row-aligned with
                   ``unique`` (attach AFTER any permutation/stacking).
    """

    unique: np.ndarray
    index_maps: Tuple[np.ndarray, ...]
    n_unique: np.ndarray
    valid: Optional[np.ndarray] = None
    plan: Optional[OwnerPlan] = None
    n_decode: Optional[int] = None
    codes: Optional[np.ndarray] = None

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        leaves = (self.unique, self.n_unique) + tuple(self.index_maps)
        aux = (len(self.index_maps), self.valid is not None,
               self.plan is not None, self.n_decode,
               self.codes is not None)
        if self.valid is not None:
            leaves = leaves + (self.valid,)
        if self.plan is not None:
            leaves = leaves + (self.plan,)
        if self.codes is not None:
            leaves = leaves + (self.codes,)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        # aux grew a trailing has_codes flag; accept the old 4-tuple too so
        # treedefs pickled before the codes leaf still unflatten.
        n_maps, has_valid, has_plan, n_decode = aux[:4]
        has_codes = aux[4] if len(aux) > 4 else False
        maps = tuple(leaves[2:2 + n_maps])
        rest = list(leaves[2 + n_maps:])
        valid = rest.pop(0) if has_valid else None
        plan = rest.pop(0) if has_plan else None
        codes = rest.pop(0) if has_codes else None
        return cls(leaves[0], maps, leaves[1], valid, plan, n_decode, codes)

    # -- construction ----------------------------------------------------
    @classmethod
    def from_levels(cls, levels: Sequence[np.ndarray], pad_to: int = 256,
                    cap: Optional[int] = None) -> "FrontierBatch":
        """Dedup a naive level list into a frontier + per-level index maps.

        ``cap`` pads the frontier to exactly that many rows instead of the
        next ``pad_to`` multiple — sharded runs need every shard's frontier
        the same size so the stacked (n_shards·cap,) axis splits evenly
        across devices.  Raises when the true unique count exceeds it."""
        levels = [np.asarray(l) for l in levels]
        flat = np.concatenate([l.ravel() for l in levels])
        uniq, inv = np.unique(flat, return_inverse=True)
        n_unique = uniq.shape[0]
        if cap is None:
            cap = -(-n_unique // max(pad_to, 1)) * max(pad_to, 1)
        elif n_unique > cap:
            raise ValueError(
                f"frontier has {n_unique} unique nodes > cap={cap}; raise "
                f"frontier_cap (or shrink batch/fanout)")
        if cap > n_unique:
            uniq = np.concatenate(
                [uniq, np.full(cap - n_unique, uniq[0], uniq.dtype)])
        maps, off = [], 0
        for l in levels:
            maps.append(inv[off:off + l.size].reshape(l.shape).astype(np.int32))
            off += l.size
        return cls(uniq.astype(np.int32), tuple(maps), np.int32(n_unique))

    def valid_mask(self):
        """(U_pad,) bool — True on genuine (non-padding) frontier rows."""
        if self.valid is not None:
            return self.valid
        import jax.numpy as jnp
        return jnp.arange(self.unique.shape[0], dtype=jnp.int32) < self.n_unique

    @property
    def targets(self):
        """Level-0 (target) node ids, reconstructed from the frontier."""
        return self.unique[self.index_maps[0]]

    def levels(self) -> List[np.ndarray]:
        """Rebuild the naive level list (testing / fallback path)."""
        return [self.unique[m] for m in self.index_maps]


def attach_codes(fb: FrontierBatch, host_codes: np.ndarray) -> FrontierBatch:
    """Gather the frontier's packed code rows from the host buffer.

    ``codes_placement="host"``'s producer-side step: a numpy fancy-index
    ``host_codes[fb.unique]`` (identical bit pattern to the device-side
    ``jnp.take(codes_buf, ids)`` it replaces), attached as the batch's
    ``codes`` leaf.  MUST run after any frontier permutation or stacking —
    it keys off the *final* ``unique`` — which is why the prefetch producer
    and the serving engine call it outermost, on the emitted batch."""
    if fb.codes is not None:
        return fb
    ids = np.asarray(fb.unique)
    rows = np.ascontiguousarray(
        np.asarray(host_codes, np.uint32)[ids])     # (U_pad, n_words)
    return dataclasses.replace(fb, codes=rows)


class NeighborSampler:
    def __init__(self, adj: CSRMatrix, fanouts: Sequence[int], max_deg: int = 64, seed: int = 0):
        self.fanouts = tuple(fanouts)
        self.table, self.deg = adj.neighbor_padded(max_deg)
        self.max_deg = max_deg
        self.rng = np.random.default_rng(seed)

    def _sample_level(self, nodes: np.ndarray, fanout: int,
                      rng: Optional[np.random.Generator] = None) -> np.ndarray:
        """nodes: (...,) -> (..., fanout) sampled neighbour ids."""
        rng = rng if rng is not None else self.rng
        flat = nodes.reshape(-1)
        deg = np.minimum(self.deg[flat], self.max_deg)
        idx = rng.integers(0, np.maximum(deg, 1)[:, None], (flat.shape[0], fanout))
        nbr = self.table[flat[:, None], idx]
        # isolated nodes (-1 entries): fall back to self
        nbr = np.where(nbr < 0, flat[:, None], nbr)
        return nbr.reshape(*nodes.shape, fanout).astype(np.int32)

    def sample(self, batch_nodes: np.ndarray,
               rng: Optional[np.random.Generator] = None) -> List[np.ndarray]:
        """Returns [targets (B,), level1 (B,f1), level2 (B,f1,f2), ...].

        ``rng`` overrides the sampler's stateful generator — pass a per-step
        seeded generator to make the batch a pure function of the step index
        (restart-safe resume, prefetch == sync determinism).
        """
        levels = [batch_nodes.astype(np.int32)]
        cur = batch_nodes
        for f in self.fanouts:
            cur = self._sample_level(cur, f, rng=rng)
            levels.append(cur)
        return levels

    def sample_frontier(self, batch_nodes: np.ndarray, pad_to: int = 256,
                        rng: Optional[np.random.Generator] = None) -> FrontierBatch:
        """Sample and dedup in one call (the engine's fast path)."""
        return FrontierBatch.from_levels(self.sample(batch_nodes, rng=rng), pad_to=pad_to)

    # -- counter-based (shard-sliceable) sampling ------------------------
    def _sample_level_hashed(self, nodes: np.ndarray, path_ids: np.ndarray,
                             fanout: int, key: np.uint64):
        """Hashed twin of ``_sample_level``: neighbour slot k of the subtree
        node at path id p draws ``mix64(key ^ (p*STRIDE + k + 1)) % deg`` —
        no generator state, so any slice of the batch reproduces exactly.
        Returns (neighbours, child path ids)."""
        if fanout >= int(_PATH_STRIDE):
            raise ValueError(f"fanout {fanout} >= path stride {_PATH_STRIDE}")
        flat = nodes.reshape(-1)
        pids = path_ids.reshape(-1).astype(np.uint64)
        deg = np.minimum(self.deg[flat], self.max_deg)
        with np.errstate(over="ignore"):
            counters = (pids[:, None] * _PATH_STRIDE
                        + np.arange(1, fanout + 1, dtype=np.uint64))
            u = _mix64(counters ^ key)
        idx = (u % np.maximum(deg, 1)[:, None].astype(np.uint64)).astype(np.int64)
        nbr = self.table[flat[:, None], idx]
        nbr = np.where(nbr < 0, flat[:, None], nbr)   # isolated: self-sample
        return (nbr.reshape(*nodes.shape, fanout).astype(np.int32),
                counters.reshape(*nodes.shape, fanout))

    def sample_hashed(self, batch_nodes: np.ndarray, gpos: np.ndarray,
                      key: np.uint64) -> List[np.ndarray]:
        """Deterministic sharded sampling: the subtree below the target at
        *global* batch position ``gpos[i]`` is a pure function of
        ``(key, gpos[i])`` (``key = stream_key(seed, step)``), so shards
        sampling disjoint slices of one global batch reproduce exactly the
        levels a single host would have drawn for the whole batch."""
        levels = [np.asarray(batch_nodes).astype(np.int32)]
        cur = levels[0]
        pids = np.asarray(gpos, np.uint64) + np.uint64(1)   # 0 is never a path
        for lvl, f in enumerate(self.fanouts):
            # per-level subkey: counters are only unique within a level, so
            # re-keying each level keeps a deep node's draws independent of a
            # shallow node's even when their counters coincide (which happens
            # as soon as the global batch exceeds _PATH_STRIDE)
            lkey = np.uint64(_mix64(key + np.uint64(lvl) + np.uint64(1)))
            cur, pids = self._sample_level_hashed(cur, pids, f, lkey)
            levels.append(cur)
        return levels

    def minibatches(self, nodes: np.ndarray, batch_size: int, shuffle: bool = True):
        """Yield (levels, batch_node_ids); final short batch is wrapped (padded
        by resampling from the start) so shapes stay static for jit."""
        for batch in self._batch_ids(nodes, batch_size, shuffle):
            yield self.sample(batch), batch

    def frontier_minibatches(self, nodes: np.ndarray, batch_size: int,
                             shuffle: bool = True, pad_to: int = 256):
        """Dedup-decode twin of ``minibatches``: yields (FrontierBatch, ids)."""
        for batch in self._batch_ids(nodes, batch_size, shuffle):
            yield self.sample_frontier(batch, pad_to=pad_to), batch

    def _batch_ids(self, nodes: np.ndarray, batch_size: int, shuffle: bool):
        order = self.rng.permutation(nodes) if shuffle else np.asarray(nodes)
        n = order.shape[0]
        for s in range(0, n, batch_size):
            batch = order[s: s + batch_size]
            if batch.shape[0] < batch_size:
                pad = order[: batch_size - batch.shape[0]]
                batch = np.concatenate([batch, pad])
            yield batch
