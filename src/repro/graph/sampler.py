"""Uniform neighbour sampling (GraphSAGE, paper §4 / Fig. 4).

Sampling happens host-side (numpy) against the padded neighbour table and
yields fixed-shape device batches:

  step 0: batch of target nodes                     (B,)
  step 1: fanout[0] first neighbours per target     (B, f1)
  step 2: fanout[1] second neighbours per first     (B, f1, f2)

Isolated nodes self-sample (pad with the node itself), matching the common
GraphSAGE implementation behaviour.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.graph.csr import CSRMatrix


class NeighborSampler:
    def __init__(self, adj: CSRMatrix, fanouts: Sequence[int], max_deg: int = 64, seed: int = 0):
        self.fanouts = tuple(fanouts)
        self.table, self.deg = adj.neighbor_padded(max_deg)
        self.max_deg = max_deg
        self.rng = np.random.default_rng(seed)

    def _sample_level(self, nodes: np.ndarray, fanout: int) -> np.ndarray:
        """nodes: (...,) -> (..., fanout) sampled neighbour ids."""
        flat = nodes.reshape(-1)
        deg = np.minimum(self.deg[flat], self.max_deg)
        idx = self.rng.integers(0, np.maximum(deg, 1)[:, None], (flat.shape[0], fanout))
        nbr = self.table[flat[:, None], idx]
        # isolated nodes (-1 entries): fall back to self
        nbr = np.where(nbr < 0, flat[:, None], nbr)
        return nbr.reshape(*nodes.shape, fanout).astype(np.int32)

    def sample(self, batch_nodes: np.ndarray) -> List[np.ndarray]:
        """Returns [targets (B,), level1 (B,f1), level2 (B,f1,f2), ...]."""
        levels = [batch_nodes.astype(np.int32)]
        cur = batch_nodes
        for f in self.fanouts:
            cur = self._sample_level(cur, f)
            levels.append(cur)
        return levels

    def minibatches(self, nodes: np.ndarray, batch_size: int, shuffle: bool = True):
        """Yield (levels, batch_node_ids); final short batch is wrapped (padded
        by resampling from the start) so shapes stay static for jit."""
        order = self.rng.permutation(nodes) if shuffle else np.asarray(nodes)
        n = order.shape[0]
        for s in range(0, n, batch_size):
            batch = order[s: s + batch_size]
            if batch.shape[0] < batch_size:
                pad = order[: batch_size - batch.shape[0]]
                batch = np.concatenate([batch, pad])
            yield self.sample(batch), batch
