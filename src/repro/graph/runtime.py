"""GraphRuntime: one declarative spec → train / eval / serve (ISSUE 4).

The paper's value proposition is end-to-end — hash-compressed node
embeddings trained *jointly* with the GNN and then served cheaply at
industrial scale (§5.3).  Every entry point used to re-wire the same
pipeline by hand (graph → codes → state → sampler → batch source →
prefetch → train step → loop); this module is the single front door:

    spec = RuntimeSpec(graph=GraphSource(n_nodes=20_000),
                       model=paper_gnn_config("sage", n_nodes=20_000),
                       optimizer=AdamWConfig(lr=1e-2, weight_decay=0.0))
    rt = GraphRuntime.from_spec(spec)
    rt.train(300)
    rt.evaluate("val"); rt.evaluate("test")
    engine = rt.serve()          # GraphInferenceEngine (serving.gnn)

Everything on the spec is a plain value (JSON round-trip via
``to_json``/``from_dict``), so scaling 1-shard → N-shard, switching the
decode backend, or turning the hot-node cache on is literally a spec field
change — the runtime internally selects ``SageBatchSource`` vs
``ShardedSageBatchSource``, the mesh + frontier placement, prefetch depth,
and the ``lookup_impl`` decode backend from the spec.  Checkpoints written
by ``train`` carry the spec alongside the params, so
``GraphRuntime.resume(ckpt_dir)`` rebuilds the exact pipeline with no other
inputs.

Determinism contract: a runtime built twice from the same spec produces
bit-identical training (graph, codes, init, and the ``(seed, shard, step)``
batch stream are all pure functions of spec fields) — asserted against the
hand-wired pre-runtime path in ``tests/test_runtime.py``.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import EmbeddingSpec, GNNConfig
from repro.elastic.manager import ElasticSpec
from repro.graph.engine import (FullGraphBatch, GNNModel, PrefetchIterator,
                                SageBatchSource, ShardedSageBatchSource)
from repro.graph.sampler import NeighborSampler
from repro.optim.adamw import AdamWConfig
from repro.serving.batcher import BatchingSpec

FULLGRAPH_MODELS = ("gcn", "sgc", "gin")


# ---------------------------------------------------------------------------
# spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GraphSource:
    """Declarative graph descriptor (the generators are deterministic in
    their seed, so the descriptor IS the dataset).  ``kind="external"``
    marks a graph handed to ``from_spec(graph=...)`` directly — such specs
    still serialize, but ``resume`` needs the same graph passed again."""

    kind: str = "powerlaw"        # powerlaw | sbm | external
    seed: int = 0
    n_nodes: int = 10_000
    n_classes: int = 16
    avg_degree: int = 10          # powerlaw only
    homophily: float = 0.85       # powerlaw only
    p_in: float = 0.02            # sbm only
    p_out: float = 0.002          # sbm only

    def build(self) -> Tuple[Any, np.ndarray]:
        from repro.graph.generate import powerlaw_graph, sbm_graph
        if self.kind == "powerlaw":
            return powerlaw_graph(self.seed, self.n_nodes,
                                  avg_degree=self.avg_degree,
                                  n_classes=self.n_classes,
                                  homophily=self.homophily)
        if self.kind == "sbm":
            return sbm_graph(self.seed, self.n_nodes, self.n_classes,
                             p_in=self.p_in, p_out=self.p_out)
        if self.kind == "external":
            raise ValueError(
                "GraphSource(kind='external') has no generator — pass the "
                "graph to GraphRuntime.from_spec(spec, graph=(adj, labels))")
        raise ValueError(f"unknown graph kind {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class RuntimeSpec:
    """Everything needed to build the training/eval/serving pipeline.

    The three nested configs (``graph`` / ``model`` / ``optimizer``) plus the
    pipeline knobs below are all plain values; ``to_json`` / ``from_dict``
    round-trip the whole spec, and ``train`` stores it in every checkpoint
    manifest (``GraphRuntime.resume``).

    Scaling knobs (each a pure field change — no new code):
      ``n_shards``            1 → plain ``SageBatchSource``; N → stacked
                              ``ShardedSageBatchSource`` + data-axis mesh +
                              per-shard frontier placement.
      ``model.embedding.lookup_impl``   decode backend (gather / onehot /
                              pallas / sharded[:base] / owner[:base] /
                              auto).  ``owner[:base]`` turns on the
                              owner-computes cross-shard dedup decode: the
                              sharded batch source plans the exchange
                              host-side and hub rows decode once on their
                              owning shard; ``auto`` picks it when the
                              measured duplication beats the threshold.
      ``model.embedding.cache_capacity``/``cache_staleness``  hot-node
                              decode cache in the train state.
      ``model.embedding.cache_plan_misses``  plan-ahead miss partition for
                              cached training: the prefetch thread permutes
                              the next batch's frontier miss-first against a
                              host cache shadow, so the jitted step decodes
                              only (predicted) misses — the training twin of
                              serving's miss-only decode (single-shard).
      ``model.embedding.param_dtype``/``quantize``  decode precision: bf16
                              codebook storage and/or fused absmax-int8
                              (``core.backend.MixedPrecisionPolicy``).
      ``model.embedding.codes_placement``  "host" keeps the packed codes
                              buffer in host RAM: the producer gathers each
                              frontier's code rows into the batch (device
                              code memory is O(frontier), not O(nodes));
                              bitwise-identical to "device".
      ``owner_cap``/``owner_unique_cap``  static owner-exchange capacities
                              (None = sized from ``frontier_cap``, see
                              ``graph.sampler.default_owner_caps``).
      ``prefetch_depth``      0 = synchronous sampling, >0 = async
                              double-buffered host→device pipeline.
    """

    graph: GraphSource
    model: GNNConfig
    optimizer: AdamWConfig = dataclasses.field(
        default_factory=lambda: AdamWConfig(lr=1e-2, weight_decay=0.0))
    # -- data pipeline --
    batch_size: int = 256          # GLOBAL batch (split across shards)
    data_seed: int = 0
    max_deg: int = 64
    pad_to: int = 256
    frontier_cap: Optional[int] = None
    dedup: bool = True
    prefetch_depth: int = 2
    n_shards: int = 1
    owner_cap: Optional[int] = None         # owner-exchange request slots
    owner_unique_cap: Optional[int] = None  # owner-exchange decode rows
    # -- init / splits --
    init_seed: int = 0
    split_seed: int = 0
    split_frac: Tuple[float, float, float] = (0.7, 0.1, 0.2)
    # -- loop --
    total_steps: int = 300
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    log_every: int = 25
    # -- eval / serve --
    eval_batch: int = 512
    eval_seed: int = 17
    serve_batch: int = 256
    # continuous-batching serving tier (serving.batcher); None = bare
    # engine, a BatchingSpec makes rt.serve() return a ServingBatcher
    batching: Optional[BatchingSpec] = None
    # elastic training knobs (repro.elastic); None = defaults when an
    # ElasticManager drives the run, irrelevant otherwise
    elastic: Optional[ElasticSpec] = None
    # pallas interpret mode; None resolves to "not on a TPU runtime"
    interpret: Optional[bool] = None

    # -- ergonomics ------------------------------------------------------
    def with_updates(self, **kw) -> "RuntimeSpec":
        """Replace fields across the nesting in one call: RuntimeSpec fields
        first, then ``EmbeddingSpec`` fields (``lookup_impl``,
        ``cache_capacity``, ...), then ``GNNConfig`` fields (``fanouts``,
        ``hidden``, ...).  ``spec.with_updates(n_shards=4)`` or
        ``spec.with_updates(lookup_impl="pallas", cache_capacity=4096)``."""
        spec_f = {f.name for f in dataclasses.fields(RuntimeSpec)}
        emb_f = {f.name for f in dataclasses.fields(EmbeddingSpec)}
        model_f = {f.name for f in dataclasses.fields(GNNConfig)}
        spec_kw, emb_kw, model_kw = {}, {}, {}
        for k, v in kw.items():
            if k in spec_f:
                spec_kw[k] = v
            elif k in emb_f:
                emb_kw[k] = v
            elif k in model_f:
                model_kw[k] = v
            else:
                raise TypeError(f"with_updates: unknown field {k!r}")
        model = spec_kw.pop("model", self.model)
        if emb_kw:
            model = dataclasses.replace(
                model, embedding=dataclasses.replace(model.embedding, **emb_kw))
        if model_kw:
            model = dataclasses.replace(model, **model_kw)
        return dataclasses.replace(self, model=model, **spec_kw)

    # -- serialization ---------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict())

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RuntimeSpec":
        d = dict(d)
        graph = GraphSource(**d.pop("graph"))
        md = dict(d.pop("model"))
        md["embedding"] = EmbeddingSpec(**md["embedding"])
        md["fanouts"] = tuple(md["fanouts"])
        model = GNNConfig(**md)
        opt = AdamWConfig(**d.pop("optimizer"))
        d["split_frac"] = tuple(d["split_frac"])
        if d.get("batching") is not None:
            d["batching"] = BatchingSpec(**d["batching"])
        if d.get("elastic") is not None:
            d["elastic"] = ElasticSpec(**d["elastic"])
        return cls(graph=graph, model=model, optimizer=opt, **d)

    @classmethod
    def from_json(cls, s: str) -> "RuntimeSpec":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# batch source for the full-graph model family
# ---------------------------------------------------------------------------

class FullGraphSource:
    """Trivial batch source for GCN / SGC / GIN (the paper trains them
    without minibatches, §C.1): every step is the same full-graph handle
    plus the training-node ids/labels.  The batch is device-resident once,
    so the per-step H2D cost is zero."""

    def __init__(self, adj_norm, nodes: np.ndarray, labels: np.ndarray):
        import jax.numpy as jnp
        ids = jnp.asarray(np.asarray(nodes), jnp.int32)
        self._batch = {"full": FullGraphBatch(adj_norm),
                       "ids": ids,
                       "labels": jnp.asarray(np.asarray(labels)[nodes],
                                             jnp.int32)}
        self.step = 0

    def next_batch(self) -> Dict[str, Any]:
        self.step += 1
        return self._batch

    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step}

    def load_state_dict(self, state: Dict[str, int]) -> None:
        self.step = int(state["step"])


# ---------------------------------------------------------------------------
# runtime
# ---------------------------------------------------------------------------

class GraphRuntime:
    """Facade over the streaming graph engine: build once from a spec, then
    ``train`` / ``evaluate`` / ``embed`` / ``serve``.

    Construction (``from_spec``) wires graph → codes → state → sampler →
    batch source → placement → train step exactly the way the pre-runtime
    entry points did by hand, so spec-built training is bit-identical to the
    hand-wired path (tests/test_runtime.py).  Benchmarks that need to drive
    steps manually use the exposed attributes (``state``, ``data_iter``,
    ``jitted_step``, ``place``) instead of re-wiring.
    """

    def __init__(self, spec: RuntimeSpec, *, adj, labels):
        self.spec = spec
        cfg = spec.model
        if spec.graph.kind != "external" and cfg.n_nodes != spec.graph.n_nodes:
            raise ValueError(
                f"model.n_nodes {cfg.n_nodes} != graph.n_nodes "
                f"{spec.graph.n_nodes}")
        if adj.shape[0] != cfg.n_nodes:
            raise ValueError(
                f"graph has {adj.shape[0]} nodes, model expects {cfg.n_nodes}")
        self.adj = adj
        self.labels = np.asarray(labels)
        self.cfg = cfg
        self.interpret = (spec.interpret if spec.interpret is not None
                          else jax.default_backend() != "tpu")
        self.fullgraph = cfg.model in FULLGRAPH_MODELS

        # -- codes + state (pure functions of the spec seeds) -------------
        from repro.core import embedding as emb_lib
        from repro.train import init_gnn_train_state, make_gnn_train_step
        key = jax.random.PRNGKey(spec.init_seed)
        ecfg = cfg.embedding_config()
        self.codes = None
        self.codes_on_host = ecfg.codes_on_host
        if self.codes_on_host and self.fullgraph:
            raise ValueError(
                "codes_placement='host' needs the sampled (frontier) model "
                "family — full-graph models decode every node per step, so "
                "there is no O(frontier) working set to stream")
        if ecfg.needs_codes:
            # numpy copy: the train state is donated per step, so a shared
            # device buffer would be deleted out from under a later rebuild
            # (the hashemb family needs no codes at all: position hashes are
            # recomputed from the ids at every lookup).  With
            # codes_placement="host" this numpy array IS the authoritative
            # buffer — params carry no codes_buf at all.
            self.codes = np.asarray(
                emb_lib.make_codes(key, ecfg, aux=adj))
        self.state = init_gnn_train_state(key, cfg, codes=self.codes)
        self.model = GNNModel(cfg, interpret=self.interpret)
        self._code_gather: Optional[Callable[[Any], Any]] = None
        if self.codes_on_host:
            from repro.graph.sampler import attach_codes
            host_codes = self.codes

            def _gather(batch):
                if isinstance(batch, dict) and "frontier" in batch:
                    batch = dict(batch)
                    batch["frontier"] = attach_codes(batch["frontier"],
                                                     host_codes)
                return batch
            self._code_gather = _gather

        # -- splits --------------------------------------------------------
        from repro.graph.generate import train_val_test_split
        tr, va, te = train_val_test_split(spec.split_seed, cfg.n_nodes,
                                          spec.split_frac)
        self.splits = {"train": tr, "val": va, "test": te}

        # -- mesh / placement (n_shards is the whole N-shard switch) -------
        self.mesh = None
        self.place: Callable[[Any], Any] = lambda b: b
        if spec.n_shards > 1:
            from repro.parallel.policy import make_frontier_placement
            from repro.parallel.sharding import data_mesh
            self.mesh = data_mesh(spec.n_shards)
            self.place = make_frontier_placement(self.mesh)

        # -- sampler + batch source ----------------------------------------
        if self.fullgraph:
            # no neighbour table: full-graph models never sample, and the
            # (n_nodes, max_deg) table is real memory at scale
            self.sampler = None
            adjn = adj.with_self_loops().normalized("sym")
            self.adj_norm = adjn
            self.source = FullGraphSource(adjn, tr, self.labels)
        else:
            self.sampler = NeighborSampler(adj, cfg.fanouts,
                                           max_deg=spec.max_deg,
                                           seed=spec.data_seed)
            self.adj_norm = None
            if spec.n_shards > 1:
                if spec.batch_size % spec.n_shards:
                    raise ValueError(
                        f"batch_size {spec.batch_size} not divisible by "
                        f"n_shards {spec.n_shards}")
                # owner-computes decode: the batch source plans the exchange
                # host-side whenever the backend can exploit it — always for
                # an explicit "owner[:base]" impl, measured-duplication-gated
                # for "auto" (the same threshold resolve_auto applies)
                impl = (cfg.embedding.lookup_impl or "auto").split(":")[0]
                owner_plan = (True if impl == "owner"
                              else ("auto" if impl == "auto" else False))
                self.source = ShardedSageBatchSource(
                    self.sampler, tr, self.labels,
                    spec.batch_size // spec.n_shards,
                    n_shards=spec.n_shards, seed=spec.data_seed,
                    pad_to=spec.pad_to, frontier_cap=spec.frontier_cap,
                    owner_plan=owner_plan, owner_cap=spec.owner_cap,
                    owner_unique_cap=spec.owner_unique_cap)
            else:
                self.source = SageBatchSource(
                    self.sampler, tr, self.labels, spec.batch_size,
                    seed=spec.data_seed, dedup=spec.dedup,
                    pad_to=spec.pad_to, frontier_cap=spec.frontier_cap)
            emb = cfg.embedding
            if emb.cache_plan_misses:
                # plan-ahead miss partition: the producer thread permutes the
                # next frontier miss-first against a host cache shadow, so
                # the train step's decode covers only (predicted) misses
                if emb.cache_capacity <= 0 or not cfg.embedding_config().is_compressed:
                    raise ValueError(
                        "cache_plan_misses needs a hot-node cache on a "
                        "compressed embedding (cache_capacity > 0)")
                if spec.n_shards > 1 or not spec.dedup:
                    raise ValueError(
                        "cache_plan_misses is single-shard dedup only: the "
                        "miss-first permutation breaks stacked per-shard row "
                        "blocks and owner-plan row indexing")
                from repro.graph.engine import MissPlanningSource
                self.source = MissPlanningSource(
                    self.source, emb.cache_capacity, emb.cache_staleness,
                    pad_to=spec.pad_to)

        # -- iterator (prefetch is a knob, not a code path) ----------------
        if spec.prefetch_depth > 0 and not self.fullgraph:
            device = self.place if self.mesh is not None else None
            # codes_placement="host": the producer thread gathers batch
            # k+1's code rows (and completes their H2D copy) while the
            # device computes batch k
            self.data_iter = PrefetchIterator(self.source,
                                              depth=spec.prefetch_depth,
                                              device=device,
                                              code_gather=self._code_gather)
            self._to_device: Callable[[Any], Any] = lambda b: b
        else:
            self.data_iter = self.source
            place = self.place if self.mesh is not None else (lambda b: b)
            if self._code_gather is not None:
                gather = self._code_gather
                self._to_device = lambda b: place(gather(b))
            else:
                self._to_device = place

        # -- step + checkpointing ------------------------------------------
        self.train_step = make_gnn_train_step(
            cfg, spec.optimizer, interpret=self.interpret, mesh=self.mesh,
            duplication=getattr(self.source, "duplication_measured", None))
        self._jitted_step = None
        self.ckpt = None
        if spec.ckpt_dir:
            from repro.train import CheckpointManager
            self.ckpt = CheckpointManager(spec.ckpt_dir, keep=2)
        self._eval_fns: Dict[Any, Callable] = {}

    # -- construction ----------------------------------------------------
    @classmethod
    def from_spec(cls, spec: RuntimeSpec,
                  graph: Optional[Tuple[Any, np.ndarray]] = None
                  ) -> "GraphRuntime":
        """Build the full pipeline from a spec.  ``graph`` overrides the
        declarative ``spec.graph`` generator with a pre-built
        ``(adj, labels)`` pair (required when ``graph.kind == "external"``,
        an optional rebuild-saver otherwise)."""
        if graph is None:
            adj, labels = spec.graph.build()
        else:
            adj, labels = graph
        return cls(spec, adj=adj, labels=labels)

    @classmethod
    def resume(cls, ckpt_dir: str,
               graph: Optional[Tuple[Any, np.ndarray]] = None
               ) -> "GraphRuntime":
        """Rebuild a runtime from the spec stored in ``ckpt_dir``'s latest
        checkpoint manifest AND restore its params/opt/data state, so
        ``evaluate`` / ``embed`` / ``serve`` right after resume see the
        trained model (a later ``train`` call re-restores idempotently and
        continues the exact step sequence)."""
        from repro.train import CheckpointManager
        extra = CheckpointManager(ckpt_dir).read_extra()
        if extra is None or "spec" not in extra:
            raise FileNotFoundError(
                f"no checkpoint with a runtime spec under {ckpt_dir!r}")
        spec = RuntimeSpec.from_dict(extra["spec"])
        spec = dataclasses.replace(spec, ckpt_dir=ckpt_dir)
        rt = cls.from_spec(spec, graph=graph)
        restored = rt.ckpt.restore_latest(rt.state)
        if restored is not None:
            _step, state, rextra = restored
            rt.state = state
            if "data" in rextra and hasattr(rt.data_iter, "load_state_dict"):
                rt.data_iter.load_state_dict(rextra["data"])
            # miss-planning runs: re-anchor the host cache shadow to the
            # restored device cache (exact even for state dicts that predate
            # the shadow snapshot key)
            src = getattr(rt.data_iter, "source", rt.data_iter)
            if hasattr(src, "sync_shadow") and "cache" in rt.state:
                src.sync_shadow(rt.state["cache"])
        return rt

    # -- training --------------------------------------------------------
    @property
    def params(self):
        return self.state["params"]

    @property
    def jitted_step(self):
        """The donated-state jitted train step (for benchmarks that time
        steps manually; ``train`` uses its own via ``run_training``)."""
        if self._jitted_step is None:
            self._jitted_step = jax.jit(self.train_step, donate_argnums=(0,))
        return self._jitted_step

    def train(self, steps: Optional[int] = None,
              on_metrics: Optional[Callable[[int, Dict], None]] = None,
              fence: Optional[Callable[[int], None]] = None):
        """Run the generic fault-tolerant loop for ``steps`` (default
        ``spec.total_steps``) and absorb the resulting state.

        With ``spec.ckpt_dir`` set, ``steps`` is the absolute target step
        count: the loop auto-resumes from the newest checkpoint (params,
        optimizer, data-pipeline state AND the spec ride in every manifest)
        and trains the remaining gap.  Without a checkpoint dir it simply
        runs ``steps`` more steps.  Returns the ``LoopResult``.

        Every checkpoint manifest is stamped with the run's shard topology
        and auto-resume validates it (``train.TopologyMismatch`` on a
        mismatch — rescale via ``GraphRuntime.rescale`` instead).

        ``fence``: step-fence callback (``run_training``), the hook
        ``repro.elastic.ElasticManager`` drives liveness through; it may
        raise ``FenceInterrupt`` to stop at a step boundary."""
        from repro.train import LoopConfig, run_training
        spec = self.spec
        total = int(steps if steps is not None else spec.total_steps)
        res = run_training(
            self.jitted_step, self.state, self.data_iter,
            LoopConfig(total_steps=total, ckpt_every=spec.ckpt_every,
                       log_every=spec.log_every),
            ckpt=self.ckpt, to_device=self._to_device, on_metrics=on_metrics,
            extra_base={"spec": self.spec.to_dict()}, prejitted=True,
            fence=fence,
            topology={"n_shards": spec.n_shards,
                      "batch_size": spec.batch_size})
        self.state = res.state
        return res

    # -- elastic rescale -------------------------------------------------
    def rescale(self, n_shards: int, ckpt_dir: Optional[str] = None
                ) -> "GraphRuntime":
        """Exact in-process rescale: a new runtime at ``n_shards`` that
        continues this run's state and batch stream bit-identically to a
        native ``n_shards``-shard run (``repro.elastic.rescale`` has the
        argument; requires the global ``batch_size`` to divide evenly).
        The old runtime stays usable; close it when done.  ``ckpt_dir``
        names a *fresh* checkpoint directory for the rescaled run — the
        old one is stamped with the old topology and stays behind."""
        from repro.elastic.rescale import rescale_runtime
        return rescale_runtime(self, n_shards, ckpt_dir=ckpt_dir)

    @classmethod
    def rescale_checkpoint(cls, ckpt_dir: str, n_shards: int,
                           graph: Optional[Tuple[Any, np.ndarray]] = None,
                           new_ckpt_dir: Optional[str] = None
                           ) -> "GraphRuntime":
        """The sanctioned cross-topology resume: rebuild the checkpointed
        run at its *original* shard count (topology check passes by
        construction), then exact-rescale to ``n_shards``.  This is the
        path the ``TopologyMismatch`` error message points at."""
        rt = cls.resume(ckpt_dir, graph=graph)
        try:
            return rt.rescale(n_shards, ckpt_dir=new_ckpt_dir)
        finally:
            rt.close()

    # -- evaluation ------------------------------------------------------
    def _eval_fn(self, kind: str):
        if kind not in self._eval_fns:
            model = self.model
            def fn(params, batch):
                h = model.apply(params, batch)
                return model.logits(params, h)
            self._eval_fns[kind] = jax.jit(fn)
        return self._eval_fns[kind]

    def evaluate(self, split: str = "val",
                 batch_size: Optional[int] = None) -> Dict[str, float]:
        """Deterministic accuracy/loss over a named split ("train" / "val" /
        "test").  GraphSAGE evaluates in fixed-size frontier minibatches
        (neighbour draws seeded by ``(eval_seed, batch index)``, so repeat
        calls are identical); full-graph models evaluate in one pass.  The
        final short batch is padded and the padding masked out, so every
        split node counts exactly once."""
        from repro.models import gnn as gnn_lib
        nodes = self.splits[split]
        params = self.state["params"]
        if self.fullgraph:
            logits = np.asarray(
                self._eval_fn("full")(params, FullGraphBatch(self.adj_norm)))
            logits = logits[nodes]
            labels = self.labels[nodes]
            loss = float(gnn_lib.node_loss(jax.numpy.asarray(logits),
                                           jax.numpy.asarray(labels)))
            acc = float((logits.argmax(-1) == labels).mean())
            return {"accuracy": acc, "loss": loss, "n": int(len(nodes))}

        bs = int(batch_size or self.spec.eval_batch)
        eval_fn = self._eval_fn("sage")
        correct, loss_sum, seen = 0, 0.0, 0
        for bi, s in enumerate(range(0, len(nodes), bs)):
            batch = np.asarray(nodes[s:s + bs])
            n_real = batch.shape[0]
            if n_real < bs:                      # pad (masked out below)
                batch = np.concatenate(
                    [batch, np.full(bs - n_real, batch[0], batch.dtype)])
            rng = np.random.default_rng(
                (self.spec.eval_seed * 1_000_003 + 12_582_917) + bi)
            fb = self.sampler.sample_frontier(batch.astype(np.int32),
                                              pad_to=self.spec.pad_to,
                                              rng=rng)
            if self.codes_on_host:
                from repro.graph.sampler import attach_codes
                fb = attach_codes(fb, self.codes)
            logits = np.asarray(eval_fn(params, jax.device_put(fb)))[:n_real]
            labels = self.labels[batch[:n_real]]
            correct += int((logits.argmax(-1) == labels).sum())
            lj = jax.numpy.asarray(logits)
            loss_sum += float(gnn_lib.node_loss(
                lj, jax.numpy.asarray(labels))) * n_real
            seen += n_real
        return {"accuracy": correct / max(seen, 1),
                "loss": loss_sum / max(seen, 1), "n": seen}

    # -- inference -------------------------------------------------------
    def embed(self, node_ids) -> np.ndarray:
        """Final hidden representations (B, H) for ``node_ids`` through the
        current params (direct forward — for a cached, fixed-shape serving
        path use ``serve()``)."""
        ids = np.asarray(node_ids, np.int32)
        if self.fullgraph:
            h = self.model.apply(self.state["params"],
                                 FullGraphBatch(self.adj_norm))
            return np.asarray(h)[ids]
        rng = np.random.default_rng(self.spec.eval_seed)
        fb = self.sampler.sample_frontier(ids, pad_to=self.spec.pad_to,
                                          rng=rng)
        if self.codes_on_host:
            from repro.graph.sampler import attach_codes
            fb = attach_codes(fb, self.codes)
        return np.asarray(
            self.model.apply(self.state["params"], jax.device_put(fb)))

    def serve(self, *, batching=None, **overrides):
        """Freeze the current params into a ``GraphInferenceEngine`` (the
        GNN twin of ``serving.DecodeEngine``): batched frontier sampling,
        miss-only hot-node cached decode, fixed-shape jit.  Keyword
        overrides are forwarded to the engine constructor.

        ``batching`` selects the continuous-batching tier
        (``serving.ServingBatcher``, see ``docs/serving.md``): ``None``
        defers to ``spec.batching``; a ``BatchingSpec`` (or ``True`` for
        defaults) wraps the engine in a batcher whose microbatches get
        cross-request frontier dedup; ``False`` forces the bare engine even
        when the spec asks for batching.  The batcher owns the engine —
        ``close()`` it (or use it as a context manager) when done."""
        if self.fullgraph:
            raise NotImplementedError(
                "serving is minibatched GraphSAGE only; full-graph models "
                "evaluate via runtime.evaluate()")
        from repro.serving.gnn import GraphInferenceEngine
        if batching is None:
            batching = self.spec.batching
        if batching is True:
            batching = BatchingSpec()
        kw = dict(serve_batch=self.spec.serve_batch, pad_to=self.spec.pad_to,
                  interpret=self.interpret)
        if self.codes_on_host:
            # the engine gathers each (possibly permuted) serving frontier's
            # code rows from this buffer — device stays O(frontier)
            kw.setdefault("host_codes", self.codes)
        if batching:
            # engine request-count buckets must admit the batcher's flushes
            kw.setdefault("max_coalesce", batching.max_batch)
        kw.update(overrides)
        engine = GraphInferenceEngine(self.cfg, self.state["params"],
                                      self.sampler, **kw)
        if not batching:
            return engine
        from repro.serving.batcher import ServingBatcher
        return ServingBatcher(engine, batching)

    def close(self) -> None:
        if hasattr(self.data_iter, "close"):
            self.data_iter.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
