"""Streaming graph-training engine (sample → lookup → decode → train).

Three pieces restructure the minibatch path end to end:

* **Dedup-decode batches** — ``SageBatchSource`` emits ``FrontierBatch``es
  (unique-node frontier + per-level int32 index maps, see
  ``repro.graph.sampler``), so the embedding decoder runs once per unique
  node instead of once per sampled position.

* **Async prefetch** — ``PrefetchIterator`` wraps any batch source in a
  double-buffered host→device pipeline: a background thread runs the numpy
  sampler and ``jax.device_put``s the next batch(es) while the jitted train
  step consumes the current one.  ``state_dict``/``load_state_dict`` are
  forwarded with consumer-side semantics (the state of the *last consumed*
  batch, not the last produced one), so fault-tolerant resume through
  ``repro.train.loop.run_training`` remains exact.

* **Unified model API** — ``GNNModel.apply(params, batch)`` accepts a
  sampled ``FrontierBatch``, a naive level list, or a ``FullGraphBatch``
  handle, collapsing the divergent ``sage_forward`` / ``fullgraph_forward``
  entry points so training steps, benchmarks and examples stop
  special-casing the model family.

Batch sources are deterministic per step index (each batch is a pure
function of ``(seed, shard, step)``), which is what makes prefetching, crash
resume, data-parallel sharding and the sync/async equivalence tests exact
rather than statistical.

* **Sharded streaming** — ``SageBatchSource(shard=s, n_shards=N)`` slices
  one global per-step batch (same ``TokenStream`` contract);
  ``ShardedSageBatchSource`` stacks the N per-shard frontiers into a single
  batch whose rows are grouped per shard, so the ``"sharded"`` decode
  backend (``repro.core.backend``) decodes shard-local under ``shard_map``
  and an N-shard run is a config change (mesh + ``lookup_impl``), not new
  code.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Optional, Sequence, Union

import jax
import numpy as np

from repro.configs.base import GNNConfig
from repro.graph.csr import CSRMatrix
from repro.graph.sampler import FrontierBatch, NeighborSampler
from repro.models import gnn

Batch = Union[FrontierBatch, "FullGraphBatch", Sequence[Any]]


# ---------------------------------------------------------------------------
# unified model API
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FullGraphBatch:
    """Full-graph "batch": a handle on the normalised adjacency.  ``apply``
    returns hidden states for ALL nodes (the paper trains GCN/SGC/GIN
    without minibatches, §C.1)."""

    adj: CSRMatrix

    def tree_flatten(self):
        return (self.adj,), None

    @classmethod
    def tree_unflatten(cls, _aux, leaves):
        return cls(leaves[0])


class GNNModel:
    """Single entry point over the paper's GNN family.

    ``apply(params, batch)`` dispatches on the batch type at trace time:
      FrontierBatch   -> dedup-decode minibatched GraphSAGE
      list of levels  -> naive minibatched GraphSAGE (reference path)
      FullGraphBatch  -> full-graph GCN / SGC / GIN (or CSRMatrix directly)

    The embedding decode goes through the ``DecodeBackend`` selected by the
    config's ``lookup_impl`` (resolved once here, not per trace);
    ``interpret=True`` runs the pallas backend in interpret mode (CPU CI).
    ``duplication`` is the measured frontier duplication hint ``auto``
    backend selection uses to prefer the owner-computes decode over the
    plain sharded one (``core.backend.resolve_auto``).
    ``apply_cached(params, batch, cache_state)`` is the hot-node-cache twin
    for the frontier path — it returns ``(hidden, new_cache_state)``.
    """

    def __init__(self, cfg: GNNConfig, interpret: bool = False,
                 duplication: Optional[float] = None):
        from repro.core.backend import get_backend
        self.cfg = cfg
        policy = cfg.embedding_config().decoder_config().precision_policy()
        self.backend = get_backend(cfg.embedding.lookup_impl,
                                   interpret=interpret,
                                   duplication=duplication,
                                   policy=policy)

    def init(self, key, codes=None, aux=None):
        return gnn.init_gnn(key, self.cfg, codes=codes, aux=aux)

    def apply(self, params, batch: Batch):
        if isinstance(batch, FrontierBatch):
            return gnn.sage_forward_frontier(params, batch, self.cfg,
                                             backend=self.backend)
        if isinstance(batch, FullGraphBatch):
            return gnn.fullgraph_forward(params, batch.adj, self.cfg)
        if isinstance(batch, CSRMatrix):
            return gnn.fullgraph_forward(params, batch, self.cfg)
        if isinstance(batch, (list, tuple)):
            return gnn.sage_forward(params, list(batch), self.cfg,
                                    backend=self.backend)
        if isinstance(batch, dict):
            return self.apply(params, batch_view(batch))
        raise TypeError(f"GNNModel.apply: unsupported batch type {type(batch)!r}")

    def apply_cached(self, params, batch: Batch, cache_state):
        """Frontier batches decode through the hot-node cache; every other
        batch type falls back to ``apply`` with the state passed through.
        A frontier carrying a static ``n_decode`` (miss-first permuted by
        ``MissPlanningSource``) decodes only its planned-miss prefix."""
        if isinstance(batch, dict):
            batch = batch_view(batch)
        if isinstance(batch, FrontierBatch):
            if batch.n_decode is not None:
                return gnn.sage_forward_frontier_missonly(
                    params, batch, self.cfg, cache_state, batch.n_decode,
                    backend=self.backend)
            return gnn.sage_forward_frontier_cached(
                params, batch, self.cfg, cache_state, backend=self.backend)
        return self.apply(params, batch), cache_state

    def logits(self, params, hidden):
        return gnn.node_logits(params, hidden, self.cfg)


def batch_view(batch: Dict[str, Any]) -> Batch:
    """Extract the model-facing view from a batch dict produced by the
    sources below ({"frontier": ...}, {"levels": ...}) or the runtime's
    full-graph source ({"full": FullGraphBatch, "ids": ..., "labels": ...})."""
    if "frontier" in batch:
        return batch["frontier"]
    if "levels" in batch:
        return batch["levels"]
    if "full" in batch:
        return batch["full"]
    raise KeyError("batch dict has none of 'frontier' / 'levels' / 'full'")


# ---------------------------------------------------------------------------
# batch sources (host side, deterministic per step)
# ---------------------------------------------------------------------------

def _step_rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng((seed * 1_000_003 + 12_582_917) + step)


def default_frontier_cap(batch_size: int, fanouts, pad_to: int,
                         n_nodes: int) -> int:
    """Exact per-shard frontier size: the worst-case unique count (every
    sampled position distinct, bounded by the graph), rounded up to the
    padding multiple so stacked shard segments stay backend-aligned.

    Worst case is the *safe* default — an undersized cap raises mid-run —
    but real frontiers dedup far below it, so the stacked batch decodes
    padding rows (see BENCH_shard.json rows-vs-unique columns).  Runs that
    know their workload should pass a measured ``frontier_cap``."""
    worst = batch_size
    per_target = 1
    for f in fanouts:
        per_target *= f
        worst += batch_size * per_target
    cap = min(worst, int(n_nodes))
    return -(-cap // max(pad_to, 1)) * max(pad_to, 1)


class SageBatchSource:
    """Per-step GraphSAGE batch source over a node pool with labels.

    Deterministic in ``(seed, shard, step)`` — the same contract as
    ``data.tokens.TokenStream``: each step draws one *global* batch of
    ``batch_size * n_shards`` nodes from an rng seeded by ``(seed, step)``
    (identical on every shard), takes the shard's contiguous slice, and
    samples neighbourhoods counter-based (``NeighborSampler.sample_hashed``)
    keyed by the target's global batch position.  The union of the N shard
    batches is therefore *bit-identical* to the batch an ``n_shards=1``
    source of batch size ``batch_size * n_shards`` produces, and
    ``state_dict`` is just the step, so resume / prefetch replay stay exact
    per shard.

    ``dedup=True`` emits {"frontier": FrontierBatch, "labels": y};
    ``dedup=False`` emits {"levels": tuple, "labels": y} (naive reference).
    ``frontier_cap`` pads every frontier to that exact row count (sharded
    runs stack equal-size per-shard frontiers; ``None`` keeps the usual
    round-up-to-``pad_to`` padding).
    """

    def __init__(self, sampler: NeighborSampler, nodes, labels, batch_size: int,
                 seed: int = 0, dedup: bool = True, pad_to: int = 256,
                 shard: int = 0, n_shards: int = 1,
                 frontier_cap: Optional[int] = None):
        if not 0 <= shard < n_shards:
            raise ValueError(f"shard {shard} out of range for {n_shards} shards")
        self.sampler = sampler
        self.nodes = np.asarray(nodes)
        self.labels = np.asarray(labels)
        self.batch_size = int(batch_size)
        self.seed = int(seed)
        self.dedup = dedup
        self.pad_to = pad_to
        self.shard = int(shard)
        self.n_shards = int(n_shards)
        self.frontier_cap = frontier_cap
        self.step = 0

    def next_batch(self) -> Dict[str, Any]:
        from repro.graph import sampler as sampler_mod
        rng = _step_rng(self.seed, self.step)
        key = sampler_mod.stream_key(self.seed, self.step)
        self.step += 1
        global_b = self.batch_size * self.n_shards
        replace = global_b > self.nodes.shape[0]
        # the global draw is shard-independent; every shard consumes the rng
        # identically and keeps only its contiguous slice
        ids_g = rng.choice(self.nodes, global_b, replace=replace).astype(np.int32)
        lo = self.shard * self.batch_size
        ids = ids_g[lo:lo + self.batch_size]
        gpos = np.arange(lo, lo + self.batch_size, dtype=np.uint64)
        y = self.labels[ids].astype(np.int32)
        levels = self.sampler.sample_hashed(ids, gpos, key)
        if self.dedup:
            fb = FrontierBatch.from_levels(levels, pad_to=self.pad_to,
                                           cap=self.frontier_cap)
            return {"frontier": fb, "labels": y}
        return {"levels": tuple(levels), "labels": y}

    # -- checkpointable state -------------------------------------------
    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step, "seed": self.seed,
                "shard": self.shard, "n_shards": self.n_shards}

    def load_state_dict(self, state: Dict[str, int]) -> None:
        assert int(state["seed"]) == self.seed, \
            "restoring a sage batch source from a different run"
        assert (int(state.get("shard", 0)) == self.shard
                and int(state.get("n_shards", 1)) == self.n_shards), \
            "restoring a sage batch source onto a different shard layout"
        self.step = int(state["step"])


class ShardedSageBatchSource:
    """All-shard view of the sharded stream: N per-shard ``SageBatchSource``s
    advanced in lockstep, their batches stacked into one *global* batch.

    The stacked frontier groups rows per shard — row block ``s`` is shard
    ``s``'s frontier, padded to exactly ``frontier_cap`` rows — so placing
    the ``unique`` axis on the mesh's data axis (``policy.
    frontier_batch_shardings``) puts each shard's rows on its own device and
    the ``"sharded"`` decode backend runs shard-local with zero resharding.
    Index maps are offset into the owning shard's block; cross-shard
    duplicate nodes decode once *per shard* (the price of skipping a global
    dedup synchronisation — exactly the multi-host trade).  ``valid`` marks
    each block's genuine prefix, since padding is interleaved per shard
    rather than a global suffix.

    In a true multi-host deployment each host runs only its own
    ``SageBatchSource(shard=s)``; this class is the single-process stand-in
    that drives all shards for tests, benchmarks and the forced-host-device
    CI leg.

    ``owner_plan`` attaches a host-built ``OwnerPlan`` to every batch (in
    the prefetch thread, alongside the sampling) so the ``"owner"`` decode
    backend can dedup hub rows across shards: ``True`` always plans,
    ``"auto"`` measures the step-0 duplication
    (``frontier_rows / unique_rows``) and plans only when it beats
    ``core.backend.OWNER_DUP_THRESHOLD`` — the same rule ``auto`` backend
    selection applies, so plan and backend stay in sync.  A batch whose
    buckets overflow the static ``owner_cap`` / ``owner_unique_cap``
    capacities is emitted WITHOUT a plan after a loud warning (the owner
    backend then falls back to the sharded row-partition decode) — rows are
    never silently truncated.
    """

    def __init__(self, sampler: NeighborSampler, nodes, labels,
                 batch_size: int, n_shards: int, seed: int = 0,
                 pad_to: int = 256, frontier_cap: Optional[int] = None,
                 owner_plan: Union[bool, str] = False,
                 owner_cap: Optional[int] = None,
                 owner_unique_cap: Optional[int] = None):
        if frontier_cap is None:
            frontier_cap = default_frontier_cap(
                batch_size, sampler.fanouts, pad_to, sampler.table.shape[0])
        self.n_shards = int(n_shards)
        self.frontier_cap = int(frontier_cap)
        self.seed = int(seed)
        self.shards = [
            SageBatchSource(sampler, nodes, labels, batch_size, seed=seed,
                            pad_to=pad_to, shard=s, n_shards=n_shards,
                            frontier_cap=self.frontier_cap)
            for s in range(self.n_shards)
        ]
        self._peek = None   # (step, parts) cache so a peek isn't resampled
        self.duplication_measured: Optional[float] = None
        if owner_plan == "auto":
            from repro.core.backend import OWNER_DUP_THRESHOLD
            self.duplication_measured = self.measure_duplication()
            owner_plan = self.duplication_measured > OWNER_DUP_THRESHOLD
        self.owner_plan = bool(owner_plan)
        from repro.graph.sampler import default_owner_caps
        oc, ou = default_owner_caps(self.frontier_cap, self.n_shards)
        for name, cap_ in (("owner_cap", owner_cap),
                           ("owner_unique_cap", owner_unique_cap)):
            if cap_ is not None and int(cap_) <= 0:
                raise ValueError(f"{name} must be positive, got {cap_} "
                                 f"(None = sized from frontier_cap)")
        self.owner_cap = oc if owner_cap is None else int(owner_cap)
        self.owner_unique_cap = (ou if owner_unique_cap is None
                                 else int(owner_unique_cap))

    def measure_duplication(self) -> float:
        """Measured decode duplication of the upcoming batch:
        ``frontier_rows / unique_rows`` per device — the per-device decode
        work (``frontier_cap``, padding included) over the mean per-shard
        unique count; exactly the ratio ``BENCH_shard.json`` reports and
        the factor the owner decode can reclaim.  Peeks without consuming
        (shard steps are restored, and the sampled parts are cached so the
        next ``next_batch`` at the same step reuses instead of resampling),
        so resume stays exact and the step-0 sampling cost is paid once."""
        step0 = self.shards[0].step
        parts = [s.next_batch() for s in self.shards]
        for s in self.shards:
            s.step = step0
        self._peek = (step0, parts)
        total_unique = sum(int(p["frontier"].n_unique) for p in parts)
        return self.frontier_cap * self.n_shards / max(total_unique, 1)

    def next_batch(self) -> Dict[str, Any]:
        from repro.graph.sampler import build_owner_plan
        if self._peek is not None and self._peek[0] == self.shards[0].step:
            parts = self._peek[1]
            for s in self.shards:       # advance as next_batch would have
                s.step += 1
        else:
            parts = [s.next_batch() for s in self.shards]
        self._peek = None
        cap = self.frontier_cap
        fbs = [p["frontier"] for p in parts]
        unique = np.concatenate([np.asarray(fb.unique) for fb in fbs])
        n_levels = len(fbs[0].index_maps)
        maps = tuple(
            np.concatenate([np.asarray(fb.index_maps[i]) + s * cap
                            for s, fb in enumerate(fbs)], axis=0)
            for i in range(n_levels))
        valid = np.concatenate([
            np.arange(cap, dtype=np.int32) < int(fb.n_unique) for fb in fbs])
        n_unique = np.int32(sum(int(fb.n_unique) for fb in fbs))
        labels = np.concatenate([p["labels"] for p in parts])
        plan = None
        if self.owner_plan:
            plan = build_owner_plan(
                [np.asarray(fb.unique) for fb in fbs],
                [int(fb.n_unique) for fb in fbs],
                self.n_shards, self.owner_cap, self.owner_unique_cap)
            if plan is None:
                import warnings
                warnings.warn(
                    f"owner plan overflow: a (requester, owner) bucket "
                    f"exceeded owner_cap={self.owner_cap} or an owner's "
                    f"unique set exceeded owner_unique_cap="
                    f"{self.owner_unique_cap}; emitting the batch without a "
                    f"plan (decode falls back to the sharded row partition "
                    f"— correct, but no cross-shard dedup).  Raise the caps "
                    f"(RuntimeSpec.owner_cap / owner_unique_cap) if this "
                    f"recurs.", stacklevel=2)
        return {"frontier": FrontierBatch(unique, maps, n_unique, valid, plan),
                "labels": labels}

    # -- checkpointable state -------------------------------------------
    def state_dict(self) -> Dict[str, int]:
        return {"step": self.shards[0].step, "seed": self.seed,
                "n_shards": self.n_shards}

    def load_state_dict(self, state: Dict[str, int]) -> None:
        assert int(state["seed"]) == self.seed, \
            "restoring a sharded sage batch source from a different run"
        assert int(state.get("n_shards", 1)) == self.n_shards, \
            "restoring a sharded sage batch source onto a different shard count"
        for sh in self.shards:
            sh.step = int(state["step"])


class MissPlanningSource:
    """Plan-ahead miss partition for *training* with the hot-node cache.

    Serving already decodes only cache misses (``serving.gnn``: the frontier
    is permuted miss-first against the live cache and only a bucketed prefix
    enters the decoder).  Training couldn't — the cache state evolves every
    step, and by the time the prefetch thread sees batch k+1 the device
    cache for batch k hasn't been updated yet.  This wrapper closes that
    gap: it advances a ``core.backend.HostCacheShadow`` (an exact numpy
    replica of the cache *bookkeeping* — the update depends only on the id
    sequence, never on decoded values) one step per produced batch, so the
    producer thread can partition batch k+1's misses while step k runs.

    Each emitted frontier is permuted miss-first with its index maps
    remapped through the inverse permutation, carries an explicit ``valid``
    mask (the prefix mask no longer survives the permutation) and a static
    bucketed ``n_decode`` (geometric ``pad_to`` doubling, one jit retrace
    per bucket — the serving engine's scheme).  The train step then takes
    the ``lookup_missonly`` path: only the prefix enters the decoder.

    A planned miss that turns out to hit is served from the cache anyway
    (harmless); a planned *hit* that misses would read zeros, which is why
    the shadow replays the device update exactly.  On checkpoint resume the
    runtime re-anchors the shadow from the restored device ``CacheState``
    (``sync_shadow``), covering state dicts that predate the shadow key.

    Only single-shard frontiers qualify: the permutation would break the
    per-shard row blocks of stacked sharded batches and the row indexing of
    an ``OwnerPlan`` (``next_batch`` raises on a planned batch).
    """

    def __init__(self, source, capacity: int, staleness: int = 0,
                 pad_to: int = 256):
        from repro.core.backend import HostCacheShadow
        self.source = source
        self.pad_to = max(1, int(pad_to))
        self.shadow = HostCacheShadow(capacity, staleness)

    def _bucket(self, n_miss: int, cap: int) -> int:
        if n_miss <= 0:
            return 0
        b = self.pad_to
        while b < n_miss:
            b *= 2
        return min(b, cap)

    def next_batch(self) -> Dict[str, Any]:
        batch = dict(self.source.next_batch())
        fb = batch["frontier"]
        if fb.plan is not None:
            raise ValueError(
                "MissPlanningSource: owner-planned batches cannot be "
                "miss-permuted (plan rows index the unpermuted frontier)")
        ids = np.asarray(fb.unique)
        U = ids.shape[0]
        valid = (np.asarray(fb.valid) if fb.valid is not None
                 else np.arange(U) < int(fb.n_unique))
        perm, n_miss = self.shadow.plan(ids, valid)
        inv = np.empty_like(perm)
        inv[perm] = np.arange(U, dtype=np.int32)
        n_dec = self._bucket(n_miss, U)
        ids_p, valid_p = ids[perm], valid[perm]
        batch["frontier"] = FrontierBatch(
            unique=ids_p,
            index_maps=tuple(inv[np.asarray(m)] for m in fb.index_maps),
            n_unique=fb.n_unique, valid=valid_p, n_decode=n_dec)
        self.shadow.update(ids_p, valid_p, n_dec)
        return batch

    # -- checkpointable state -------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        sd = dict(self.source.state_dict())
        sd["miss_shadow"] = self.shadow.snapshot()
        return sd

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        self.source.load_state_dict(state)
        if "miss_shadow" in state:
            self.shadow.restore(state["miss_shadow"])
        else:
            # pre-shadow state dict: empty shadow plans everything as a
            # miss (safe); the runtime's resume re-syncs from the device
            # cache right after (sync_shadow)
            self.shadow.clear()

    def sync_shadow(self, cache_state) -> None:
        """Re-anchor the shadow to a restored device ``CacheState``."""
        self.shadow.sync_from_cache_state(cache_state)


# ---------------------------------------------------------------------------
# async prefetch
# ---------------------------------------------------------------------------

class PrefetchIterator:
    """Double-buffered host→device pipeline around a batch source.

    A daemon thread repeatedly calls ``source.next_batch()`` and
    ``jax.device_put``s the result, keeping up to ``depth`` batches in
    flight, so host-side numpy sampling and the H2D copy overlap with the
    jitted step consuming the previous batch.

    ``device`` may be a jax device/sharding (forwarded to
    ``jax.device_put``) or a *callable* ``batch -> placed_batch`` — sharded
    runs pass ``policy.make_frontier_placement(mesh)`` so each shard's
    frontier rows land on their own device straight off the host thread.

    ``code_gather`` is the codes-placement hook (``codes_placement="host"``):
    a host-side ``batch -> batch`` callable — typically ``attach_codes``
    partial-applied to the full packed buffer — run by the producer thread
    on each batch *before* the device put, so the frontier's code rows are
    gathered for batch k+1 while the device computes batch k.  The producer
    blocks on the transferred arrays after ``device_put``, which is what
    makes the pipeline genuinely double-buffered: the H2D copy of the next
    batch completes in the background, not lazily on first consumer use.

    Per-stage producer wall-clock is accumulated and exposed via
    ``stats()`` (``sample_us`` / ``code_gather_us`` / ``put_us`` +
    ``transferred_code_bytes``) — the honest axis for judging whether the
    host gather hides behind the device step.

    Resume semantics: each queue item carries the source state captured
    *after* producing that batch; ``state_dict()`` returns the state of the
    last batch the consumer actually took, so a checkpoint taken after
    consuming k batches restores to exactly batch k+1 regardless of how far
    ahead the producer ran.
    """

    def __init__(self, source, depth: int = 2, device=None, code_gather=None):
        self.source = source
        self.depth = max(1, int(depth))
        self._device = device
        self._code_gather = code_gather
        self._lock = threading.Lock()     # serialises (re)starts vs producer
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._thread: Optional[threading.Thread] = None
        self._q: "queue.Queue" = queue.Queue(maxsize=self.depth)
        self._last_state = self._snapshot()
        # producer-side accounting (producer writes, stats() reads)
        self._n_produced = 0
        self._sample_us = 0.0
        self._code_gather_us = 0.0
        self._put_us = 0.0
        self._transferred_code_bytes = 0
        self._start()

    # -- internals -------------------------------------------------------
    def _snapshot(self):
        if hasattr(self.source, "state_dict"):
            return self.source.state_dict()
        return None

    def _start(self):
        self._stop = threading.Event()
        self._err = None
        self._q = queue.Queue(maxsize=self.depth)
        self._thread = threading.Thread(target=self._produce, daemon=True,
                                        name="engine-prefetch")
        self._thread.start()

    @staticmethod
    def _code_bytes(batch) -> int:
        """Bytes of batch-carried packed code rows (the per-batch H2D code
        traffic a host-placement run pays instead of a resident buffer)."""
        total = 0
        for leaf in jax.tree.leaves(
                batch, is_leaf=lambda x: isinstance(x, FrontierBatch)):
            if isinstance(leaf, FrontierBatch) and leaf.codes is not None:
                total += int(np.asarray(leaf.codes).nbytes)
        return total

    def _produce(self):
        import time as _time
        stop, q = self._stop, self._q
        try:
            while not stop.is_set():
                t0 = _time.perf_counter()
                with self._lock:
                    if stop.is_set():
                        return
                    batch = self.source.next_batch()
                    state = self._snapshot()
                t1 = _time.perf_counter()
                if self._code_gather is not None:
                    batch = self._code_gather(batch)
                    self._transferred_code_bytes += self._code_bytes(batch)
                t2 = _time.perf_counter()
                if callable(self._device):
                    batch = self._device(batch)
                else:
                    batch = jax.device_put(batch, self._device)
                # block here, in the producer: the H2D copy of batch k+1
                # completes while the consumer computes batch k (the actual
                # double-buffering), and put_us measures the real transfer
                jax.block_until_ready(batch)
                t3 = _time.perf_counter()
                self._sample_us += (t1 - t0) * 1e6
                self._code_gather_us += (t2 - t1) * 1e6
                self._put_us += (t3 - t2) * 1e6
                self._n_produced += 1
                item = (batch, state)
                while not stop.is_set():
                    try:
                        q.put(item, timeout=0.05)
                        break
                    except queue.Full:
                        continue
        except BaseException as e:  # surfaced on the consumer side
            self._err = e

    # -- consumer API ----------------------------------------------------
    def next_batch(self):
        if self._thread is None:    # closed (e.g. by run_training): restart
            self._start()
        thread, q = self._thread, self._q
        while True:
            try:
                batch, state = q.get(timeout=0.1)
            except queue.Empty:
                if self._err is not None:
                    raise self._err
                if thread is None or not thread.is_alive():
                    raise RuntimeError("prefetch producer exited without a batch")
                continue
            self._last_state = state
            return batch

    def close(self):
        """Stop the producer and drop any batches in flight.

        Acts as a *pause* when the source is checkpointable: the source is
        rewound to the last consumed batch, so a later ``next_batch`` (which
        restarts the producer lazily) continues the exact sequence — callers
        like ``run_training`` may close an iterator they don't own without
        rendering it unusable."""
        self._stop.set()
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        if self._last_state is not None and hasattr(self.source, "load_state_dict"):
            self.source.load_state_dict(self._last_state)

    def stats(self) -> Dict[str, float]:
        """Cumulative producer-side accounting: per-stage wall-clock
        (``sample_us`` sampling + source bookkeeping, ``code_gather_us``
        host code-row gather, ``put_us`` device put incl. the blocking H2D
        copy), produced-batch count, and code-row transfer volume."""
        n = self._n_produced
        return {
            "n_produced": n,
            "sample_us": self._sample_us,
            "code_gather_us": self._code_gather_us,
            "put_us": self._put_us,
            "transferred_code_bytes": self._transferred_code_bytes,
            "transferred_code_bytes_per_batch": (
                self._transferred_code_bytes / n if n else 0.0),
        }

    # -- checkpointable state -------------------------------------------
    def state_dict(self):
        return self._last_state

    def load_state_dict(self, state) -> None:
        self.close()
        if hasattr(self.source, "load_state_dict"):
            self.source.load_state_dict(state)
        self._last_state = self._snapshot()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
