"""Streaming graph-training engine (sample → lookup → decode → train).

Three pieces restructure the minibatch path end to end:

* **Dedup-decode batches** — ``SageBatchSource`` emits ``FrontierBatch``es
  (unique-node frontier + per-level int32 index maps, see
  ``repro.graph.sampler``), so the embedding decoder runs once per unique
  node instead of once per sampled position.

* **Async prefetch** — ``PrefetchIterator`` wraps any batch source in a
  double-buffered host→device pipeline: a background thread runs the numpy
  sampler and ``jax.device_put``s the next batch(es) while the jitted train
  step consumes the current one.  ``state_dict``/``load_state_dict`` are
  forwarded with consumer-side semantics (the state of the *last consumed*
  batch, not the last produced one), so fault-tolerant resume through
  ``repro.train.loop.run_training`` remains exact.

* **Unified model API** — ``GNNModel.apply(params, batch)`` accepts a
  sampled ``FrontierBatch``, a naive level list, or a ``FullGraphBatch``
  handle, collapsing the divergent ``sage_forward`` / ``fullgraph_forward``
  entry points so training steps, benchmarks and examples stop
  special-casing the model family.

Batch sources are deterministic per step index (each batch is a pure
function of ``(seed, step)``), which is what makes prefetching, crash
resume and the sync/async equivalence tests exact rather than statistical.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Optional, Sequence, Union

import jax
import numpy as np

from repro.configs.base import GNNConfig
from repro.graph.csr import CSRMatrix
from repro.graph.sampler import FrontierBatch, NeighborSampler
from repro.models import gnn

Batch = Union[FrontierBatch, "FullGraphBatch", Sequence[Any]]


# ---------------------------------------------------------------------------
# unified model API
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class FullGraphBatch:
    """Full-graph "batch": a handle on the normalised adjacency.  ``apply``
    returns hidden states for ALL nodes (the paper trains GCN/SGC/GIN
    without minibatches, §C.1)."""

    adj: CSRMatrix

    def tree_flatten(self):
        return (self.adj,), None

    @classmethod
    def tree_unflatten(cls, _aux, leaves):
        return cls(leaves[0])


class GNNModel:
    """Single entry point over the paper's GNN family.

    ``apply(params, batch)`` dispatches on the batch type at trace time:
      FrontierBatch   -> dedup-decode minibatched GraphSAGE
      list of levels  -> naive minibatched GraphSAGE (reference path)
      FullGraphBatch  -> full-graph GCN / SGC / GIN (or CSRMatrix directly)

    The embedding decode goes through the ``DecodeBackend`` selected by the
    config's ``lookup_impl`` (resolved once here, not per trace);
    ``interpret=True`` runs the pallas backend in interpret mode (CPU CI).
    ``apply_cached(params, batch, cache_state)`` is the hot-node-cache twin
    for the frontier path — it returns ``(hidden, new_cache_state)``.
    """

    def __init__(self, cfg: GNNConfig, interpret: bool = False):
        from repro.core.backend import get_backend
        self.cfg = cfg
        self.backend = get_backend(cfg.embedding.lookup_impl,
                                   interpret=interpret)

    def init(self, key, codes=None, aux=None):
        return gnn.init_gnn(key, self.cfg, codes=codes, aux=aux)

    def apply(self, params, batch: Batch):
        if isinstance(batch, FrontierBatch):
            return gnn.sage_forward_frontier(params, batch, self.cfg,
                                             backend=self.backend)
        if isinstance(batch, FullGraphBatch):
            return gnn.fullgraph_forward(params, batch.adj, self.cfg)
        if isinstance(batch, CSRMatrix):
            return gnn.fullgraph_forward(params, batch, self.cfg)
        if isinstance(batch, (list, tuple)):
            return gnn.sage_forward(params, list(batch), self.cfg,
                                    backend=self.backend)
        if isinstance(batch, dict):
            return self.apply(params, batch_view(batch))
        raise TypeError(f"GNNModel.apply: unsupported batch type {type(batch)!r}")

    def apply_cached(self, params, batch: Batch, cache_state):
        """Frontier batches decode through the hot-node cache; every other
        batch type falls back to ``apply`` with the state passed through."""
        if isinstance(batch, dict):
            batch = batch_view(batch)
        if isinstance(batch, FrontierBatch):
            return gnn.sage_forward_frontier_cached(
                params, batch, self.cfg, cache_state, backend=self.backend)
        return self.apply(params, batch), cache_state

    def logits(self, params, hidden):
        return gnn.node_logits(params, hidden, self.cfg)


def batch_view(batch: Dict[str, Any]) -> Batch:
    """Extract the model-facing view from a batch dict produced by the
    sources below ({"frontier": ...} or {"levels": ...})."""
    if "frontier" in batch:
        return batch["frontier"]
    if "levels" in batch:
        return batch["levels"]
    raise KeyError("batch dict has neither 'frontier' nor 'levels'")


# ---------------------------------------------------------------------------
# batch sources (host side, deterministic per step)
# ---------------------------------------------------------------------------

def _step_rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng((seed * 1_000_003 + 12_582_917) + step)


class SageBatchSource:
    """Per-step GraphSAGE batch source over a node pool with labels.

    Each ``next_batch`` draws ``batch_size`` nodes and samples their
    neighbourhood with a generator seeded by ``(seed, step)`` — the batch
    sequence is a pure function of the step counter, so ``state_dict`` is
    just the step and resume / prefetch replay are exact.

    ``dedup=True`` emits {"frontier": FrontierBatch, "labels": y};
    ``dedup=False`` emits {"levels": tuple, "labels": y} (naive reference).
    """

    def __init__(self, sampler: NeighborSampler, nodes, labels, batch_size: int,
                 seed: int = 0, dedup: bool = True, pad_to: int = 256):
        self.sampler = sampler
        self.nodes = np.asarray(nodes)
        self.labels = np.asarray(labels)
        self.batch_size = int(batch_size)
        self.seed = int(seed)
        self.dedup = dedup
        self.pad_to = pad_to
        self.step = 0

    def next_batch(self) -> Dict[str, Any]:
        rng = _step_rng(self.seed, self.step)
        self.step += 1
        replace = self.batch_size > self.nodes.shape[0]
        ids = rng.choice(self.nodes, self.batch_size, replace=replace).astype(np.int32)
        y = self.labels[ids].astype(np.int32)
        if self.dedup:
            fb = self.sampler.sample_frontier(ids, pad_to=self.pad_to, rng=rng)
            return {"frontier": fb, "labels": y}
        return {"levels": tuple(self.sampler.sample(ids, rng=rng)), "labels": y}

    # -- checkpointable state -------------------------------------------
    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, state: Dict[str, int]) -> None:
        assert int(state["seed"]) == self.seed, \
            "restoring a sage batch source from a different run"
        self.step = int(state["step"])


# ---------------------------------------------------------------------------
# async prefetch
# ---------------------------------------------------------------------------

class PrefetchIterator:
    """Double-buffered host→device pipeline around a batch source.

    A daemon thread repeatedly calls ``source.next_batch()`` and
    ``jax.device_put``s the result, keeping up to ``depth`` batches in
    flight, so host-side numpy sampling and the H2D copy overlap with the
    jitted step consuming the previous batch.

    Resume semantics: each queue item carries the source state captured
    *after* producing that batch; ``state_dict()`` returns the state of the
    last batch the consumer actually took, so a checkpoint taken after
    consuming k batches restores to exactly batch k+1 regardless of how far
    ahead the producer ran.
    """

    def __init__(self, source, depth: int = 2, device=None):
        self.source = source
        self.depth = max(1, int(depth))
        self._device = device
        self._lock = threading.Lock()     # serialises (re)starts vs producer
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._thread: Optional[threading.Thread] = None
        self._q: "queue.Queue" = queue.Queue(maxsize=self.depth)
        self._last_state = self._snapshot()
        self._start()

    # -- internals -------------------------------------------------------
    def _snapshot(self):
        if hasattr(self.source, "state_dict"):
            return self.source.state_dict()
        return None

    def _start(self):
        self._stop = threading.Event()
        self._err = None
        self._q = queue.Queue(maxsize=self.depth)
        self._thread = threading.Thread(target=self._produce, daemon=True,
                                        name="engine-prefetch")
        self._thread.start()

    def _produce(self):
        stop, q = self._stop, self._q
        try:
            while not stop.is_set():
                with self._lock:
                    if stop.is_set():
                        return
                    batch = self.source.next_batch()
                    state = self._snapshot()
                batch = jax.device_put(batch, self._device)
                item = (batch, state)
                while not stop.is_set():
                    try:
                        q.put(item, timeout=0.05)
                        break
                    except queue.Full:
                        continue
        except BaseException as e:  # surfaced on the consumer side
            self._err = e

    # -- consumer API ----------------------------------------------------
    def next_batch(self):
        if self._thread is None:    # closed (e.g. by run_training): restart
            self._start()
        thread, q = self._thread, self._q
        while True:
            try:
                batch, state = q.get(timeout=0.1)
            except queue.Empty:
                if self._err is not None:
                    raise self._err
                if thread is None or not thread.is_alive():
                    raise RuntimeError("prefetch producer exited without a batch")
                continue
            self._last_state = state
            return batch

    def close(self):
        """Stop the producer and drop any batches in flight.

        Acts as a *pause* when the source is checkpointable: the source is
        rewound to the last consumed batch, so a later ``next_batch`` (which
        restarts the producer lazily) continues the exact sequence — callers
        like ``run_training`` may close an iterator they don't own without
        rendering it unusable."""
        self._stop.set()
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        if self._last_state is not None and hasattr(self.source, "load_state_dict"):
            self.source.load_state_dict(self._last_state)

    # -- checkpointable state -------------------------------------------
    def state_dict(self):
        return self._last_state

    def load_state_dict(self, state) -> None:
        self.close()
        if hasattr(self.source, "load_state_dict"):
            self.source.load_state_dict(state)
        self._last_state = self._snapshot()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
